"""Deterministic serve-simulation driver (clock-free discrete events).

Replays arrival traces through the REAL serve objects — `ServeEngine`
wired with its production `AdmissionController`, `Scheduler`,
`SessionManager` and `SessionArena` — and snapshots the control-plane
state after every event so a property suite can assert serving
invariants over the full admit -> schedule -> offload -> restore ->
cancel lifecycle (`tests/test_admission_properties.py`).

Determinism & speed: there is no wall clock anywhere (the "time" axis is
the event sequence itself plus the scheduler's logical round counter),
and by default the engine's fused compute step is replaced with
`launch.serve.make_null_step` — same call contract, zero model FLOPs —
so hundreds of fuzzed traces run in seconds while still exercising real
arena gathers, free-list moves and host offload transfers.  Pass
``params`` to run the same trace against the real model step (used to
cross-check that the null-step harness doesn't diverge structurally).

Events are plain tuples (hypothesis-friendly):

  ("create",  sid, tenant)          # online session (auto on first use)
  ("submit",  sid, op, length, priority, tenant)
  ("run",     max_batches)          # drain up to N batches
  ("offload", sid)                  # explicit offload (may be a no-op)
  ("close",   sid)                  # cancel queued + drop state

The driver never lets a trace die on *caller-contract* errors the fuzzer
can't know about (op on a closed sid, KV-cache exhaustion, wrong-kind
op): those submissions are skipped and counted in ``skipped``.  Engine
bugs — `ArenaFull` escaping, accounting drift, free-list corruption —
propagate, which is exactly what the property suite wants to catch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.launch.serve import make_null_step
from repro.obs import ManualClock, Observability
from repro.serve import ServeEngine, TenantQuota
from repro.serve.scheduler import Request

OPS = ("ingest", "query")


@dataclasses.dataclass
class Snapshot:
    """Control-plane state right after one event."""
    event: Tuple
    n_resident: int                       # online arena
    max_resident: int
    tenant_resident: Dict[str, int]       # per tenant (online arena)
    queued_tokens_total: int              # controller accounting
    queued_tokens: Dict[str, int]         # per tenant (controller)
    true_queued_tokens: Dict[str, int]    # recomputed from the raw queue
    backlog: int
    consistency: List[str]                # arena free-list violations
    admission_counters: Dict[str, int]    # registry verdict counters
    pressure_used: Optional[int] = None   # controller's used_tokens()
    pressure_capacity: Optional[int] = None
    pressure_decisions: int = 0           # ladder log length so far
    n_shards: int = 1
    shard_resident: List[int] = dataclasses.field(default_factory=list)
    shard_open: List[int] = dataclasses.field(default_factory=list)
    shard_free: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Accounting:
    """Terminal disposition of every request the trace produced."""
    submitted: List[Request]
    delivered: Dict[int, int]             # id(req) -> times in a batch
    shed: List[Request]
    cancelled: List[Request]
    skipped: int                          # submissions the driver refused


class ServeSimulation:
    def __init__(self, cfg, *, n_slots: int = 3,
                 max_resident: Optional[int] = None,
                 cache_len: int = 64,
                 policy: str = "block",
                 max_queued_tokens: Optional[int] = None,
                 max_backlog: Optional[int] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None,
                 batch_buckets=(1, 2, 4),
                 token_buckets=(2, 4, 8, 16),
                 aging: Optional[int] = 4,
                 batched_offload: bool = True,
                 async_offload: bool = False,
                 offload_cost_model=None,
                 pressure_policy=None,
                 params=None,
                 n_shards: int = 1,
                 obs: Optional[Observability] = None):
        # tracing on a ManualClock by default: event application advances
        # the clock by exactly 1.0s, so every span timestamp — and
        # therefore every latency histogram bucket — is reproducible
        # run-to-run (the obs property suite depends on this)
        self.obs = obs if obs is not None \
            else Observability.tracing(clock=ManualClock())
        self.clock = self.obs.clock
        self.engine = ServeEngine(
            params, cfg, n_slots=n_slots, cache_len=cache_len,
            max_resident=max_resident, batch_buckets=batch_buckets,
            token_buckets=token_buckets, aging=aging,
            admission_policy=policy, max_queued_tokens=max_queued_tokens,
            max_backlog=max_backlog,
            tenant_quotas=quotas, default_quota=default_quota,
            batched_offload=batched_offload, async_offload=async_offload,
            offload_cost_model=offload_cost_model,
            pressure_policy=pressure_policy,
            step_factory=None if params is not None else make_null_step,
            n_shards=n_shards,
            obs=self.obs)
        self.cache_len = cache_len
        self.verdicts: List[Tuple[Tuple, Any]] = []
        self.snapshots: List[Snapshot] = []
        # (incoming request, its eff. priority, [(victim, victim eff. prio)])
        # recorded AT DECISION TIME — aging moves effective priorities
        # later, so the property suite can't recompute them post hoc
        self.shed_log: List[Tuple[Request, int, List[Tuple[Request, int]]]] \
            = []
        self._submitted: List[Request] = []
        self._delivered: Dict[int, int] = {}
        self._skipped = 0
        self._closed_for_good: set = set()
        # count batch deliveries at the source: wrap BOTH scheduler pops
        # (the engine uses next_batch at n_shards=1, next_sharded_batches
        # otherwise — `requests` is uniform across the two return types)
        sched = self.engine.scheduler

        def _counting(orig):
            def pop(*a, **kw):
                batch = orig(*a, **kw)
                if batch is not None:
                    for r in batch.requests:
                        self._delivered[id(r)] = \
                            self._delivered.get(id(r), 0) + 1
                return batch
            return pop
        sched.next_batch = _counting(sched.next_batch)
        sched.next_sharded_batches = _counting(sched.next_sharded_batches)

    # -- event application --------------------------------------------
    def _ensure_session(self, sid: str, tenant: str) -> bool:
        """Create on first use; a closed sid stays closed (recreating it
        would make 'cancelled exactly the closed session's requests'
        ambiguous in the ledger)."""
        if sid in self.engine._kind:
            return True
        if sid in self._closed_for_good:
            return False
        self.engine.create_session(sid, kind="online", tenant=tenant)
        return True

    def apply(self, event: Tuple) -> Snapshot:
        if hasattr(self.clock, "advance"):
            self.clock.advance(1.0)       # one simulated second per event
        kind = event[0]
        if kind == "create":
            _, sid, tenant = event
            self._ensure_session(sid, tenant)
        elif kind == "submit":
            _, sid, op, length, priority, tenant = event
            self._apply_submit(sid, op, length, priority, tenant)
        elif kind == "run":
            self.engine.run(max_batches=event[1])
        elif kind == "offload":
            self.engine.offload_session(event[1])
        elif kind == "close":
            sid = event[1]
            if sid in self.engine._kind:
                self.engine.close_session(sid)
                self._closed_for_good.add(sid)
        else:
            raise ValueError(f"unknown simulation event {event!r}")
        snap = self.snapshot(event)
        self.snapshots.append(snap)
        return snap

    def _apply_submit(self, sid, op, length, priority, tenant) -> None:
        if op not in OPS or not self._ensure_session(sid, tenant):
            self._skipped += 1
            return
        if op == "query":
            used = self.engine._cached.get(sid, 0)
            if used + length > self.cache_len:   # caller-contract guard
                self._skipped += 1
                return
        toks = np.zeros(length, np.int32)
        verdict = getattr(self.engine, op)(sid, toks, priority=priority)
        self.verdicts.append((("submit", sid, op, length, priority, tenant),
                              verdict))
        self._submitted.append(verdict.request)
        victims = getattr(verdict, "shed_victims", ())
        if victims:
            sch = self.engine.scheduler
            # effective_priority depends only on (priority, round at
            # enqueue, current round) — unchanged by the removal, and the
            # round hasn't advanced since the decision
            self.shed_log.append(
                (verdict.request, verdict.request.priority,
                 [(v, sch.effective_priority(v)) for v in victims]))

    def run_trace(self, events) -> List[Snapshot]:
        for ev in events:
            self.apply(ev)
        return self.snapshots

    def finish(self) -> Snapshot:
        """Drain to quiescence (queue AND pumpable backlog empty)."""
        self.engine.run()
        return self.apply(("run", 0))

    # -- state exposure ------------------------------------------------
    def snapshot(self, event: Tuple = ("probe",)) -> Snapshot:
        eng = self.engine
        mgr = eng._mgr["online"]
        tenants = sorted({s.tenant for s in mgr.sessions.values()}
                        | set(eng.admission.quotas)
                        | {r.tenant for r in eng.scheduler._queue})
        true_q: Dict[str, int] = {}
        for r in eng.scheduler._queue:
            true_q[r.tenant] = true_q.get(r.tenant, 0) + r.token_len
        return Snapshot(
            event=event,
            n_resident=mgr.n_resident,
            max_resident=mgr.max_resident,
            tenant_resident={t: mgr.n_resident_of(t) for t in tenants},
            queued_tokens_total=eng.admission.queued_tokens(),
            queued_tokens={t: eng.admission.queued_tokens(t)
                           for t in tenants},
            true_queued_tokens=true_q,
            backlog=len(eng.admission.backlog),
            consistency=mgr.arena.consistency_errors(),
            admission_counters=dict(eng.admission.stats),
            pressure_used=(eng.pressure.used_tokens()
                           if eng.pressure is not None else None),
            pressure_capacity=(eng.pressure.capacity
                               if eng.pressure is not None else None),
            pressure_decisions=(len(eng.pressure.decisions)
                                if eng.pressure is not None else 0),
            n_shards=eng.n_shards,
            shard_resident=self._shard_resident(mgr),
            shard_open=mgr.shard_load(),
            shard_free=[mgr.arena.shard_free(s)
                        for s in range(eng.n_shards)])

    @staticmethod
    def _shard_resident(mgr) -> List[int]:
        out = [0] * mgr.arena.n_shards
        for s in mgr.sessions.values():
            if s.resident:
                out[s.shard] += 1
        return out

    def accounting(self) -> Accounting:
        return Accounting(
            submitted=list(self._submitted),
            delivered=dict(self._delivered),
            shed=[r for r in self._submitted if r.shed],
            cancelled=[r for r in self._submitted if r.cancelled],
            skipped=self._skipped)

    def session_states(self) -> Dict[str, str]:
        """sid -> 'resident' | 'offloaded' | 'fresh' for every live
        session (the terminal-state half of the acceptance criterion)."""
        out = {}
        for sid, sess in self.engine._mgr["online"].sessions.items():
            if sess.resident:
                out[sid] = "resident"
            elif sess.host_state is not None or sess.needs_replay:
                out[sid] = "offloaded"
            else:
                out[sid] = "fresh"
        return out
