"""Deterministic serve-simulation driver (clock-free discrete events).

Replays arrival traces through the REAL serve objects — `ServeEngine`
wired with its production `AdmissionController`, `Scheduler`,
`SessionManager` and `SessionArena` — and snapshots the control-plane
state after every event so a property suite can assert serving
invariants over the full admit -> schedule -> offload -> restore ->
cancel lifecycle (`tests/test_admission_properties.py`).

Determinism & speed: there is no wall clock anywhere (the "time" axis is
the event sequence itself plus the scheduler's logical round counter),
and by default the engine's fused compute step is replaced with
`launch.serve.make_null_step` — same call contract, zero model FLOPs —
so hundreds of fuzzed traces run in seconds while still exercising real
arena gathers, free-list moves and host offload transfers.  Pass
``params`` to run the same trace against the real model step (used to
cross-check that the null-step harness doesn't diverge structurally).

Events are plain tuples (hypothesis-friendly):

  ("create",  sid, tenant)          # online session (auto on first use)
  ("create",  sid, tenant, plen)    # ... opening with a deterministic
                                    # prefix of plen tokens (content is a
                                    # pure function of plen, so equal
                                    # lengths dedup via the prefix cache)
  ("submit",  sid, op, length, priority, tenant)
  ("submit",  sid, op, length, priority, tenant, rel_deadline)
  ("fork",    parent_sid, child_sid)  # scheduled copy-on-write fork
  ("run",     max_batches)          # drain up to N batches
  ("offload", sid)                  # explicit offload (may be a no-op)
  ("close",   sid)                  # cancel queued + drop state

The optional 7th submit element is a RELATIVE deadline in simulated
seconds (None = no SLO): the driver turns it into an absolute deadline
on the manual clock at submit time, so lateness is a deterministic
function of the event sequence.  Every scheduler pop is additionally
recorded in ``pop_log`` — the eligible set with its `effective_key`s
and lateness flags AT decision time, the chosen requests, and the caps
the engine passed — which is what lets the deadline property suite
replay the fill exactly and prove EDF-within-priority at every pop.

The driver never lets a trace die on *caller-contract* errors the fuzzer
can't know about (op on a closed sid, KV-cache exhaustion, wrong-kind
op): those submissions are skipped and counted in ``skipped``.  Engine
bugs — `ArenaFull` escaping, accounting drift, free-list corruption —
propagate, which is exactly what the property suite wants to catch.

This module also hosts the SHARED trace generators
(`tenant_of` / `expand_event` / `random_events` / `event_strategy`) so
the admission, pressure and deadline property suites all fuzz the same
traffic model instead of three hand-rolled copies.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.launch.serve import make_null_step
from repro.obs import ManualClock, Observability
from repro.serve import ServeEngine, TenantQuota
from repro.serve.scheduler import Request

OPS = ("ingest", "query")

# shared traffic-model vocabulary (used by every property suite)
SIDS = tuple(f"s{i}" for i in range(5))
FORK_SIDS = tuple(f"f{i}" for i in range(4))   # fork-child sid pool
LENGTHS = (1, 2, 3, 5, 8, 13)
PRIORITIES = (0, 1, 2, 3)
PREFIX_LENS = (4, 4, 8)        # repeats on purpose: equal lengths dedup


def tenant_of(sid: str) -> str:
    """Deterministic sid -> tenant map: t0 is quota-bound in bounded
    configs, t1/t2 ride the default quota."""
    return f"t{int(sid[1]) % 3}"


def expand_event(ev: Tuple) -> Tuple:
    """Fill a 5-tuple submit's tenant from `tenant_of`; full 6/7-tuple
    submits and every other event pass through unchanged."""
    if ev[0] == "submit" and len(ev) == 5:
        _, sid, op, length, prio = ev
        return ("submit", sid, op, length, prio, tenant_of(sid))
    return ev


def random_events(rng, n: int, *, sids=SIDS, ops=OPS, lengths=LENGTHS,
                  priorities=PRIORITIES, tenants=None, rel_deadlines=None,
                  max_run: int = 3, fork_sids=None,
                  prefix_lens=None) -> List[Tuple]:
    """Seeded trace generator over the shared traffic model
    (``rng``: `numpy.random.RandomState`).  ``tenants=None`` derives
    tenants via `tenant_of`; ``rel_deadlines`` (a tuple possibly
    containing None) adds the 7th submit element.

    ``fork_sids`` (a child-sid pool, e.g. `FORK_SIDS`) mixes in fork
    events — parents drawn from ``sids`` + the pool itself, so fork
    trees grow several levels deep; ``prefix_lens`` mixes in 4-tuple
    prefix creates (equal lengths dedup via the prefix cache).  Both
    default off so the pre-fork property suites fuzz unchanged
    traffic."""
    all_sids = tuple(sids) + (tuple(fork_sids) if fork_sids else ())
    evs: List[Tuple] = []
    for _ in range(n):
        roll = rng.rand()
        if fork_sids and roll < 0.10:
            parent = all_sids[rng.randint(len(all_sids))]
            child = fork_sids[rng.randint(len(fork_sids))]
            evs.append(("fork", parent, child))
        elif prefix_lens is not None and roll < 0.18:
            sid = all_sids[rng.randint(len(all_sids))]
            evs.append(("create", sid, tenant_of(sid),
                        int(prefix_lens[rng.randint(len(prefix_lens))])))
        elif roll < 0.55:
            sid = all_sids[rng.randint(len(all_sids))]
            ev = ["submit", sid, ops[rng.randint(len(ops))],
                  int(lengths[rng.randint(len(lengths))]),
                  int(priorities[rng.randint(len(priorities))]),
                  (tenants[rng.randint(len(tenants))] if tenants
                   else tenant_of(sid))]
            if rel_deadlines is not None:
                ev.append(rel_deadlines[rng.randint(len(rel_deadlines))])
            evs.append(tuple(ev))
        elif roll < 0.75:
            evs.append(("run", int(rng.randint(1, max_run + 1))))
        elif roll < 0.85:
            evs.append(("offload", all_sids[rng.randint(len(all_sids))]))
        else:
            evs.append(("close", all_sids[rng.randint(len(all_sids))]))
    return evs


def event_strategy(*, sids=SIDS, ops=OPS, lengths=LENGTHS,
                   priorities=PRIORITIES, tenants=None, rel_deadlines=None,
                   max_run: int = 3, fork_sids=None, prefix_lens=None):
    """Hypothesis strategy over the same traffic model as
    `random_events` (imported lazily so this module stays usable
    without hypothesis installed).  ``fork_sids`` / ``prefix_lens``
    mix in fork and prefix-create events exactly as in
    `random_events`."""
    from hypothesis import strategies as st

    all_sids = tuple(sids) + (tuple(fork_sids) if fork_sids else ())
    parts = [st.sampled_from(all_sids), st.sampled_from(ops),
             st.sampled_from(lengths), st.sampled_from(priorities)]
    if tenants is not None:
        parts.append(st.sampled_from(tenants))
    if rel_deadlines is not None:
        parts.append(st.sampled_from(rel_deadlines))

    def mk_submit(t):
        t = list(t)
        rel = (t.pop(),) if rel_deadlines is not None else ()
        tenant = t.pop() if tenants is not None else tenant_of(t[0])
        return ("submit", t[0], t[1], t[2], t[3], tenant) + rel

    options = [
        st.tuples(*parts).map(mk_submit),
        st.tuples(st.just("run"), st.integers(1, max_run)),
        st.tuples(st.just("offload"), st.sampled_from(all_sids)),
        st.tuples(st.just("close"), st.sampled_from(all_sids))]
    if fork_sids:
        options.append(st.tuples(st.just("fork"),
                                 st.sampled_from(all_sids),
                                 st.sampled_from(tuple(fork_sids))))
    if prefix_lens is not None:
        options.append(st.tuples(st.just("create"),
                                 st.sampled_from(all_sids),
                                 st.sampled_from(prefix_lens))
                       .map(lambda t: ("create", t[1], tenant_of(t[1]),
                                       t[2])))
    return st.one_of(*options)


@dataclasses.dataclass
class Snapshot:
    """Control-plane state right after one event."""
    event: Tuple
    n_resident: int                       # online arena
    max_resident: int
    tenant_resident: Dict[str, int]       # per tenant (online arena)
    queued_tokens_total: int              # controller accounting
    queued_tokens: Dict[str, int]         # per tenant (controller)
    true_queued_tokens: Dict[str, int]    # recomputed from the raw queue
    backlog: int
    consistency: List[str]                # arena free-list violations
    admission_counters: Dict[str, int]    # registry verdict counters
    pressure_used: Optional[int] = None   # controller's used_tokens()
    pressure_capacity: Optional[int] = None
    pressure_decisions: int = 0           # ladder log length so far
    n_shards: int = 1
    shard_resident: List[int] = dataclasses.field(default_factory=list)
    shard_open: List[int] = dataclasses.field(default_factory=list)
    shard_free: List[int] = dataclasses.field(default_factory=list)
    refcounts: List[str] = dataclasses.field(default_factory=list)
    #                                     # refcount-conservation errors
    shared_rows: int = 0                  # rows with refcount > 1


@dataclasses.dataclass
class Accounting:
    """Terminal disposition of every request the trace produced."""
    submitted: List[Request]
    delivered: Dict[int, int]             # id(req) -> times in a batch
    shed: List[Request]
    cancelled: List[Request]
    skipped: int                          # submissions the driver refused


class ServeSimulation:
    def __init__(self, cfg, *, n_slots: int = 3,
                 max_resident: Optional[int] = None,
                 cache_len: int = 64,
                 policy: str = "block",
                 max_queued_tokens: Optional[int] = None,
                 max_backlog: Optional[int] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None,
                 batch_buckets=(1, 2, 4),
                 token_buckets=(2, 4, 8, 16),
                 aging: Optional[int] = 4,
                 batched_offload: bool = True,
                 async_offload: bool = False,
                 offload_cost_model=None,
                 pressure_policy=None,
                 params=None,
                 n_shards: int = 1,
                 edf: bool = True,
                 obs: Optional[Observability] = None):
        # tracing on a ManualClock by default: event application advances
        # the clock by exactly 1.0s, so every span timestamp — and
        # therefore every latency histogram bucket — is reproducible
        # run-to-run (the obs property suite depends on this)
        self.obs = obs if obs is not None \
            else Observability.tracing(clock=ManualClock())
        self.clock = self.obs.clock
        self.engine = ServeEngine(
            params, cfg, n_slots=n_slots, cache_len=cache_len,
            max_resident=max_resident, batch_buckets=batch_buckets,
            token_buckets=token_buckets, aging=aging,
            admission_policy=policy, max_queued_tokens=max_queued_tokens,
            max_backlog=max_backlog,
            tenant_quotas=quotas, default_quota=default_quota,
            batched_offload=batched_offload, async_offload=async_offload,
            offload_cost_model=offload_cost_model,
            pressure_policy=pressure_policy,
            step_factory=None if params is not None else make_null_step,
            n_shards=n_shards, edf=edf,
            obs=self.obs)
        self.cache_len = cache_len
        self.verdicts: List[Tuple[Tuple, Any]] = []
        self.snapshots: List[Snapshot] = []
        # (incoming request, its eff. priority, [(victim, victim eff. prio)])
        # recorded AT DECISION TIME — aging moves effective priorities
        # later, so the property suite can't recompute them post hoc
        self.shed_log: List[Tuple[Request, int, List[Tuple[Request, int]]]] \
            = []
        self._submitted: List[Request] = []
        self._delivered: Dict[int, int] = {}
        self._skipped = 0
        self._closed_for_good: set = set()
        # rid -> absolute deadline the driver computed at submit time
        # (conservation: the engine must carry it unchanged end to end)
        self.deadline_of: Dict[int, Optional[float]] = {}
        # one entry per non-empty scheduler pop: the eligible set (keys +
        # lateness at decision time), what was taken, and the caps the
        # engine passed — enough to replay the fill deterministically
        self.pop_log: List[Dict[str, Any]] = []
        # count batch deliveries at the source: wrap BOTH scheduler pops
        # (the engine uses next_batch at n_shards=1, next_sharded_batches
        # otherwise — `requests` is uniform across the two return types)
        sched = self.engine.scheduler

        def _snap_elig():
            # effective_key/is_late read _round and the clock, which the
            # pop only advances AFTER building its own eligible order —
            # so this pre-pop snapshot sees exactly the keys the pop used
            now = sched.clock.now()
            return now, [dict(rid=id(r), sid=r.sid, kind=r.kind,
                              tenant=r.tenant, token_len=r.token_len,
                              shard=r.shard, deadline=r.deadline,
                              key=sched.effective_key(r),
                              late=sched.is_late(r, now))
                         for r in sched._eligible()]

        def _record(batch, now, elig, caps, default_cap, **extra):
            for r in batch.requests:
                self._delivered[id(r)] = self._delivered.get(id(r), 0) + 1
            self.pop_log.append(dict(
                now=now, elig=elig, kind=batch.kind,
                token_len=batch.token_len, bucket=batch.bucket,
                taken=[id(r) for r in batch.requests],
                lane_caps=None if caps is None else dict(caps),
                default_lane_cap=default_cap,
                max_batch=dict(sched.max_batch),
                batch_buckets=sched.batch_buckets,
                token_buckets=sched.token_buckets,
                max_token_len=dict(sched.max_token_len), **extra))

        orig_pop = sched.next_batch
        orig_sharded = sched.next_sharded_batches

        def pop(caps=None, default_cap=None):
            now, elig = _snap_elig()
            batch = orig_pop(caps, default_cap)
            if batch is not None:
                _record(batch, now, elig, caps, default_cap, sharded=False)
            return batch

        def pop_sharded(n_shards, caps=None, default_cap=None,
                        per_shard_cap=None, max_total=None):
            now, elig = _snap_elig()
            batch = orig_sharded(n_shards, caps, default_cap,
                                 per_shard_cap=per_shard_cap,
                                 max_total=max_total)
            if batch is not None:
                _record(batch, now, elig, caps, default_cap, sharded=True,
                        n_shards=n_shards, per_shard_cap=per_shard_cap,
                        max_total=max_total,
                        taken_shards=[[id(r) for r in sb.requests]
                                      for sb in batch.shards])
            return batch

        sched.next_batch = pop
        sched.next_sharded_batches = pop_sharded

    # -- event application --------------------------------------------
    def _ensure_session(self, sid: str, tenant: str) -> bool:
        """Create on first use; a closed sid stays closed (recreating it
        would make 'cancelled exactly the closed session's requests'
        ambiguous in the ledger)."""
        if sid in self.engine._kind:
            return True
        if sid in self._closed_for_good:
            return False
        self.engine.create_session(sid, kind="online", tenant=tenant)
        return True

    def apply(self, event: Tuple) -> Snapshot:
        if hasattr(self.clock, "advance"):
            self.clock.advance(1.0)       # one simulated second per event
        kind = event[0]
        if kind == "create":
            if len(event) == 4:
                _, sid, tenant, plen = event
                self._apply_prefix_create(sid, tenant, int(plen))
            else:
                _, sid, tenant = event
                self._ensure_session(sid, tenant)
        elif kind == "fork":
            _, parent, child = event
            self._apply_fork(parent, child)
        elif kind == "submit":
            _, sid, op, length, priority, tenant = event[:6]
            rel = event[6] if len(event) > 6 else None
            self._apply_submit(sid, op, length, priority, tenant,
                               rel_deadline=rel)
        elif kind == "run":
            self.engine.run(max_batches=event[1])
        elif kind == "offload":
            self.engine.offload_session(event[1])
        elif kind == "close":
            sid = event[1]
            if sid in self.engine._kind:
                self.engine.close_session(sid)
                self._closed_for_good.add(sid)
        else:
            raise ValueError(f"unknown simulation event {event!r}")
        snap = self.snapshot(event)
        self.snapshots.append(snap)
        return snap

    def _apply_prefix_create(self, sid: str, tenant: str,
                             plen: int) -> None:
        """4-tuple create: open the session with a deterministic prefix
        whose content is a pure function of its length — equal lengths
        within one tenant dedup via the prefix cache."""
        if sid in self.engine._kind or sid in self._closed_for_good:
            self._skipped += 1
            return
        toks = (np.arange(plen, dtype=np.int32) * 7 + plen) % 101
        self.engine.create_session(sid, tenant=tenant,
                                   prefix_tokens=toks)

    def _apply_fork(self, parent: str, child: str) -> None:
        """Fork event: skipped (caller-contract) when the parent is
        unknown/closed or the child sid is taken; fork-of-a-pending-
        child parents are VALID — the scheduler hold chains the
        grandchild fork behind its parent's creation."""
        eng = self.engine
        if (parent not in eng._kind
                or child in eng._kind
                or child in eng._pending_forks
                or child in self._closed_for_good
                or parent == child):
            self._skipped += 1
            return
        eng.fork_session(parent, child)

    def _apply_submit(self, sid, op, length, priority, tenant,
                      rel_deadline=None) -> None:
        if op not in OPS or not self._ensure_session(sid, tenant):
            self._skipped += 1
            return
        if op == "query":
            used = self.engine._cached.get(sid, 0)
            if used + length > self.cache_len:   # caller-contract guard
                self._skipped += 1
                return
        toks = np.zeros(length, np.int32)
        deadline = None if rel_deadline is None \
            else self.clock.now() + float(rel_deadline)
        verdict = getattr(self.engine, op)(sid, toks, priority=priority,
                                           deadline=deadline)
        self.verdicts.append((("submit", sid, op, length, priority, tenant,
                               rel_deadline),
                              verdict))
        self._submitted.append(verdict.request)
        self.deadline_of[id(verdict.request)] = deadline
        victims = getattr(verdict, "shed_victims", ())
        if victims:
            sch = self.engine.scheduler
            # effective_priority depends only on (priority, round at
            # enqueue, current round) — unchanged by the removal, and the
            # round hasn't advanced since the decision
            self.shed_log.append(
                (verdict.request, verdict.request.priority,
                 [(v, sch.effective_priority(v)) for v in victims]))

    def run_trace(self, events) -> List[Snapshot]:
        for ev in events:
            self.apply(ev)
        return self.snapshots

    def finish(self) -> Snapshot:
        """Drain to quiescence (queue AND pumpable backlog empty)."""
        self.engine.run()
        return self.apply(("run", 0))

    # -- state exposure ------------------------------------------------
    def snapshot(self, event: Tuple = ("probe",)) -> Snapshot:
        eng = self.engine
        mgr = eng._mgr["online"]
        tenants = sorted({s.tenant for s in mgr.sessions.values()}
                        | set(eng.admission.quotas)
                        | {r.tenant for r in eng.scheduler._queue})
        true_q: Dict[str, int] = {}
        for r in eng.scheduler._queue:
            true_q[r.tenant] = true_q.get(r.tenant, 0) + r.token_len
        return Snapshot(
            event=event,
            n_resident=mgr.n_resident,
            max_resident=mgr.max_resident,
            tenant_resident={t: mgr.n_resident_of(t) for t in tenants},
            queued_tokens_total=eng.admission.queued_tokens(),
            queued_tokens={t: eng.admission.queued_tokens(t)
                           for t in tenants},
            true_queued_tokens=true_q,
            backlog=len(eng.admission.backlog),
            consistency=mgr.arena.consistency_errors(),
            refcounts=self.refcount_ledger(),
            shared_rows=len(mgr.arena.shared_slots()),
            admission_counters=dict(eng.admission.stats),
            pressure_used=(eng.pressure.used_tokens()
                           if eng.pressure is not None else None),
            pressure_capacity=(eng.pressure.capacity
                               if eng.pressure is not None else None),
            pressure_decisions=(len(eng.pressure.decisions)
                                if eng.pressure is not None else 0),
            n_shards=eng.n_shards,
            shard_resident=self._shard_resident(mgr),
            shard_open=mgr.shard_load(),
            shard_free=[mgr.arena.shard_free(s)
                        for s in range(eng.n_shards)])

    def refcount_ledger(self) -> List[str]:
        """Refcount conservation: every live online-arena row's refcount
        must equal its holder count — resident sessions on the slot plus
        prefix-cache entries pinning it.  Returns violations (empty =
        conserved)."""
        eng = self.engine
        mgr = eng._mgr["online"]
        holders: Dict[int, int] = {}
        for sess in mgr.sessions.values():
            if sess.resident:
                holders[sess.slot] = holders.get(sess.slot, 0) + 1
        if eng.prefix_cache is not None:
            for ent in eng.prefix_cache._entries.values():
                holders[ent.slot] = holders.get(ent.slot, 0) + 1
        errs = []
        for slot in sorted(mgr.arena._live):
            want = holders.get(slot, 0)
            got = mgr.arena.refcount(slot)
            if got != want:
                errs.append(f"slot {slot}: refcount {got} != "
                            f"{want} holders")
        for slot in sorted(holders):
            if slot not in mgr.arena._live:
                errs.append(f"slot {slot}: held but not allocated")
        return errs

    @staticmethod
    def _shard_resident(mgr) -> List[int]:
        out = [0] * mgr.arena.n_shards
        for s in mgr.sessions.values():
            if s.resident:
                out[s.shard] += 1
        return out

    def accounting(self) -> Accounting:
        return Accounting(
            submitted=list(self._submitted),
            delivered=dict(self._delivered),
            shed=[r for r in self._submitted if r.shed],
            cancelled=[r for r in self._submitted if r.cancelled],
            skipped=self._skipped)

    def session_states(self) -> Dict[str, str]:
        """sid -> 'resident' | 'offloaded' | 'fresh' for every live
        session (the terminal-state half of the acceptance criterion)."""
        out = {}
        for sid, sess in self.engine._mgr["online"].sessions.items():
            if sess.resident:
                out[sid] = "resident"
            elif sess.host_state is not None or sess.needs_replay:
                out[sid] = "offloaded"
            else:
                out[sid] = "fresh"
        return out
