"""Traffic-derived token-bucket ladder: golden cases + properties.

`launch.specs.derive_token_buckets` fits a ladder to observed request
lengths by exact DP over ``pad_waste + compile_cost_tokens * churn``.
This suite pins:

  * golden hand-computed fits, including the cost-model regression pin
    (the exact crossover where pricing a compile higher flips the fit
    from two buckets to one);
  * coverage — the ladder always serves the largest observed length
    with a bucket (nothing runs off-ladder on the fitted trace);
  * monotonicity — strictly increasing, and never pad-regressing vs
    the static baseline on the trace it was fit to (the clamp);
  * determinism — same history, same ladder;
  * exactness — a seeded sweep cross-checks the DP against brute-force
    enumeration over all bucket placements at observed lengths;
  * warm-shape gravity — lengths the engine already compiled cost no
    churn, so refits keep them;
  * the engine wiring — ``bucket_policy='derived'`` refits after the
    configured submission interval, swaps the active ladder atomically
    into both engine and scheduler, and counts the refit.
"""
import itertools

import numpy as np
import pytest

from repro.launch.serve import make_null_step
from repro.launch.specs import (SERVE_TOKEN_BUCKETS, derive_token_buckets,
                                pad_waste, token_bucket)
from repro.obs import ManualClock, Observability
from repro.serve import ServeEngine

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# -- goldens ------------------------------------------------------------

def test_golden_free_compiles_exact_cover():
    # zero churn price: one bucket per distinct length, zero pad
    assert derive_token_buckets([6, 7], max_buckets=2,
                                compile_cost_tokens=0.0,
                                baseline=()) == (6, 7)
    assert derive_token_buckets([6, 7], max_buckets=1,
                                compile_cost_tokens=0.0,
                                baseline=()) == (7,)


def test_golden_cost_model_crossover():
    """Hand-computed regression pin for the cost model on [1,1,1,7]:

      (1, 7): pad 0,  churn 2C   ->  cost 2C
      (7,)  : pad 18, churn 1C   ->  cost 18 + C

    crossover at C = 18: below it two buckets win, above it one."""
    lens = [1, 1, 1, 7]
    assert derive_token_buckets(lens, max_buckets=8,
                                compile_cost_tokens=2.0,
                                baseline=()) == (1, 7)
    assert derive_token_buckets(lens, max_buckets=8,
                                compile_cost_tokens=20.0,
                                baseline=()) == (7,)
    assert pad_waste(lens, (7,)) == 18
    assert pad_waste(lens, (1, 7)) == 0


def test_golden_compiled_lens_cost_no_churn():
    # same trace and the expensive price, but both shapes are already
    # compiled -> churn is free and the exact cover wins again
    assert derive_token_buckets([1, 1, 1, 7], max_buckets=8,
                                compile_cost_tokens=20.0,
                                compiled_lens=(1, 7),
                                baseline=()) == (1, 7)


def test_golden_clamp_unions_baseline_on_regression():
    """Churn pricing can buy FEWER buckets than the baseline had; the
    clamp unions the baseline's hit buckets back in so a refit never
    pads worse than what it replaced (on its own window)."""
    lens = [1] * 5 + [8] * 5
    # unclamped DP at C=100: (8,) costs 35+100 < (1,8) at 0+200
    assert derive_token_buckets(lens, max_buckets=8,
                                compile_cost_tokens=100.0,
                                baseline=()) == (8,)
    got = derive_token_buckets(lens, max_buckets=8,
                               compile_cost_tokens=100.0,
                               baseline=(1, 8))
    assert got == (1, 8)
    assert pad_waste(lens, got) <= pad_waste(lens, (1, 8))


def test_empty_history_returns_baseline():
    assert derive_token_buckets([], baseline=(4, 2, 8)) == (2, 4, 8)
    assert derive_token_buckets(
        []) == tuple(sorted(SERVE_TOKEN_BUCKETS))


def test_validation():
    with pytest.raises(ValueError):
        derive_token_buckets([3], max_buckets=0)
    with pytest.raises(ValueError):
        derive_token_buckets([3], compile_cost_tokens=-1.0)
    with pytest.raises(ValueError):
        derive_token_buckets([0])


# -- properties ---------------------------------------------------------

def _check_ladder(lengths, ladder, max_buckets):
    assert ladder == tuple(sorted(set(ladder)))          # strict monotone
    assert all(isinstance(b, int) and b >= 1 for b in ladder)
    assert max(ladder) >= max(lengths)                   # coverage
    # never regress vs the static baseline on the fitted trace
    assert pad_waste(lengths, ladder) <= \
        pad_waste(lengths, SERVE_TOKEN_BUCKETS)


def _brute_force_cost(lengths, max_buckets, cost, compiled):
    """Exhaustive optimum over ladders at observed lengths (the DP's
    search space: the last bucket must cover the max length)."""
    uniq = sorted(set(lengths))
    best = float("inf")
    for k in range(1, min(max_buckets, len(uniq)) + 1):
        for combo in itertools.combinations(uniq, k):
            if combo[-1] != uniq[-1]:
                continue
            c = pad_waste(lengths, combo) + cost * sum(
                1 for b in combo if b not in compiled)
            best = min(best, c)
    return best


def _dp_cost(lengths, ladder, cost, compiled):
    return pad_waste(lengths, ladder) + cost * sum(
        1 for b in ladder if b not in compiled)


def _sweep_case(rng):
    lengths = [int(rng.randint(1, 40)) for _ in range(rng.randint(1, 25))]
    while len(set(lengths)) > 7:                 # keep brute force cheap
        lengths.pop()
    max_buckets = int(rng.randint(1, 9))
    cost = float(rng.choice([0.0, 1.0, 5.0, 30.0, 200.0]))
    uniq = sorted(set(lengths))
    compiled = set(u for u in uniq if rng.rand() < 0.3)
    return lengths, max_buckets, cost, compiled


def test_seeded_sweep_dp_matches_brute_force():
    rng = np.random.RandomState(20260813)
    for _ in range(300):
        lengths, max_buckets, cost, compiled = _sweep_case(rng)
        ladder = derive_token_buckets(lengths, max_buckets=max_buckets,
                                      compile_cost_tokens=cost,
                                      compiled_lens=compiled,
                                      baseline=())
        want = _brute_force_cost(lengths, max_buckets, cost, compiled)
        got = _dp_cost(lengths, ladder, cost, compiled)
        assert got == want, (lengths, max_buckets, cost, compiled,
                             ladder, got, want)


def test_seeded_sweep_ladder_properties():
    rng = np.random.RandomState(20260814)
    for _ in range(200):
        lengths, max_buckets, cost, compiled = _sweep_case(rng)
        ladder = derive_token_buckets(lengths, max_buckets=max_buckets,
                                      compile_cost_tokens=cost,
                                      compiled_lens=compiled)
        _check_ladder(lengths, ladder, max_buckets)
        # determinism: same history, same fit
        again = derive_token_buckets(lengths, max_buckets=max_buckets,
                                     compile_cost_tokens=cost,
                                     compiled_lens=compiled)
        assert again == ladder


if HAVE_HYPOTHESIS:
    @given(lengths=st.lists(st.integers(1, 40), min_size=1, max_size=25),
           max_buckets=st.integers(1, 8),
           cost=st.sampled_from((0.0, 1.0, 5.0, 30.0, 200.0)))
    @settings(max_examples=200, deadline=None)
    def test_property_derived_ladders(lengths, max_buckets, cost):
        ladder = derive_token_buckets(lengths, max_buckets=max_buckets,
                                      compile_cost_tokens=cost)
        _check_ladder(lengths, ladder, max_buckets)
        if len(set(lengths)) <= 7:
            raw = derive_token_buckets(lengths, max_buckets=max_buckets,
                                       compile_cost_tokens=cost,
                                       baseline=())
            assert _dp_cost(lengths, raw, cost, set()) == \
                _brute_force_cost(lengths, max_buckets, cost, set())
else:
    def test_property_derived_ladders():
        pytest.skip("property fuzz needs hypothesis")


# -- engine wiring ------------------------------------------------------

def test_engine_refits_ladder_under_derived_policy(tiny_cfg):
    eng = ServeEngine(
        None, tiny_cfg, n_slots=3, cache_len=64,
        token_buckets=(2, 4, 8, 16),
        bucket_policy="derived", bucket_refit_interval=4,
        bucket_compile_cost_tokens=1.0,
        step_factory=make_null_step,
        obs=Observability.tracing(clock=ManualClock()))
    for sid in ("s0", "s1", "s2"):
        eng.create_session(sid, kind="online")
    # 6 offered lengths, all 3s -> after the 4th submission the next
    # drain refits; at compile cost 1.0 the fit collapses to one warm
    # bucket at the single observed length
    for i in range(6):
        eng.ingest(f"s{i % 3}", np.zeros(3, np.int32))
        eng.run()
    assert int(eng._m_refits.value) >= 1
    assert eng.token_buckets == (3,)
    assert eng.scheduler.token_buckets == (3,)
    assert int(eng._g_ladder.value) == 1
    assert eng.length_history() == [3] * 6
    # preview API agrees with the applied ladder on the same window
    assert eng.derived_token_buckets() == (3,)


def test_engine_static_policy_never_refits(tiny_cfg):
    eng = ServeEngine(
        None, tiny_cfg, n_slots=3, cache_len=64,
        token_buckets=(2, 4, 8, 16),
        bucket_refit_interval=2,
        step_factory=make_null_step,
        obs=Observability.tracing(clock=ManualClock()))
    eng.create_session("s0", kind="online")
    for _ in range(6):
        eng.ingest("s0", np.zeros(3, np.int32))
        eng.run()
    assert int(eng._m_refits.value) == 0
    assert eng.token_buckets == (2, 4, 8, 16)


def test_derived_policy_requires_ragged(tiny_cfg):
    with pytest.raises(ValueError):
        ServeEngine(None, tiny_cfg, n_slots=3, token_buckets=None,
                    bucket_policy="derived", step_factory=make_null_step)
