"""Deadline (EDF) property suite over the serve simulation.

Deadline-carrying traces run through `tests/simulation.py` (REAL
engine/scheduler/admission objects, null compute step, manual clock:
one simulated second per event) and a model checker asserts, at every
scheduler pop and at end of trace:

  1. no EDF inversion — the eligible order at every pop is sorted by
     `Scheduler.effective_key`, so within one effective-priority class
     deadlines are non-decreasing and priority classes dominate
     deadlines across classes;
  2. the fill is canonical — each pop's taken set is reproduced exactly
     by replaying the fill algorithm (kind/bucket filter, batch cap,
     per-tenant lane caps; per-shard caps and the total cap in the
     sharded pop) over the recorded eligible order, so nothing about
     EDF order is lost between `_eligible()` and the returned batch;
  3. late-preferring shed — every `shed-lowest-priority` decision's
     recorded candidate list is sorted by `shed_preference_key`
     (already-late victims first) and the chosen victims replay the
     two-pass (tenant deficit, then global) transactional selection
     exactly;
  4. deadline conservation — the absolute deadline computed at submit
     time rides the request unchanged through every verdict (Admitted /
     Queued backlog pump / Shed) and into every eligible-set snapshot;
  5. aging still rescues — a starved deadline-less low-priority request
     drains under sustained tight-deadline high-priority load (one more
     aging step beats any deadline), the satellite regression for the
     single-effective-key refactor;
  6. off-switch equivalence — with no deadlines submitted, ``edf=True``
     and ``edf=False`` engines produce bit-identical pop sequences,
     verdicts and terminal states on the same trace.

The checker is shared between a hypothesis fuzz (CI runs the
derandomized "ci" profile, see conftest.py) and seeded deterministic
sweeps that run even where hypothesis is not installed.
"""
import math

import numpy as np
import pytest

from repro.launch.specs import token_bucket
from repro.obs import ManualClock
from repro.serve import TenantQuota
from repro.serve.admission import POLICIES
from repro.serve.scheduler import Scheduler

from simulation import ServeSimulation, event_strategy, random_events

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# relative deadlines the traffic model draws from: tight enough that
# some requests are already late when they pop (1 event = 1 second),
# loose enough that others always make it; None = deadline-less
REL_DEADLINES = (None, 1.0, 3.0, 8.0, 20.0)


# -- pop replay: the canonical fill over the recorded eligible order ----

def _head_tlen(head, entry):
    tb = entry["token_buckets"]
    if tb is None:
        return head["token_len"]
    tlen = token_bucket(head["token_len"], tb)
    cap = entry["max_token_len"].get(head["kind"])
    if cap is not None:
        tlen = min(tlen, cap)
    return max(tlen, head["token_len"])


def _fits(entry, tlen):
    head, tb = entry["elig"][0], entry["token_buckets"]
    return [e for e in entry["elig"] if e["kind"] == head["kind"]
            and (e["token_len"] == tlen if tb is None
                 else e["token_len"] <= tlen)]


def _lane_ok(entry, lanes, e):
    caps, dflt = entry["lane_caps"], entry["default_lane_cap"]
    if caps is None and dflt is None:
        return True
    tcap = (caps or {}).get(e["tenant"], dflt)
    return tcap is None or lanes.get(e["tenant"], 0) < tcap


def _replay_single(entry):
    tlen = _head_tlen(entry["elig"][0], entry)
    cap = entry["max_batch"].get(entry["elig"][0]["kind"],
                                 entry["batch_buckets"][-1])
    taken, lanes = [], {}
    for e in _fits(entry, tlen):
        if len(taken) >= cap:
            break
        if not _lane_ok(entry, lanes, e):
            continue
        taken.append(e["rid"])
        lanes[e["tenant"]] = lanes.get(e["tenant"], 0) + 1
    return tlen, taken


def _replay_sharded(entry):
    def resolve(v, kind):
        return v.get(kind) if isinstance(v, dict) else v

    head = entry["elig"][0]
    tlen = _head_tlen(head, entry)
    cap = entry["max_batch"].get(head["kind"], entry["batch_buckets"][-1])
    psc = resolve(entry["per_shard_cap"], head["kind"])
    if psc is not None:
        cap = min(cap, psc)
    total_cap = resolve(entry["max_total"], head["kind"])
    taken = [[] for _ in range(entry["n_shards"])]
    lanes, total = {}, 0
    for e in _fits(entry, tlen):
        if total_cap is not None and total >= total_cap:
            break
        if len(taken[e["shard"]]) >= cap:
            continue
        if not _lane_ok(entry, lanes, e):
            continue
        taken[e["shard"]].append(e["rid"])
        lanes[e["tenant"]] = lanes.get(e["tenant"], 0) + 1
        total += 1
    return tlen, taken


def check_pops(sim):
    """1 + 2: EDF-sorted eligible order and canonical fill, every pop."""
    for entry in sim.pop_log:
        keys = [e["key"] for e in entry["elig"]]
        assert keys == sorted(keys), "eligible order not effective_key order"
        for a, b in zip(entry["elig"], entry["elig"][1:]):
            if a["key"][0] == b["key"][0]:     # same eff-priority class
                assert a["key"][1] <= b["key"][1], \
                    f"deadline inversion within class: {a} before {b}"
        if entry["sharded"]:
            tlen, taken = _replay_sharded(entry)
            assert entry["token_len"] == tlen
            assert entry["taken_shards"] == taken, \
                "sharded pop diverged from the canonical fill"
            assert entry["taken"] == [r for g in taken for r in g]
        else:
            tlen, taken = _replay_single(entry)
            assert entry["token_len"] == tlen
            assert entry["taken"] == taken, \
                "pop diverged from the canonical fill"


def check_shed_decisions(sim):
    """3: candidates in shed_preference_key order; victims replay the
    two-pass transactional selection exactly."""
    for d in sim.engine.admission.shed_decisions:
        def pref(c):
            dl = c["deadline"] if c["deadline"] is not None else math.inf
            return (0 if c["late"] else 1, -c["eff"], dl, -c["seq"])
        prefs = [pref(c) for c in d["candidates"]]
        assert prefs == sorted(prefs), "candidates not preference-sorted"
        for c in d["candidates"]:
            assert c["eff"] > d["incoming"]["priority"], \
                "candidate not strictly lower effective priority"
        victims, vset = [], set()
        freed_t = freed_g = 0
        for c in d["candidates"]:              # pass 1: tenant deficit
            if freed_t >= d["need_t"]:
                break
            if c["tenant"] == d["incoming"]["tenant"]:
                victims.append(c["seq"])
                vset.add(c["seq"])
                freed_t += c["token_len"]
                freed_g += c["token_len"]
        for c in d["candidates"]:              # pass 2: global deficit
            if freed_g >= d["need_g"]:
                break
            if c["seq"] not in vset:
                victims.append(c["seq"])
                vset.add(c["seq"])
                freed_g += c["token_len"]
        assert victims == d["victims"], "victim set diverged from replay"
        assert d["ok"] == (freed_t >= d["need_t"]
                           and freed_g >= d["need_g"])


def check_conservation(sim):
    """4: the submit-time deadline rides the request unchanged."""
    for r in sim._submitted:
        expect = sim.deadline_of.get(id(r))
        if expect is not None:
            assert r.deadline == expect, \
                f"deadline mutated: {expect} -> {r.deadline}"
    for entry in sim.pop_log:
        for e in entry["elig"]:
            want = sim.deadline_of.get(e["rid"])
            if want is not None:
                assert e["deadline"] == want
            # recorded lateness agrees with the recorded clock
            if e["deadline"] is not None:
                assert e["late"] == (entry["now"] > e["deadline"])


def check_disposition_conservation(sim):
    """4b: every deadline-carrying request reaches EXACTLY ONE terminal
    disposition — met, missed, shed or cancelled — so the families sum
    back to ``serve_deadline_requests_total`` (minus any request still
    sitting in the queue/backlog when the trace ends).  The cancelled
    family is the regression: `close_session` used to drop queued
    deadline requests without any terminal count, leaking the
    conservation on every close."""
    eng = sim.engine
    kinds = ("ingest", "query", "stream", "fork")
    fam = eng._m_deadline
    requests = sum(int(fam["requests"].labels(kind=k).value)
                   for k in kinds)
    met = sum(int(fam["met"].labels(kind=k).value) for k in kinds)
    missed = sum(int(fam["missed"].labels(kind=k).value) for k in kinds)
    cancelled = sum(int(fam["cancelled"].labels(kind=k).value)
                    for k in kinds)
    shed = sum(int(fam["shed"].labels(late=y).value)
               for y in ("yes", "no"))
    still_pending = sum(
        1 for r in list(eng.scheduler._queue) + list(eng.admission.backlog)
        if r.deadline is not None)
    assert met + missed + shed + cancelled + still_pending == requests, (
        f"deadline dispositions leak: met={met} missed={missed} "
        f"shed={shed} cancelled={cancelled} pending={still_pending} "
        f"!= requests={requests}")


def check_trace(sim):
    check_pops(sim)
    check_shed_decisions(sim)
    check_conservation(sim)
    check_disposition_conservation(sim)
    for r in sim._submitted:                   # terminal resolution
        assert r.done


# -- trace driver -------------------------------------------------------

def _random_conf(rng):
    return {
        "policy": POLICIES[rng.randint(len(POLICIES))],
        "max_queued_tokens": (None, 12, 24)[rng.randint(3)],
        "n_slots": (2, 4)[rng.randint(2)],
        "aging": (0, 3)[rng.randint(2)],
        "n_shards": (1, 2)[rng.randint(2)],
        "slo": (None, 6.0)[rng.randint(2)],
    }


def build_sim(cfg, conf):
    quotas = None
    if conf.get("slo") is not None:
        # t0 gets an SLO quota: its deadline-less submits acquire
        # derived deadlines (exercised alongside explicit ones)
        quotas = {"t0": TenantQuota(slo_seconds=conf["slo"])}
    return ServeSimulation(
        cfg, n_slots=conf["n_slots"], policy=conf["policy"],
        max_queued_tokens=conf["max_queued_tokens"],
        quotas=quotas, aging=conf["aging"],
        n_shards=conf.get("n_shards", 1),
        edf=conf.get("edf", True))


def run_trace(cfg, events, conf):
    sim = build_sim(cfg, conf)
    for ev in events:
        sim.apply(ev)
    sim.finish()
    check_trace(sim)
    return sim


# -- seeded sweeps (run without hypothesis) -----------------------------

def test_seeded_deadline_traces_uphold_invariants(tiny_cfg):
    rng = np.random.RandomState(20260810)
    for _ in range(30):
        run_trace(tiny_cfg,
                  random_events(rng, 35, rel_deadlines=REL_DEADLINES),
                  _random_conf(rng))


def test_sharded_deadline_traces_uphold_invariants(tiny_cfg):
    """Multi-shard variant: the sharded pop preserves EDF order and the
    canonical fill per shard (entry replay goes through
    `_replay_sharded`)."""
    rng = np.random.RandomState(20260811)
    conf = {"policy": "shed-lowest-priority", "max_queued_tokens": 16,
            "n_slots": 4, "aging": 3, "n_shards": 2, "slo": 6.0}
    sharded_pops = 0
    for _ in range(10):
        sim = run_trace(tiny_cfg,
                        random_events(rng, 35,
                                      rel_deadlines=REL_DEADLINES),
                        conf)
        sharded_pops += sum(1 for e in sim.pop_log if e["sharded"])
    assert sharded_pops > 0, "sweep never exercised the sharded pop"


def test_deadline_sheds_prefer_late_work(tiny_cfg):
    """Targeted: with two equal-priority shed candidates, the one whose
    deadline has already passed is the victim — the on-time request
    keeps its slot."""
    conf = {"policy": "shed-lowest-priority", "max_queued_tokens": 8,
            "n_slots": 3, "aging": 0, "n_shards": 1, "slo": None}
    sim = build_sim(tiny_cfg, conf)
    # t=1: s0 submits 5 tokens, deadline t=2 -> late from t=3 on
    sim.apply(("submit", "s0", "ingest", 5, 3, "t0", 1.0))
    # t=2: s1 submits 3 tokens, no deadline (never late)
    sim.apply(("submit", "s1", "ingest", 3, 3, "t1"))
    # t=3: higher-priority newcomer needs 5 tokens of room; both
    # candidates have eff=3 > 0, but s0 is late -> preferred victim
    sim.apply(("submit", "s3", "ingest", 5, 0, "t1", 10.0))
    _, v0 = sim.verdicts[0]
    _, v1 = sim.verdicts[1]
    _, v2 = sim.verdicts[2]
    assert v0.request.shed and v0.request.done
    assert not v1.request.shed
    assert [v.sid for v in v2.shed_victims] == ["s0"]
    shed = sim.engine._m_deadline["shed"]
    assert int(shed.labels(late="yes").value) == 1
    assert int(shed.labels(late="no").value) == 0
    sim.finish()
    check_trace(sim)


def test_slo_quota_derives_deadlines(tiny_cfg):
    """A tenant SLO turns deadline-less submits into deadline-carrying
    requests (now + slo; per-kind dict maps kinds independently)."""
    sim = ServeSimulation(
        tiny_cfg, n_slots=3,
        quotas={"t0": TenantQuota(slo_seconds={"ingest": 4.0})})
    sim.apply(("submit", "s0", "ingest", 2, 0, "t0"))   # t=1 -> dl 5.0
    sim.apply(("submit", "s0", "query", 2, 0, "t0"))    # no SLO for query
    sim.apply(("submit", "s1", "ingest", 2, 0, "t1"))   # no quota
    v = [vd for _, vd in sim.verdicts]
    assert v[0].request.deadline == pytest.approx(5.0)
    assert v[1].request.deadline is None
    assert v[2].request.deadline is None
    reqs = sim.engine._m_deadline["requests"]
    assert int(reqs.labels(kind="ingest").value) == 1
    sim.finish()
    check_trace(sim)


def test_met_missed_accounting(tiny_cfg):
    """Delivery-side deadline accounting: loose deadlines all count met,
    tight ones all count missed, and the lateness histogram only sees
    misses."""
    def drive(rel):
        sim = ServeSimulation(tiny_cfg, n_slots=3)
        for i, s in enumerate(("s0", "s1", "s2")):
            sim.apply(("submit", s, "ingest", 2, 0, f"t{i}", rel))
        sim.apply(("run", 8))
        sim.finish()
        check_trace(sim)
        met = sum(int(sim.engine._m_deadline["met"].labels(kind=k).value)
                  for k in ("ingest", "query", "stream"))
        missed = sum(
            int(sim.engine._m_deadline["missed"].labels(kind=k).value)
            for k in ("ingest", "query", "stream"))
        return met, missed, sim.engine._h_lateness.labels().count

    met, missed, n_obs = drive(100.0)   # delivery lands well before
    assert (met, missed, n_obs) == (3, 0, 0)
    met, missed, n_obs = drive(0.5)     # late before the run event fires
    assert (met, missed, n_obs) == (0, 3, 3)


def test_cancelled_deadline_requests_get_terminal_disposition(tiny_cfg):
    """Targeted satellite regression: closing a session with queued
    deadline-carrying requests must emit the ``cancelled`` disposition
    for each — before the fix they were counted in
    ``serve_deadline_requests_total`` but never reached met/missed, so
    the conservation met+missed+shed+cancelled == requests broke on
    every close."""
    sim = ServeSimulation(tiny_cfg, n_slots=3)
    sim.apply(("submit", "s0", "ingest", 2, 0, "t0", 10.0))
    sim.apply(("submit", "s0", "query", 2, 0, "t0", 10.0))
    sim.apply(("submit", "s1", "ingest", 2, 0, "t1", 10.0))
    sim.apply(("close", "s0"))            # 2 queued deadline reqs dropped
    fam = sim.engine._m_deadline
    assert int(fam["cancelled"].labels(kind="ingest").value) == 1
    assert int(fam["cancelled"].labels(kind="query").value) == 1
    sim.finish()                          # s1 delivers -> met
    check_trace(sim)                      # incl. disposition conservation
    assert int(fam["met"].labels(kind="ingest").value) == 1


def test_aging_rescues_starved_request_under_edf(tiny_cfg):
    """Satellite regression for the single-effective-key refactor: a
    deadline-less low-priority request starves behind sustained
    tight-deadline priority-0 traffic ONLY until aging drops it into a
    strictly better class — where it beats every deadline."""
    clock = ManualClock()
    sched = Scheduler(batch_buckets=(1,), token_buckets=(4,),
                      aging=2, edf=True, clock=clock)
    starved = sched.submit("s9", "query", np.zeros(2, np.int32),
                           priority=1)
    popped_kinds = []
    for i in range(6):
        clock.advance(1.0)
        sched.submit(f"s{i}", "ingest", np.zeros(2, np.int32),
                     priority=0, deadline=clock.now() + 1.0)
        batch = sched.next_batch()
        popped_kinds.append((batch.kind, [r.sid for r in batch.requests]))
        if any(r is starved for r in batch.requests):
            break
    else:
        pytest.fail(f"aging never rescued the starved request: "
                    f"{popped_kinds}")
    # rescue must happen via a strictly better class, not a tie: at the
    # rescuing pop the starved request's effective priority beat 0
    rounds_waited = len(popped_kinds) - 1
    assert starved.priority - (rounds_waited // 2) < 0
    # and it takes at least the aging horizon to get there (it really
    # was starved first — priority-0 deadline traffic kept winning)
    assert rounds_waited >= 4


def test_starvation_without_aging(tiny_cfg):
    """Contrast case: aging disabled, the same load starves the
    deadline-less request indefinitely (shows aging, not EDF, is the
    rescue mechanism)."""
    clock = ManualClock()
    sched = Scheduler(batch_buckets=(1,), token_buckets=(4,),
                      aging=None, edf=True, clock=clock)
    starved = sched.submit("s9", "query", np.zeros(2, np.int32),
                           priority=1)
    for i in range(8):
        clock.advance(1.0)
        sched.submit(f"s{i}", "ingest", np.zeros(2, np.int32),
                     priority=0, deadline=clock.now() + 1.0)
        batch = sched.next_batch()
        assert all(r is not starved for r in batch.requests)
    assert sched.pending == 1


def test_edf_orders_within_class_priority_across(tiny_cfg):
    """Direct scheduler unit: EDF reorders within one priority class;
    a strictly better class beats any deadline."""
    clock = ManualClock()
    sched = Scheduler(batch_buckets=(1,), token_buckets=(4,),
                      aging=None, edf=True, clock=clock)
    a = sched.submit("sa", "ingest", np.zeros(2, np.int32), priority=1,
                     deadline=9.0)
    b = sched.submit("sb", "ingest", np.zeros(2, np.int32), priority=1,
                     deadline=4.0)
    c = sched.submit("sc", "ingest", np.zeros(2, np.int32), priority=1)
    d = sched.submit("sd", "ingest", np.zeros(2, np.int32), priority=0)
    order = []
    while sched.pending:
        order.extend(r.sid for r in sched.next_batch().requests)
    assert order == ["sd", "sb", "sa", "sc"]
    assert a.done is False               # pops don't resolve; engine does


def test_edf_off_bit_exact_without_deadlines(tiny_cfg):
    """6: with no deadlines in the traffic, edf=True and edf=False
    engines agree pop for pop, verdict for verdict, state for state."""
    rng = np.random.RandomState(20260812)
    for _ in range(4):
        events = random_events(rng, 30)        # no rel_deadlines
        conf = _random_conf(rng)
        conf["slo"] = None                     # no derived deadlines
        runs = []
        for edf in (True, False):
            c = dict(conf, edf=edf)
            sim = build_sim(tiny_cfg, c)
            for ev in events:
                sim.apply(ev)
            sim.finish()
            check_trace(sim)
            runs.append(sim)
        on, off = runs
        pops_on = [(e["kind"], e["token_len"],
                    [x["sid"] for x in e["elig"]], e["taken"] and
                    [x["sid"] for x in e["elig"]
                     if x["rid"] in set(e["taken"])])
                   for e in on.pop_log]
        pops_off = [(e["kind"], e["token_len"],
                     [x["sid"] for x in e["elig"]], e["taken"] and
                     [x["sid"] for x in e["elig"]
                      if x["rid"] in set(e["taken"])])
                    for e in off.pop_log]
        assert pops_on == pops_off
        assert [type(v).__name__ for _, v in on.verdicts] == \
               [type(v).__name__ for _, v in off.verdicts]
        assert on.session_states() == off.session_states()
        assert on.engine.admission.stats == off.engine.admission.stats


# -- hypothesis fuzz ----------------------------------------------------

if HAVE_HYPOTHESIS:
    EVENTS = st.lists(
        event_strategy(rel_deadlines=REL_DEADLINES), max_size=40)
    CONFIGS = st.fixed_dictionaries({
        "policy": st.sampled_from(POLICIES),
        "max_queued_tokens": st.sampled_from((None, 12, 24)),
        "n_slots": st.sampled_from((2, 4)),
        "aging": st.sampled_from((0, 3)),
        "n_shards": st.sampled_from((1, 2)),
        "slo": st.sampled_from((None, 6.0)),
    })

    @given(events=EVENTS, conf=CONFIGS)
    @settings(max_examples=150, deadline=None)
    def test_property_deadline_traces_uphold_invariants(tiny_cfg, events,
                                                        conf):
        run_trace(tiny_cfg, events, conf)
else:
    def test_property_deadline_traces_uphold_invariants():
        pytest.skip("property fuzz needs hypothesis")
