"""Fork / prefix-dedup property suite over the serve simulation.

Fork- and prefix-heavy traces run through `tests/simulation.py` (REAL
engine/arena/session/prefix objects, null compute step) and a model
checker asserts, at EVERY event:

  1. refcount conservation — every live arena row's refcount equals its
     holder count (resident sessions on the slot + prefix-cache entries
     pinning it), via `ServeSimulation.refcount_ledger`;
  2. free-list integrity — `SessionArena.consistency_errors()` stays
     empty: no double-free, no leaked slot, and crucially no
     "shared-row write attempted" violation (a scatter must never land
     on a row with refcount > 1 — the COW break has to run first);

and at end of trace (after `finish()` drains to quiescence):

  3. fork hygiene — no fork is left pending, no child sid is left held
     in the scheduler, and every submitted request reached a terminal
     disposition.

NOTE the suite deliberately does NOT assert the pre-fork shard
invariant `shard_free[s] == slots_per_shard - shard_resident[s]`:
with row sharing two resident sessions can hold ONE slot, so free +
resident no longer tiles the shard.  The refcount ledger is the
sharing-aware replacement.

Real-params tests (tiny model, same idiom as test_serve.py) prove the
numerics: COW isolation (a forked parent's and child's logits each
bit-match unforked controls), shared-row offload keeping siblings
readable, and prefix-dedup hits serving the same logits as a fresh
compression.  Satellite regressions ride along: close() vs an async
offload still in flight, duplicate sids in batch calls, and the
derived-bucket refit deferring to a pop boundary.
"""
import jax
import numpy as np
import pytest

from repro.core import inference as I
from repro.launch.serve import make_null_step
from repro.models import transformer as T
from repro.serve import PressurePolicy
from repro.serve.arena import SessionArena
from repro.serve.engine import ServeEngine
from repro.serve.session import SessionManager

from simulation import (FORK_SIDS, PREFIX_LENS, ServeSimulation,
                        event_strategy, random_events)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# -- the model checker --------------------------------------------------

def check_fork_trace(sim):
    """Refcount conservation + free-list integrity at every event;
    fork hygiene and terminal resolution at quiescence."""
    for snap in sim.snapshots:
        assert snap.consistency == [], \
            f"arena integrity broken after {snap.event}: {snap.consistency}"
        assert snap.refcounts == [], \
            f"refcount leak after {snap.event}: {snap.refcounts}"
    eng = sim.engine
    assert eng._pending_forks == set(), \
        f"forks left pending at quiescence: {eng._pending_forks}"
    assert not eng.scheduler._held, \
        f"child sids left held at quiescence: {eng.scheduler._held}"
    for r in sim._submitted:
        assert r.done, f"request {r.sid}/{r.kind} never resolved"
    # a closed/quiescent trace must also conserve refcounts one last
    # time (snapshots already checked it per event; this catches drift
    # inside the final drain itself)
    assert sim.refcount_ledger() == []


def _conf(rng):
    return {
        "policy": ("block", "shed-lowest-priority",
                   "reject-new")[rng.randint(3)],
        "max_queued_tokens": (None, 12, 24)[rng.randint(3)],
        "n_slots": (4, 6, 8)[rng.randint(3)],   # even: divide n_shards=2
        "aging": (0, 3)[rng.randint(2)],
        "n_shards": (1, 2)[rng.randint(2)],
    }


def build_sim(cfg, conf):
    return ServeSimulation(
        cfg, n_slots=conf["n_slots"], policy=conf["policy"],
        max_queued_tokens=conf["max_queued_tokens"],
        aging=conf["aging"], n_shards=conf.get("n_shards", 1))


def run_trace(cfg, events, conf):
    sim = build_sim(cfg, conf)
    for ev in events:
        sim.apply(ev)
    sim.finish()
    check_fork_trace(sim)
    return sim


FORK_TRAFFIC = dict(fork_sids=FORK_SIDS, prefix_lens=PREFIX_LENS)


# -- seeded sweeps (run without hypothesis) -----------------------------

def test_seeded_fork_traces_uphold_invariants(tiny_cfg):
    rng = np.random.RandomState(20260814)
    forks = shares = 0
    for _ in range(25):
        sim = run_trace(tiny_cfg, random_events(rng, 35, **FORK_TRAFFIC),
                        _conf(rng))
        forks += int(sim.engine._m_fork.value)
        shares += sum(s.shared_rows for s in sim.snapshots)
    # the sweep must actually exercise the machinery it checks
    assert forks > 0, "sweep never executed a fork"
    assert shares > 0, "sweep never observed a shared row"


def test_seeded_sharded_fork_traces(tiny_cfg):
    """Sharded variant: children pin to the parent's shard, shared-row
    offload dedups per shard, and the sharded pop carries fork batches.
    n_shards=4 runs the loop path on one device; under CI's 4-forced-
    device job the same test exercises real per-device slabs."""
    rng = np.random.RandomState(20260815)
    conf = {"policy": "block", "max_queued_tokens": None,
            "n_slots": 8, "aging": 3, "n_shards": 4}
    forks = 0
    for _ in range(10):
        sim = run_trace(tiny_cfg, random_events(rng, 35, **FORK_TRAFFIC),
                        conf)
        forks += int(sim.engine._m_fork.value)
        eng = sim.engine
        mgr = eng._mgr["online"]
        for sess in mgr.sessions.values():      # children on parent shard
            assert 0 <= sess.shard < 4
    assert forks > 0


def test_fork_trees_nest_and_abort_cleanly(tiny_cfg):
    """Grandchild forks chain on held children; closing the root before
    the drain aborts the whole pending subtree without leaking holds,
    side tables or refcounts."""
    sim = ServeSimulation(tiny_cfg, n_slots=4)
    sim.apply(("submit", "s0", "ingest", 4, 0, "t0"))
    sim.apply(("fork", "s0", "f0"))      # child queued on s0
    sim.apply(("fork", "f0", "f1"))      # grandchild queued on held f0
    sim.apply(("submit", "f1", "query", 2, 0, "t0"))   # held, must wait
    sim.apply(("close", "s0"))           # aborts f0 -> recursively f1
    sim.finish()
    check_fork_trace(sim)
    eng = sim.engine
    assert "f0" not in eng._kind and "f1" not in eng._kind
    assert int(eng._m_fork.value) == 0
    # the same shape WITHOUT the close executes the whole tree
    sim2 = ServeSimulation(tiny_cfg, n_slots=4)
    sim2.apply(("submit", "s0", "ingest", 4, 0, "t0"))
    sim2.apply(("fork", "s0", "f0"))
    sim2.apply(("fork", "f0", "f1"))
    sim2.apply(("submit", "f1", "query", 2, 0, "t0"))
    sim2.finish()
    check_fork_trace(sim2)
    assert int(sim2.engine._m_fork.value) == 2
    assert set(sim2.engine._mgr["online"].sessions) == {"s0", "f0", "f1"}


# -- hypothesis fuzz ----------------------------------------------------

if HAVE_HYPOTHESIS:
    EVENTS = st.lists(event_strategy(**FORK_TRAFFIC), max_size=40)
    CONFIGS = st.fixed_dictionaries({
        "policy": st.sampled_from(("block", "shed-lowest-priority",
                                   "reject-new")),
        "max_queued_tokens": st.sampled_from((None, 12, 24)),
        "n_slots": st.sampled_from((4, 6, 8)),
        "aging": st.sampled_from((0, 3)),
        "n_shards": st.sampled_from((1, 2)),
    })

    @given(events=EVENTS, conf=CONFIGS)
    @settings(max_examples=120, deadline=None)
    def test_property_fork_traces_uphold_invariants(tiny_cfg, events,
                                                    conf):
        run_trace(tiny_cfg, events, conf)
else:
    def test_property_fork_traces_uphold_invariants():
        pytest.skip("property fuzz needs hypothesis")


# -- real-model numerics ------------------------------------------------

@pytest.fixture(scope="module")
def params(tiny_cfg):
    return T.init_lm(jax.random.PRNGKey(0), tiny_cfg)


def _tokens(key, n, vocab=128):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(key), (n,), 0, vocab, dtype=np.int32))


def _direct_logits(params, cfg, chunks, query, cache_len=32):
    st = I.init_online_state(cfg, 1, max_cache_len=cache_len)
    for c in chunks:
        st = I.ingest_context(params, cfg, st, c[None])
    lg, _ = I.prefill(params, cfg, st, query[None], full_logits=True)
    return np.asarray(lg[0])


def test_fork_cow_isolation(tiny_cfg, params):
    """The tentpole numeric: after a fork, a parent write COW-breaks
    away from the shared row — the child's logits bit-match a control
    that never saw the parent's post-fork ingest, and the parent's
    match a control that ingested both chunks."""
    c1, c2, q = _tokens(1, 8), _tokens(2, 8), _tokens(3, 4)
    eng = ServeEngine(params, tiny_cfg, n_slots=4, cache_len=32,
                      batch_buckets=(1, 2, 4))
    eng.create_session("p")
    eng.ingest("p", c1)
    eng.run()
    eng.fork_session("p", "c")
    eng.ingest("p", c2)                  # queues BEHIND the fork on p
    rp = eng.query("p", q).request
    rc = eng.query("c", q).request
    eng.run()
    np.testing.assert_allclose(
        np.asarray(rp.result), _direct_logits(params, tiny_cfg, [c1, c2], q),
        atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(rc.result), _direct_logits(params, tiny_cfg, [c1], q),
        atol=1e-5)
    mgr = eng._mgr["online"]
    assert mgr.arena.consistency_errors() == []
    assert int(eng._m_fork.value) == 1
    cow = sum(int(mgr._m_cow.labels(shard=str(s)).value)
              for s in range(mgr.arena.n_shards))
    assert cow >= 1, "parent write never COW-broke the shared row"


def test_fork_shared_row_offload_keeps_siblings_readable(tiny_cfg, params):
    """Offloading one holder of a shared row must not tear the row out
    from under its siblings: after offload + restore, parent and child
    both still serve the pre-fork logits."""
    c1, q = _tokens(11, 8), _tokens(12, 4)
    want = _direct_logits(params, tiny_cfg, [c1], q)
    eng = ServeEngine(params, tiny_cfg, n_slots=4, cache_len=32,
                      batch_buckets=(1, 2, 4))
    eng.create_session("p")
    eng.ingest("p", c1)
    eng.run()
    eng.fork_session("p", "c")
    eng.run()                            # execute the fork -> shared row
    mgr = eng._mgr["online"]
    assert mgr.arena.shared(mgr.sessions["p"].slot)
    eng.offload_session("p")             # parent leaves the shared row
    assert not mgr.sessions["p"].resident
    assert mgr.sessions["c"].resident    # sibling keeps it
    rp = eng.query("p", q).request       # restore path
    rc = eng.query("c", q).request       # still-resident path
    eng.run()
    np.testing.assert_allclose(np.asarray(rp.result), want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rc.result), want, atol=1e-5)
    assert mgr.arena.consistency_errors() == []


def test_prefix_dedup_hits_match_fresh_compression(tiny_cfg, params):
    """Two sessions opening with the same tenant-scoped prefix share one
    compressed row (one insert, one hit) and both serve the same logits
    as a direct compress-from-scratch."""
    ptoks, q = _tokens(21, 8), _tokens(22, 4)
    want = _direct_logits(params, tiny_cfg, [ptoks], q)
    eng = ServeEngine(params, tiny_cfg, n_slots=4, cache_len=32,
                      batch_buckets=(1, 2, 4))
    eng.create_session("a", prefix_tokens=ptoks)
    eng.run()                            # owner compresses + caches
    assert int(eng.prefix_cache._m_inserts.value) == 1
    eng.create_session("b", prefix_tokens=ptoks)   # dedup hit: adopt row
    assert int(eng.prefix_cache._m_hits.value) == 1
    ra = eng.query("a", q).request
    rb = eng.query("b", q).request
    eng.run()
    np.testing.assert_allclose(np.asarray(ra.result), want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rb.result), want, atol=1e-5)
    mgr = eng._mgr["online"]
    assert mgr.arena.consistency_errors() == []
    # different tenant, same tokens: no cross-tenant sharing
    eng.create_session("x", tenant="other", prefix_tokens=ptoks)
    assert int(eng.prefix_cache._m_hits.value) == 1
    assert int(eng.prefix_cache._m_misses.value) >= 1


def test_recompress_skips_shared_rows(tiny_cfg):
    """Pressure lever 1 must never write a shared row in place: on a
    shared slot `_recompress_session` reclaims 0 tokens and leaves the
    slabs untouched (the write guard would refuse the scatter anyway)."""
    eng = ServeEngine(None, tiny_cfg, n_slots=4, cache_len=32,
                      batch_buckets=(1, 2, 4),
                      pressure_policy=PressurePolicy(capacity_tokens=10_000),
                      step_factory=make_null_step)
    eng.create_session("p")
    eng.ingest("p", np.zeros(8, np.int32))
    eng.run()
    eng.fork_session("p", "c")
    eng.run()
    mgr = eng._mgr["online"]
    assert mgr.arena.shared(mgr.sessions["p"].slot)
    assert eng._recompress_session("p") == 0
    assert eng._recompress_session("c") == 0
    assert mgr.arena.consistency_errors() == []


def test_arena_write_guard_refuses_shared_rows(tiny_cfg):
    """Arena-level invariant: a scatter into a refcount>1 row raises and
    is recorded as a consistency violation; once the row drops back to a
    single holder writes are legal again."""
    arena = SessionArena.for_online(tiny_cfg, n_slots=2, cache_len=8)
    slot = arena.alloc()
    arena.incref(slot)
    with pytest.raises(RuntimeError, match="shared"):
        arena.mark_dirty([slot])
    assert any("shared-row write attempted" in e
               for e in arena.consistency_errors())
    arena.free(slot)                     # decref back to one holder
    assert arena.refcount(slot) == 1
    arena.mark_dirty([slot])             # now fine


def test_session_footprint_charges_shared_row_once(tiny_cfg):
    """Pressure accounting: a shared row's compressed-memory tokens are
    charged to exactly one sharer, so used_tokens() reflects physical
    rows, not logical sessions."""
    eng = ServeEngine(None, tiny_cfg, n_slots=4, cache_len=32,
                      batch_buckets=(1, 2, 4),
                      pressure_policy=PressurePolicy(capacity_tokens=10_000),
                      step_factory=make_null_step)
    eng.create_session("p")
    eng.ingest("p", np.zeros(8, np.int32))
    eng.run()
    solo = eng._session_footprint("p")
    assert solo > 0
    eng.fork_session("p", "c")
    eng.run()
    both = eng._session_footprint("p") + eng._session_footprint("c")
    assert both == solo, \
        "shared row double-charged across sharers"


# -- satellite: close() vs async offload in flight ----------------------

def test_close_mid_async_offload_does_not_resurrect(tiny_cfg):
    """Regression: a sid closed while its async offload is still in
    flight must be scrubbed from the in-flight entry — sync() must not
    resurrect host rows for it, and recreating the sid starts fresh."""
    import jax.numpy as jnp
    arena = SessionArena.for_online(tiny_cfg, n_slots=2, cache_len=8)
    mgr = SessionManager(arena, max_resident=2, async_offload=True)
    for s in ("a", "b"):
        mgr.create(s)
        mgr.activate(s)
    marked = jax.tree.map(lambda s: jnp.full(s.shape, 7, s.dtype),
                          arena.template)
    arena.write_slot(mgr.sessions["a"].slot, marked)
    mgr.offload_batch(["a", "b"])        # async: buffers in flight
    assert mgr._inflight
    mgr.close("a")                       # close BEFORE the sync barrier
    for entry in mgr._inflight:          # sid scrubbed from every entry
        assert "a" not in entry[3]
    mgr.sync()                           # must not raise, must not
    assert "a" not in mgr.sessions       # resurrect the closed session
    assert mgr.sessions["b"].host_state is not None
    assert arena.consistency_errors() == []
    # recreate the sid: state starts from zero, not the old marked row
    mgr.create("a")
    mgr.activate("a")
    got = arena.read_slot(mgr.sessions["a"].slot)
    for leaf in jax.tree.leaves(got):
        assert not np.any(np.asarray(leaf) == 7), \
            "closed session's bytes resurrected into the new session"


def test_duplicate_sids_in_batch_calls(tiny_cfg):
    """Regression: duplicate sids in one activate_batch/offload_batch
    call must not double-count — one restore, one offload lane, refcount
    stays 1, free-list stays consistent."""
    import jax.numpy as jnp
    arena = SessionArena.for_online(tiny_cfg, n_slots=2, cache_len=8)
    mgr = SessionManager(arena, max_resident=2)
    mgr.create("a")
    slots = mgr.activate_batch(["a", "a"])
    assert slots[0] == slots[1]
    assert arena.refcount(slots[0]) == 1
    marked = jax.tree.map(lambda s: jnp.full(s.shape, 7, s.dtype),
                          arena.template)
    arena.write_slot(slots[0], marked)
    results = mgr.offload_batch(["a", "a"])
    assert mgr.sessions["a"].n_offloads == 1
    assert sum(1 for r in results if r.status == "offloaded") == 1
    assert arena.consistency_errors() == []
    mgr.activate("a")                    # restore round-trips the bytes
    got = arena.read_slot(mgr.sessions["a"].slot)
    for leaf, exp in zip(jax.tree.leaves(got), jax.tree.leaves(marked)):
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(exp))


# -- satellite: derived-bucket refit defers to pop boundaries -----------

def test_derived_refit_defers_mid_pop(tiny_cfg):
    """Regression: a ladder refit arriving while a (sharded) pop is
    being executed must NOT swap token_buckets mid-flight — it is
    deferred to the pop boundary, counted, and every sub-batch of every
    sharded pop sees one uniform token_len."""
    eng = ServeEngine(None, tiny_cfg, n_slots=8, cache_len=64,
                      batch_buckets=(1, 2, 4),
                      token_buckets=(2, 4, 8, 16),
                      bucket_policy="derived", bucket_refit_interval=4,
                      n_shards=4, step_factory=make_null_step)
    sched = eng.scheduler
    ladders_seen = []
    deferred_returns = []
    orig = sched.next_sharded_batches

    def hostile_pop(*a, **k):
        batch = orig(*a, **k)
        if batch is not None:
            # adversarial: demand a refit while the engine is inside
            # its pop/execute window — must defer, not swap
            before = eng._token_buckets
            deferred_returns.append(eng.refit_token_buckets())
            assert eng._token_buckets == before, \
                "ladder swapped inside the pop window"
            ladders_seen.append(before)
            for sb in batch.shards:       # uniform padded length
                assert sb.token_len == batch.token_len
        return batch

    sched.next_sharded_batches = hostile_pop
    rng = np.random.RandomState(0)
    for i in range(24):                   # skewed lengths drive a refit
        sid = f"s{i % 6}"
        if sid not in eng._kind:
            eng.create_session(sid)
        eng.ingest(sid, np.zeros(int(rng.choice((1, 2, 3, 15))),
                                 np.int32))
        eng.run()
    assert len(ladders_seen) > 0
    assert int(eng._m_refits_deferred.value) == len(deferred_returns)
    assert int(eng._m_refits_deferred.value) > 0
    # the deferred refits DID land (at boundaries): at least one applied
    assert int(eng._m_refits.value) >= 1
    assert not eng._refit_pending        # nothing left dangling
