"""Optimizer, partition, grad-compression tests (incl. hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import partition as PT
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw
from repro.optim.grad_compress import (EFState, compress_with_ef, init_ef,
                                       quantize_int8, dequantize_int8,
                                       topk_sparsify)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_adamw(params)
    cfg = AdamWConfig(lr=0.2, schedule="constant", clip_norm=0.0,
                      warmup_steps=0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_frozen_leaves_untouched():
    params = {"a": jnp.ones(3), "b": jnp.ones(3)}
    mask = {"a": True, "b": False}
    opt = init_adamw(params, mask)
    assert opt.mu["b"] is None                    # no state for frozen
    grads = {"a": jnp.ones(3), "b": jnp.ones(3)}
    new_p, _, _ = adamw_update(AdamWConfig(), params, grads, opt, mask)
    assert bool(jnp.all(new_p["b"] == params["b"]))
    assert bool(jnp.any(new_p["a"] != params["a"]))


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    opt = init_adamw(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, schedule="constant",
                      warmup_steps=0)
    _, _, m = adamw_update(cfg, params, {"w": jnp.full((4,), 100.0)}, opt)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_partition_merge_roundtrip():
    params = {"x": {"lora": {"a": jnp.ones(2)}, "w": jnp.zeros(3)},
              "comp_embed": jnp.ones(4)}
    mask = PT.trainable_mask(params, PT.lora_predicate)
    tp, fp = PT.partition(params, mask)
    assert tp["x"]["w"] is None and fp["x"]["lora"]["a"] is None
    merged = PT.merge(tp, fp)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 merged, params)


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4,
                max_size=64))
@settings(max_examples=30, deadline=None)
def test_int8_roundtrip_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5001 + 1e-6


@given(st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_error_feedback_conservation(seed):
    """compressed + residual == grads + old residual (nothing lost)."""
    key = jax.random.PRNGKey(seed)
    g = {"w": jax.random.normal(key, (32,))}
    ef = init_ef(g)
    ef = EFState(residual={"w": jax.random.normal(
        jax.random.fold_in(key, 1), (32,)) * 0.1})
    comp, new_ef = compress_with_ef(g, ef, codec="int8")
    np.testing.assert_allclose(
        np.asarray(comp["w"] + new_ef.residual["w"]),
        np.asarray(g["w"] + ef.residual["w"]), atol=1e-5)


def test_error_feedback_unbiased_over_time():
    """sum of transmitted updates -> sum of true grads (EF property)."""
    key = jax.random.PRNGKey(0)
    grads = [{"w": jax.random.normal(jax.random.fold_in(key, i), (64,))}
             for i in range(50)]
    ef = init_ef(grads[0])
    sent = jnp.zeros(64)
    for g in grads:
        c, ef = compress_with_ef(g, ef, codec="topk", topk_frac=0.1)
        sent = sent + c["w"]
    true = sum(g["w"] for g in grads)
    # residual bounds the gap
    gap = jnp.abs(true - sent)
    np.testing.assert_allclose(np.asarray(gap),
                               np.abs(np.asarray(ef.residual["w"])),
                               atol=1e-4)


def test_topk_keeps_largest():
    x = jnp.asarray([1.0, -5.0, 0.1, 3.0])
    out = topk_sparsify(x, 0.5)
    np.testing.assert_allclose(np.asarray(out), [0, -5.0, 0, 3.0])


def test_cosine_schedule_monotone_after_warmup():
    from repro.optim.adamw import schedule_lr
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))
