"""Observability layer: histogram percentile math (property-tested),
metrics export, request-lifecycle tracing, flight recorder, and the
NullRecorder bit-exactness acceptance criterion.

The percentile properties pin down the fixed-bucket histogram contract
(`repro.obs.metrics.Histogram`): quantiles are bucket upper bounds —
exact at bucket boundaries, monotone in q, and merge is associative
(integer counts; sums associative up to float addition, tested with
integer-valued samples where it is exact).

The tracing properties run the REAL serve stack through the
deterministic simulation harness (`tests/simulation.py`, ManualClock):
every submitted request reaches exactly ONE terminal span, span
timestamps never decrease, and two identical runs produce byte-equal
traces.

The acceptance test proves the default `NullRecorder` path is
bit-exact: the same seeded traffic through a traced and an untraced
engine yields identical verdict sequences, identical results, and
``np.array_equal`` arena slabs.
"""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.obs import (DEFAULT_TIME_BUCKETS, Histogram, ManualClock,
                       MetricsRegistry, Observability, render_prometheus)
from repro.obs.trace import TERMINALS, FlightRecorder, TraceRecorder
from simulation import ServeSimulation

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False

needs_hyp = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis not installed")

BOUNDS = (1.0, 2.0, 5.0, 10.0)


# -- histogram percentile math ----------------------------------------

def test_histogram_bucket_boundary_exactness():
    """Samples ON bucket boundaries are recovered exactly by quantile():
    the sample lands in the bucket whose upper bound equals it."""
    h = Histogram(BOUNDS)
    for v in (1.0, 2.0, 5.0, 10.0):
        h.observe(v)
    assert h.quantile(0.25) == 1.0
    assert h.quantile(0.50) == 2.0
    assert h.quantile(0.75) == 5.0
    assert h.quantile(1.00) == 10.0


def test_histogram_empty_and_overflow():
    h = Histogram(BOUNDS)
    assert h.quantile(0.5) == 0.0             # empty -> 0.0
    h.observe(99.0)                           # beyond the largest bound
    assert h.quantile(0.5) == float("inf")    # overflow bucket -> inf
    assert h.counts[-1] == 1


def test_histogram_validation():
    with pytest.raises(ValueError):
        Histogram(())                         # no buckets
    with pytest.raises(ValueError):
        Histogram((1.0, 1.0))                 # not strictly increasing
    with pytest.raises(ValueError):
        Histogram((1.0, float("inf")))        # inf bound is implicit
    h = Histogram(BOUNDS)
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        h.merge(Histogram((1.0, 2.0)))        # different ladders


if HAVE_HYP:
    samples = st.lists(
        st.floats(min_value=0.0, max_value=20.0, allow_nan=False,
                  width=32),
        min_size=0, max_size=50)

    @needs_hyp
    @settings(max_examples=60, deadline=None)
    @given(samples)
    def test_histogram_quantiles_monotone(vals):
        """q1 <= q2 implies quantile(q1) <= quantile(q2), any sample set."""
        h = Histogram(BOUNDS)
        for v in vals:
            h.observe(v)
        qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0]
        got = [h.quantile(q) for q in qs]
        assert got == sorted(got)

    @needs_hyp
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.lists(st.integers(min_value=0, max_value=15),
                             max_size=20), min_size=3, max_size=3))
    def test_histogram_merge_associative(shards):
        """(a+b)+c == a+(b+c) exactly — integer-valued samples make the
        float sum associative too, so equality is bitwise."""
        hs = []
        for shard in shards:
            h = Histogram(BOUNDS)
            for v in shard:
                h.observe(float(v))
            hs.append(h)
        a, b, c = hs
        left, right = a.merge(b).merge(c), a.merge(b.merge(c))
        assert left.counts == right.counts
        assert left.sum == right.sum
        assert left.count == right.count
        for q in (0.5, 0.95, 0.99):
            assert left.quantile(q) == right.quantile(q)

    @needs_hyp
    @settings(max_examples=60, deadline=None)
    @given(samples)
    def test_histogram_merge_equals_single(vals):
        """Observing a stream into two shards then merging equals
        observing it all into one histogram (counts and quantiles)."""
        one = Histogram(BOUNDS)
        a, b = Histogram(BOUNDS), Histogram(BOUNDS)
        for i, v in enumerate(vals):
            one.observe(v)
            (a if i % 2 == 0 else b).observe(v)
        m = a.merge(b)
        assert m.counts == one.counts
        assert m.count == one.count
        for q in (0.5, 0.95, 0.99):
            assert m.quantile(q) == one.quantile(q)


# -- registry + export -------------------------------------------------

def test_registry_declare_idempotent_and_conflicts():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", "h", labels=("kind",))
    assert reg.counter("x_total", "h", labels=("kind",)) is c1
    with pytest.raises(ValueError):
        reg.gauge("x_total")                   # type conflict
    with pytest.raises(ValueError):
        reg.counter("x_total", labels=("other",))   # label conflict
    with pytest.raises(ValueError):
        reg.counter("bad name")                # invalid name
    with pytest.raises(ValueError):
        c1.labels(wrong="a")                   # undeclared label
    with pytest.raises(ValueError):
        c1.inc()                               # labelled family needs labels
    with pytest.raises(ValueError):
        c1.labels(kind="a").inc(-1)            # counters are monotonic


def test_snapshot_and_prometheus_roundtrip():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", labels=("kind",)).labels(
        kind="query").inc(3)
    reg.gauge("depth", "queue depth").set(7)
    h = reg.histogram("lat_seconds", "latency", buckets=BOUNDS)
    h.observe(1.0)
    h.observe(99.0)
    snap = reg.snapshot()
    json.dumps(snap)                           # JSON-serializable (inf ok)
    assert snap["req_total"]["values"][0] == {
        "labels": {"kind": "query"}, "value": 3}
    hv = snap["lat_seconds"]["values"][0]
    assert hv["count"] == 2 and hv["counts"][-1] == 1
    text = reg.to_prometheus()
    assert 'req_total{kind="query"} 3' in text
    assert "depth 7" in text
    # cumulative buckets + the implicit +Inf bucket equal to _count
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert "lat_seconds_count 2" in text
    # a saved snapshot re-renders to the identical exposition
    assert render_prometheus(snap) == text


def test_shard_labeled_histogram_merge_keeps_percentiles_exact():
    """The sharded serve engine keeps one histogram child per shard
    label; merging the per-shard children (`aggregate()`) must give the
    EXACT percentiles of a single unsharded histogram fed the same
    stream — counts are integers, so the merge is bitwise, not
    approximate."""
    reg = MetricsRegistry()
    fam = reg.histogram("lat_seconds", "latency", buckets=BOUNDS,
                        labels=("shard",))
    one = Histogram(BOUNDS)
    stream = [0.5, 1.0, 1.5, 2.0, 3.0, 5.0, 7.5, 10.0, 42.0, 0.1,
              2.5, 9.9, 1.0, 5.0]
    for i, v in enumerate(stream):
        fam.labels(shard=str(i % 4)).observe(v)    # round-robin placement
        one.observe(v)
    merged = fam.aggregate()
    assert merged.counts == one.counts
    assert merged.count == one.count
    assert merged.sum == one.sum
    for q in (0.0, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0):
        assert merged.quantile(q) == one.quantile(q)
    # the per-shard children render with their label and survive a
    # snapshot round-trip
    snap = reg.snapshot()
    assert len(snap["lat_seconds"]["values"]) == 4
    text = render_prometheus(snap)
    assert 'lat_seconds_bucket{shard="0",le="+Inf"}' in text


# -- clocks ------------------------------------------------------------

def test_manual_clock():
    c = ManualClock(5.0)
    assert c.now() == 5.0 and c.now() == 5.0   # stable between advances
    assert c.advance(2.5) == 7.5
    with pytest.raises(ValueError):
        c.advance(-1.0)


# -- flight recorder ---------------------------------------------------

def test_flight_recorder_bounded():
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.record(float(i), f"ev{i}")
    assert len(fr) == 4
    assert [e[1] for e in fr.events()] == ["ev6", "ev7", "ev8", "ev9"]
    assert fr.lines()[0].startswith("[t=6.000000] ev6")
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


# -- tracing through the simulated serve stack -------------------------

def _trace_events():
    return [
        ("submit", "a", "ingest", 4, 0, "t0"),
        ("submit", "b", "ingest", 8, 1, "t1"),
        ("submit", "a", "query", 4, 0, "t0"),
        ("run", 2),
        ("submit", "c", "ingest", 16, 0, "t0"),   # over the token bound
        ("submit", "b", "query", 2, 0, "t1"),
        ("offload", "a"),
        ("run", 5),
        ("close", "b"),
    ]


def _run_traced_sim(tiny_cfg):
    sim = ServeSimulation(tiny_cfg, n_slots=2, max_queued_tokens=12,
                          policy="block")
    sim.run_trace(_trace_events())
    sim.finish()
    return sim


def test_trace_conservation(tiny_cfg):
    """Every submitted request reaches exactly ONE terminal span; span
    timestamps are non-decreasing; nothing stays active at quiescence."""
    sim = _run_traced_sim(tiny_cfg)
    rec = sim.obs.recorder
    assert rec.active == []                    # quiescent: all terminal
    acc = sim.accounting()
    # cancelled backlog entries (close before pump) also get traces, so
    # completed >= submitted; every SUBMITTED request must have a trace
    assert len(rec.completed) >= len(acc.submitted)
    for req in acc.submitted:
        trace = rec.trace_of(req)
        assert trace is not None, f"no trace for {req.sid}/{req.kind}"
        terminals = [e for e in trace.events if e.name in TERMINALS]
        assert len(terminals) == 1, (
            f"{req.sid}: {[e.name for e in trace.events]}")
        ts = [e.ts for e in trace.events]
        assert ts == sorted(ts)
        assert trace.events[0].name == "submit"
        # outcome flags agree with the trace's terminal event
        expected = ("shed" if req.shed else
                    "cancelled" if req.cancelled else "finished")
        assert trace.terminal == expected


def test_trace_determinism(tiny_cfg):
    """Two identical simulated runs produce byte-identical traces (the
    ManualClock removes all host timing noise)."""
    def fingerprint(sim):
        return [(t.sid, t.kind, t.tenant,
                 tuple((e.name, e.ts) for e in t.events))
                for t in sim.obs.recorder.completed]
    a, b = _run_traced_sim(tiny_cfg), _run_traced_sim(tiny_cfg)
    fa, fb = fingerprint(a), fingerprint(b)
    assert fa == fb and fa                      # equal AND non-empty
    # the latency histograms are therefore identical too
    ha = a.engine.obs.registry.get("serve_queue_wait_seconds").aggregate()
    hb = b.engine.obs.registry.get("serve_queue_wait_seconds").aggregate()
    assert ha.counts == hb.counts and ha.sum == hb.sum


def test_queue_wait_measured_from_last_enqueue(tiny_cfg):
    """A pumped request's queue wait starts at the pump, not the submit
    (backlog time is backpressure, not scheduler queueing)."""
    sim = ServeSimulation(tiny_cfg, n_slots=2, max_queued_tokens=8,
                          policy="block")
    sim.apply(("submit", "a", "ingest", 8, 0, "t0"))   # fills the bound
    sim.apply(("submit", "b", "ingest", 8, 0, "t1"))   # backlogged
    sim.apply(("run", 10))                              # pops a, pumps b, pops b
    sim.finish()
    rec = sim.obs.recorder
    (trace_b,) = [t for t in rec.completed if t.sid == "b"]
    assert trace_b.ts_of("pumped") is not None
    wait = trace_b.ts_of("popped") - trace_b.ts_of("pumped")
    h = sim.engine.obs.registry.get(
        "serve_queue_wait_seconds").labels(kind="ingest")
    # b's observed wait must land in a bucket consistent with pump->pop,
    # not submit->pop; with the manual clock both pops happen in one
    # run event, so wait == 0.0 and lands in the first bucket
    assert wait == 0.0
    assert h.count == 2                                 # a and b


def test_admission_counters_monotonic_and_pump(tiny_cfg):
    """The pump no longer decrements 'admitted': every stats counter is
    monotonic across events, and pumped entries count under 'pumped'
    with 'admitted' covering DIRECT admissions only."""
    sim = ServeSimulation(tiny_cfg, n_slots=2, max_queued_tokens=8,
                          policy="block")
    sim.apply(("submit", "a", "ingest", 8, 0, "t0"))
    sim.apply(("submit", "b", "ingest", 8, 0, "t1"))
    sim.apply(("run", 10))
    sim.finish()
    st = sim.engine.admission.stats
    assert st == {"admitted": 1, "queued": 1, "shed_new": 0,
                  "shed_victims": 0, "pumped": 1}
    # monotone across the snapshot sequence, every key
    prev = None
    for snap in sim.snapshots:
        if prev is not None:
            for k, v in snap.admission_counters.items():
                assert v >= prev[k], (k, prev, snap.admission_counters)
        prev = snap.admission_counters


# -- engine integration (real model weights) ---------------------------

@pytest.fixture(scope="module")
def tiny_params(tiny_cfg):
    return T.init_lm(jax.random.PRNGKey(0), tiny_cfg)


def _drive(eng, cfg, seed=3):
    """Seeded traffic with offload churn; returns the verdict list."""
    rng = np.random.RandomState(seed)
    verdicts = []
    for s in range(4):
        eng.create_session(f"u{s}")
    for rnd in range(3):
        for s in range(4):
            ln = (3, 5)[rng.randint(2)]
            toks = rng.randint(0, cfg.vocab_size, size=ln).astype(np.int32)
            verdicts.append(eng.ingest(f"u{s}", toks,
                                       priority=int(rng.randint(2))))
        eng.run()
    for s in range(4):
        verdicts.append(eng.query(f"u{s}", np.arange(4, dtype=np.int32)))
    eng.run()
    return verdicts


def test_null_recorder_bit_exact(tiny_cfg, tiny_params):
    """ACCEPTANCE: an engine with the default NullRecorder produces
    bit-exact cache state and identical verdicts vs a recorder-enabled
    engine on the same seeded traffic."""
    from repro.serve import ServeEngine
    engs = [ServeEngine(tiny_params, tiny_cfg, n_slots=3, max_resident=2,
                        cache_len=32, batch_buckets=(1, 2, 4), obs=obs)
            for obs in (None, Observability.tracing())]
    outs = []
    for eng in engs:
        verdicts = _drive(eng, tiny_cfg)
        outs.append((
            [type(v).__name__ for v in verdicts],
            [None if v.request.result is None else np.asarray(v.request.result)
             for v in verdicts],
            jax.tree.leaves(eng._mgr["online"].arena.slabs),
        ))
    (v0, r0, s0), (v1, r1, s1) = outs
    assert v0 == v1                            # identical verdict sequence
    for a, b in zip(r0, r1):
        if a is None:
            assert b is None
        else:
            assert np.array_equal(a, b)        # bit-exact results
    for a, b in zip(s0, s1):
        assert np.array_equal(np.asarray(a), np.asarray(b))  # bit-exact slabs
    # and the traced engine actually traced
    assert engs[1].obs.recorder.completed
    assert engs[0].obs.recorder.flight_lines() == []


def test_compile_churn_counter_and_clamp(tiny_cfg, tiny_params):
    from repro.serve import ServeEngine
    eng = ServeEngine(tiny_params, tiny_cfg, n_slots=3, cache_len=32,
                      batch_buckets=(1, 2, 4))
    _drive(eng, tiny_cfg)
    fam = eng.obs.registry.get("serve_compiled_programs_total")
    seen = sum(int(child.value) for _, child in fam.children())
    assert seen == len(eng._seen_shapes) > 0
    # the sentinel clamp lives in compile_stats, nowhere else
    cs = eng.compile_stats()
    assert all(v >= -1 for v in cs.values())
    clamped = eng.compile_stats(clamped=True)
    assert all(v >= 0 for v in clamped.values())
    assert eng.compiled_programs() == sum(clamped.values())
    # stats compat view mirrors the registry counters
    st = eng.stats
    fam_req = eng.obs.registry.get("serve_requests_total")
    for kind in ("ingest", "query", "stream"):
        assert st[kind]["requests"] == int(
            fam_req.labels(kind=kind).value)


def test_metrics_snapshot_shape(tiny_cfg, tiny_params):
    from repro.serve import ServeEngine
    eng = ServeEngine(tiny_params, tiny_cfg, n_slots=3, max_resident=2,
                      cache_len=32, batch_buckets=(1, 2, 4),
                      obs=Observability.tracing())
    _drive(eng, tiny_cfg)
    snap = eng.metrics_snapshot()
    json.dumps(snap)                           # fully JSON-serializable
    m, d = snap["metrics"], snap["derived"]
    for fam in ("serve_requests_total", "serve_tokens_total",
                "admission_verdicts_total", "offload_bytes_total",
                "serve_arena_occupancy", "serve_queue_wait_seconds",
                "serve_e2e_latency_seconds",
                "serve_arena_consistency_errors_total"):
        assert fam in m, fam
    # the integrity probe ran and found nothing
    errs = m["serve_arena_consistency_errors_total"]["values"]
    assert all(v["value"] == 0 for v in errs)
    assert d["queue_depth"] == 0
    assert d["throughput_tok_per_s"] > 0
    assert set(d["admission"]) == {"admitted", "queued", "shed_new",
                                   "shed_victims", "pumped"}
    # prometheus export renders the same registry
    text = eng.metrics_prometheus()
    assert "serve_requests_total" in text and "serve_e2e_latency" in text


def test_flight_dump_on_error(tiny_cfg, capsys):
    """An exception escaping run() dumps the flight recorder to stderr
    (and is re-raised); the NullRecorder path dumps nothing."""
    def boom_factory(cfg, op, masked):
        def step(params, slabs, ids, toks, lens):
            raise RuntimeError("kaboom")
        return step

    from repro.serve import ServeEngine
    for traced in (True, False):
        obs = Observability.tracing(clock=ManualClock()) if traced else None
        eng = ServeEngine(None, tiny_cfg, n_slots=2, cache_len=32,
                          step_factory=boom_factory, obs=obs)
        eng.create_session("u")
        eng.ingest("u", np.arange(3, dtype=np.int32))
        with pytest.raises(RuntimeError, match="kaboom"):
            eng.run()
        err = capsys.readouterr().err
        if traced:
            assert "serve flight recorder" in err
            assert "kaboom" in err and "submit" in err
        else:
            assert err == ""


def test_trace_recorder_memory_bounded(tiny_cfg):
    """Completed traces are a ring: capacity stays bounded under
    sustained traffic (the completed-by-key map is pruned too)."""
    rec = TraceRecorder(clock=ManualClock(), registry=MetricsRegistry(),
                        keep_completed=8)

    class R:
        def __init__(self, i):
            self.sid, self.kind, self.tenant = f"s{i}", "ingest", "t"
            self.token_len = 1
    for i in range(100):
        r = R(i)
        rec.submit(r)
        rec.finished(r)
    assert len(rec.completed) == 8
    assert len(rec._completed_by_key) <= 16    # pruned at 2x maxlen


# -- timer lint --------------------------------------------------------

def test_no_stray_timers_lint(tmp_path):
    """The repo passes its own lint, and the lint actually catches an
    offender."""
    res = subprocess.run(
        [sys.executable, "scripts/check_no_stray_timers.py"],
        capture_output=True, text=True)
    assert res.returncode == 0, res.stdout + res.stderr
    bad = tmp_path / "src" / "repro" / "x"
    bad.mkdir(parents=True)
    (bad / "mod.py").write_text(
        "import time\nt0 = time.perf_counter()  # offender\n")
    res = subprocess.run(
        [sys.executable, "scripts/check_no_stray_timers.py",
         "--root", str(tmp_path)], capture_output=True, text=True)
    assert res.returncode == 1
    assert "mod.py:2" in res.stdout
