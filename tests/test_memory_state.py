"""MemState update/evict semantics + streaming invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import memory as MEM
from repro.core import streaming as ST
from repro.data.synthetic import lm_stream
from repro.models import transformer as T
from repro.models.config import CCMConfig, ModelConfig


def _cfg(mode="concat", **kw):
    return ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                       compute_dtype="float32",
                       ccm=CCMConfig(comp_len=2, max_steps=4, mode=mode, **kw))


def _h(key, cfg, B=2):
    L = MEM.mem_layers(cfg)
    return jax.random.normal(key, (L, B, cfg.ccm.comp_len, cfg.n_kv_heads,
                                   cfg.hd))


def test_concat_update_appends():
    cfg = _cfg()
    mem = MEM.init_memory(cfg, 2)
    hs = [_h(jax.random.PRNGKey(i), cfg) for i in range(3)]
    for i, h in enumerate(hs):
        mem = MEM.update_memory(cfg, mem, h, h, 10)
        assert int(mem.slots) == i + 1
        assert int(mem.stream_pos) == 10 * (i + 1)
    m = cfg.ccm.comp_len
    for i, h in enumerate(hs):
        np.testing.assert_allclose(
            np.asarray(mem.k[:, :, i * m:(i + 1) * m]), np.asarray(h),
            atol=1e-6)


def test_merge_update_is_arithmetic_mean():
    cfg = _cfg("merge")
    mem = MEM.init_memory(cfg, 2)
    hs = [_h(jax.random.PRNGKey(i), cfg) for i in range(4)]
    for h in hs:
        mem = MEM.update_memory(cfg, mem, h, h, 5)
    np.testing.assert_allclose(
        np.asarray(mem.k), np.asarray(sum(hs) / 4), atol=1e-5)
    assert int(mem.slots) == 1   # fixed-size memory


def test_merge_ema_update():
    cfg = _cfg("merge", merge_alpha=0.5)
    mem = MEM.init_memory(cfg, 1)
    h1, h2 = _h(jax.random.PRNGKey(0), cfg, 1), _h(jax.random.PRNGKey(1), cfg, 1)
    mem = MEM.update_memory(cfg, mem, h1, h1, 1)
    mem = MEM.update_memory(cfg, mem, h2, h2, 1)
    np.testing.assert_allclose(np.asarray(mem.k),
                               np.asarray(0.5 * h1 + 0.5 * h2), atol=1e-5)


def test_evict_oldest_rolls():
    cfg = _cfg()
    mem = MEM.init_memory(cfg, 1)
    hs = [_h(jax.random.PRNGKey(i), cfg, 1) for i in range(4)]
    for h in hs:
        mem = MEM.update_memory(cfg, mem, h, h, 1)
    mem = MEM.evict_oldest(mem, cfg.ccm.comp_len)
    assert int(mem.slots) == 3
    m = cfg.ccm.comp_len
    np.testing.assert_allclose(np.asarray(mem.k[:, :, :m]),
                               np.asarray(hs[1]), atol=1e-6)


def test_streaming_bounded_and_compressing():
    """KV budget stays bounded; memory fills and caps; both ccm and
    baseline modes run (paper Fig. 8 setting in miniature)."""
    cfg = _cfg().replace(ccm=CCMConfig(
        comp_len=2, max_steps=4, stream_window=32, stream_sink=2,
        stream_chunk=8, stream_mem_slots=4))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = lm_stream(jax.random.PRNGKey(1), 2, 192, 128)
    for ccm_on in (True, False):
        st = ST.init_stream_state(cfg, 2)
        for i in range(0, 192, 8):
            lg, st = ST.stream_step(params, cfg, st, toks[:, i:i + 8],
                                    ccm_on=ccm_on)
            assert int(st.win_len) <= 32
            assert not bool(jnp.isnan(lg).any())
        if ccm_on:
            assert int(st.mem.slots) == 4      # capped
            assert float(jnp.abs(st.mem.k).sum()) > 0
        else:
            assert int(st.mem.slots) == 0      # StreamingLLM baseline


def test_merge_mean_matches_over_t_steps_distinct_kv():
    """merge_alpha=None is the TRUE arithmetic mean over t steps — checked
    per-tensor with distinct k/v updates and t not a power of two."""
    cfg = _cfg("merge")
    mem = MEM.init_memory(cfg, 2)
    ks = [_h(jax.random.PRNGKey(i), cfg) for i in range(7)]
    vs = [_h(jax.random.PRNGKey(100 + i), cfg) for i in range(7)]
    for t, (hk, hv) in enumerate(zip(ks, vs), start=1):
        mem = MEM.update_memory(cfg, mem, hk, hv, 3)
        np.testing.assert_allclose(np.asarray(mem.k),
                                   np.asarray(sum(ks[:t]) / t), atol=1e-5)
        np.testing.assert_allclose(np.asarray(mem.v),
                                   np.asarray(sum(vs[:t]) / t), atol=1e-5)
        assert int(mem.steps) == t
    assert int(mem.stream_pos) == 21


def test_evict_oldest_preserves_survivor_order():
    """After eviction every surviving <COMP> group sits one slot earlier,
    in original order, for both k and v."""
    cfg = _cfg()
    mem = MEM.init_memory(cfg, 1)
    ks = [_h(jax.random.PRNGKey(i), cfg, 1) for i in range(4)]
    vs = [_h(jax.random.PRNGKey(50 + i), cfg, 1) for i in range(4)]
    for hk, hv in zip(ks, vs):
        mem = MEM.update_memory(cfg, mem, hk, hv, 1)
    m = cfg.ccm.comp_len
    mem = MEM.evict_oldest(mem, m)
    assert int(mem.slots) == 3
    for i, (hk, hv) in enumerate(zip(ks[1:], vs[1:])):
        np.testing.assert_allclose(
            np.asarray(mem.k[:, :, i * m:(i + 1) * m]), np.asarray(hk),
            atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(mem.v[:, :, i * m:(i + 1) * m]), np.asarray(hv),
            atol=1e-6)
    # a second eviction keeps shifting in order
    mem = MEM.evict_oldest(mem, m)
    assert int(mem.slots) == 2
    np.testing.assert_allclose(np.asarray(mem.k[:, :, :m]),
                               np.asarray(ks[2]), atol=1e-6)


def test_stream_step_rejects_oversized_chunk():
    """Regression: a chunk bigger than the eviction quantum used to
    overflow the window silently (one eviction per step + clamped
    dynamic_update_slice corrupting the newest KV rows)."""
    cfg = _cfg().replace(ccm=CCMConfig(
        comp_len=2, max_steps=4, stream_window=32, stream_sink=2,
        stream_chunk=8, stream_mem_slots=4))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    st = ST.init_stream_state(cfg, 1)
    toks = lm_stream(jax.random.PRNGKey(1), 1, 64, 128)
    with pytest.raises(ValueError, match="stream_chunk"):
        ST.stream_step(params, cfg, st, toks[:, :16])   # c=16 > cc=8
    # sink + stream_chunk must fit inside the window
    bad = cfg.replace(ccm=CCMConfig(comp_len=2, max_steps=4,
                                    stream_window=8, stream_sink=4,
                                    stream_chunk=6, stream_mem_slots=4))
    with pytest.raises(ValueError, match="stream_window"):
        ST.stream_step(params, bad, ST.init_stream_state(bad, 1),
                       toks[:, :4])
    # boundary case c == stream_chunk still runs
    lg, _ = ST.stream_step(params, cfg, st, toks[:, :8])
    assert not bool(jnp.isnan(lg).any())


def test_mem_layers_per_family():
    assert MEM.mem_layers(_cfg()) == 2
    hyb = ModelConfig(name="h", family="hybrid", n_layers=6, attn_every=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64, ssm_state=8, ssm_head_dim=8,
                      ccm=CCMConfig())
    assert MEM.mem_layers(hyb) == 3
    ssm = hyb.replace(family="ssm", attn_every=0)
    assert MEM.mem_layers(ssm) == 0
