"""Memory-pressure controller: recompression numerics + ladder +
serve-lifecycle regressions (PR 7).

Four clusters:

  1. `core.memory.recompress_memory` vs the `kernels/ref.py` oracle —
     including the COMMUTATION equality the lever's soundness rests on:
     recompressing a built memory at ratio r and attending over it is
     bit-identical (f32) to having compressed the original h(t) stream
     at the grouped ratio directly.
  2. `launch.serve.recompress_arena_slots` — masked-lane arena path:
     selected lanes shrink per the oracle, unselected lanes (and lanes
     with nothing to free) stay BIT-exact.
  3. The degradation ladder end-to-end on the deterministic simulation
     harness: controller-on sheds strictly less than levers-off at the
     same capacity, ladder order is recompress -> offload -> shed.
  4. Serve-lifecycle bugfix regressions: structured close (unknown sid,
     async-inflight buffers), policy-controlled recompute latch,
     async-offload bandwidth gauge, calibrated cost model.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.memory import init_memory, recompress_memory, update_memory
from repro.core.streaming import recompress_memory_lanes
from repro.kernels import ref
from repro.launch.serve import recompress_arena_slots
from repro.obs import ManualClock, Observability
from repro.serve import (CloseResult, OffloadCostModel, PressurePolicy,
                         SessionArena, SessionManager)
from repro.serve.pressure import MemoryPressureController

from simulation import ServeSimulation


def _rand_h(cfg, key, scale=1.0):
    shp = (2, 1, cfg.ccm.comp_len, cfg.n_kv_heads, cfg.hd)
    k1, k2 = jax.random.split(key)
    return (scale * jax.random.normal(k1, shp),
            scale * jax.random.normal(k2, shp))


def _build_mem(cfg, n_groups, key=None, dtype=jnp.float32):
    """Memory with ``n_groups`` filled groups from random h(t) states;
    returns (mem, [h_k...], [h_v...])."""
    key = key if key is not None else jax.random.PRNGKey(0)
    mem = init_memory(cfg, 1, dtype=dtype)
    hks, hvs = [], []
    for t in range(n_groups):
        key, sub = jax.random.split(key)
        hk, hv = _rand_h(cfg, sub)
        hks.append(hk)
        hvs.append(hv)
        mem = update_memory(cfg, mem, hk, hv, jnp.asarray(8, jnp.int32))
    return mem, hks, hvs


# -- 1. recompress_memory vs oracle ------------------------------------

@pytest.mark.parametrize("n_groups,group", [(2, 2), (3, 2), (4, 2),
                                            (3, 3), (4, 3)])
def test_recompress_matches_oracle(tiny_cfg, n_groups, group):
    cfg = tiny_cfg
    mem, _, _ = _build_mem(cfg, n_groups)
    rc = recompress_memory(cfg, mem, group)
    assert int(rc.slots) == -(-n_groups // group)
    want_k = ref.recompress_memory_ref(np.asarray(mem.k), n_groups,
                                       cfg.ccm.comp_len, group)
    want_v = ref.recompress_memory_ref(np.asarray(mem.v), n_groups,
                                       cfg.ccm.comp_len, group)
    # group=2 means are exact in f32 (halving is exact); odd groups
    # differ only in summation order
    tol = 0 if group == 2 else 1e-6
    np.testing.assert_allclose(np.asarray(rc.k), want_k, atol=tol)
    np.testing.assert_allclose(np.asarray(rc.v), want_v, atol=tol)
    # timeline counters untouched: representation changed, history didn't
    assert int(rc.steps) == int(mem.steps)
    assert int(rc.stream_pos) == int(mem.stream_pos)


def test_recompress_identity_cases(tiny_cfg):
    cfg = tiny_cfg
    mem, _, _ = _build_mem(cfg, 3)
    same = recompress_memory(cfg, mem, 1)          # group=1: no-op
    assert same is mem
    merge_cfg = dataclasses.replace(
        cfg, ccm=dataclasses.replace(cfg.ccm, mode="merge"))
    mmem = init_memory(merge_cfg, 1, dtype=jnp.float32)
    assert recompress_memory(merge_cfg, mmem, 2) is mmem
    with pytest.raises(ValueError):
        recompress_memory(cfg, mem, 0)


def test_recompress_then_attend_equals_direct_grouped(tiny_cfg):
    """THE soundness equality: recompress(mem(h1..h4), r=2) ==
    memory built from the grouped stream (mean(h1,h2), mean(h3,h4)) —
    bit-exact in f32 — and so is attending over either."""
    cfg = tiny_cfg
    m = cfg.ccm.comp_len
    mem, hks, hvs = _build_mem(cfg, 4)
    rc = recompress_memory(cfg, mem, 2)

    direct = init_memory(cfg, 1, dtype=jnp.float32)
    for i in range(0, 4, 2):
        hk = (hks[i] + hks[i + 1]) / 2
        hv = (hvs[i] + hvs[i + 1]) / 2
        direct = update_memory(cfg, direct, hk, hv,
                               jnp.asarray(16, jnp.int32))
    assert int(rc.slots) == int(direct.slots) == 2
    # a*0.5 + b*0.5 and (a+b)*0.5 both round once, to the same value;
    # invalid tail groups are zero on both sides (recompress zeroes, the
    # direct build never wrote them) — whole-array bit equality
    assert jnp.array_equal(rc.k, direct.k)
    assert jnp.array_equal(rc.v, direct.v)

    # and the attend: memory segment metadata (idx=-1 precedes
    # everything, comp=True crosses segments, valid = slots*m)
    M = mem.k.shape[2]
    Sq = 4
    q = jax.random.normal(jax.random.PRNGKey(7),
                          (1, cfg.n_heads, Sq, cfg.hd))
    valid = np.arange(M) < int(rc.slots) * m
    meta = dict(q_idx=jnp.arange(100, 100 + Sq, dtype=jnp.int32),
                q_seg=jnp.full((Sq,), 9, jnp.int32),
                k_idx=jnp.full((M,), -1, jnp.int32),
                k_seg=jnp.zeros((M,), jnp.int32),
                k_comp=jnp.ones((M,), bool),
                k_valid=jnp.asarray(valid))
    def attend(mm):
        # memory layout (B, M, Hkv, hd) -> ref layout (B, Hkv, Sk, D)
        k = jnp.transpose(mm.k[0], (0, 2, 1, 3))
        v = jnp.transpose(mm.v[0], (0, 2, 1, 3))
        return ref.ccm_attention_ref(q, k, v, scale=0.125, **meta)

    outs = [attend(mm) for mm in (rc, direct)]
    assert jnp.array_equal(outs[0], outs[1])


def test_recompress_bf16_close_to_f32_oracle(tiny_cfg):
    """Default-dtype (cfg.cdtype) memories recompress within one ulp of
    the f32 oracle — the arithmetic runs in f32 and rounds once."""
    cfg = tiny_cfg
    mem, _, _ = _build_mem(cfg, 3, dtype=cfg.cdtype)
    rc = recompress_memory(cfg, mem, 2)
    want = ref.recompress_memory_ref(np.asarray(mem.k, np.float32), 3,
                                     cfg.ccm.comp_len, 2)
    tol = 2e-2 if cfg.cdtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(rc.k, np.float32), want,
                               atol=tol)


# -- 2. masked arena lanes ---------------------------------------------

def test_recompress_arena_slots_masked_lanes(tiny_cfg):
    """Stacked-lane arena path: each selected lane shrinks per the
    per-lane oracle; lanes with nothing to free are bit-exact, and
    un-gathered rows never change."""
    cfg = tiny_cfg
    fills = [0, 1, 2, 3, 4]                # per-lane filled groups
    lanes = [_build_mem(cfg, n, key=jax.random.PRNGKey(10 + n))[0]
             for n in fills]
    n_rows = len(lanes) + 1                # + scratch row
    slabs = jax.tree.map(
        lambda *xs: jnp.stack(list(xs) + [jnp.zeros_like(xs[0])]), *lanes)
    before = jax.tree.map(np.asarray, slabs)
    ids = jnp.asarray([0, 1, 2, 3, 4], jnp.int32)   # gather all real rows
    out = recompress_arena_slots(slabs, ids, cfg=cfg, group=2)
    for i, n in enumerate(fills):
        row_k = np.asarray(jax.tree.map(lambda x: x[i], out).k)
        new_g = -(-n // 2)
        if new_g < n:                      # lane actually shrank
            want = ref.recompress_memory_ref(
                np.asarray(before.k[i]), n, cfg.ccm.comp_len, 2)
            np.testing.assert_allclose(row_k, want, atol=0)
            assert int(out.slots[i]) == new_g
        else:                              # nothing to free: BIT-exact
            np.testing.assert_array_equal(row_k, before.k[i])
            assert int(out.slots[i]) == n
    # scratch row untouched
    np.testing.assert_array_equal(np.asarray(out.k[n_rows - 1]),
                                  before.k[n_rows - 1])


def test_recompress_memory_lanes_reselects_unselected_bitexact(tiny_cfg):
    cfg = tiny_cfg
    lanes = [_build_mem(cfg, n, key=jax.random.PRNGKey(n))[0]
             for n in (4, 4, 3)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)
    do = jnp.asarray([True, False, True])
    out = recompress_memory_lanes(cfg, stacked, 2, do)
    assert [int(s) for s in out.slots] == [2, 4, 2]
    # the masked-out lane is re-selected wholesale, not recomputed
    np.testing.assert_array_equal(np.asarray(out.k[1]),
                                  np.asarray(stacked.k[1]))
    # nothing-selected batches skip behind the scalar cond
    none = recompress_memory_lanes(cfg, stacked,
                                   2, jnp.zeros((3,), bool))
    assert jnp.array_equal(none.k, stacked.k)


# -- 3. the ladder on the simulation harness ----------------------------

def _drive_pressure(cfg, policy):
    sim = ServeSimulation(cfg, n_slots=4, cache_len=32,
                          policy="shed-lowest-priority",
                          pressure_policy=policy)
    for sid in ("a", "b", "c"):
        sim.apply(("create", sid, "default"))
    for _ in range(8):
        for sid in ("a", "b", "c"):
            sim.apply(("submit", sid, "ingest", 8, 0, "default"))
        sim.apply(("run", 8))
    sim.finish()
    return sim


def test_ladder_beats_shedding_at_equal_capacity(tiny_cfg):
    on = _drive_pressure(tiny_cfg, PressurePolicy(capacity_tokens=26))
    off = _drive_pressure(tiny_cfg, PressurePolicy(
        capacity_tokens=26, enable_recompress=False, enable_offload=False))
    shed_on = sum(1 for r in on._submitted if r.shed)
    shed_off = sum(1 for r in off._submitted if r.shed)
    assert shed_on < shed_off, (shed_on, shed_off)
    levers = {lv: int(on.engine.pressure._m_decisions
                      .labels(lever=lv).value)
              for lv in ("recompress", "offload", "shed")}
    assert levers["recompress"] > 0
    # levers-off arm never recompressed or offloaded
    for lv in ("recompress", "offload"):
        assert int(off.engine.pressure._m_decisions
                   .labels(lever=lv).value) == 0


def test_ladder_monotonicity_in_decision_log(tiny_cfg):
    """No shed decision while a cheaper lever had candidates left."""
    sim = _drive_pressure(tiny_cfg, PressurePolicy(capacity_tokens=26))
    log = list(sim.engine.pressure.decisions)
    assert log, "pressure never fired — scenario lost its bite"
    for d in log:
        if d["lever"] == "shed":
            assert d["recompress_candidates"] == 0
            assert d["offload_candidates"] == 0
            assert d["unmet"] > 0


def test_recompress_lever_updates_session_and_metrics(tiny_cfg):
    sim = _drive_pressure(tiny_cfg, PressurePolicy(capacity_tokens=26))
    eng = sim.engine
    assert any(s.mem_groups < 4
               for s in eng._mgr["online"].sessions.values())
    freed = eng.pressure._m_freed.labels(lever="recompress").value
    assert freed > 0
    snap = eng.metrics_snapshot()["metrics"]
    assert "pressure_decisions_total" in snap
    assert "pressure_memory_used_tokens" in snap


def test_mem_groups_tracks_ingests_and_survives_offload(tiny_cfg):
    sim = ServeSimulation(tiny_cfg, n_slots=3, cache_len=32)
    eng = sim.engine
    sim.apply(("create", "a", "default"))
    for _ in range(3):
        sim.apply(("submit", "a", "ingest", 4, 0, "default"))
    sim.apply(("run", 8))
    sess = eng._mgr["online"].sessions["a"]
    assert sess.mem_groups == 3
    sim.apply(("offload", "a"))
    assert sess.mem_groups == 3              # host mirror rides along
    sim.apply(("submit", "a", "ingest", 4, 0, "default"))
    sim.apply(("run", 8))
    assert sess.mem_groups == 4              # restored + one more
    # capped at the arena's mem_slots (tiny_cfg: max_steps=4)
    sim.apply(("submit", "a", "ingest", 4, 0, "default"))
    sim.apply(("run", 8))
    assert sess.mem_groups == 4


def test_replay_recounts_mem_groups(tiny_cfg):
    """A recompute-dropped session rebuilds at the BASE ratio: group
    count = replayed ingests, not whatever recompression had shrunk."""
    sim = ServeSimulation(
        tiny_cfg, n_slots=3, cache_len=32,
        offload_cost_model=OffloadCostModel(host_bandwidth=1.0,
                                            replay_tokens_per_s=1e12))
    eng = sim.engine
    sim.apply(("create", "a", "default"))
    for _ in range(3):
        sim.apply(("submit", "a", "ingest", 4, 0, "default"))
    sim.apply(("run", 8))
    res = eng.offload_session("a")
    assert res.status == "recompute"
    sess = eng._mgr["online"].sessions["a"]
    sess.mem_groups = 1                      # pretend pressure shrank it
    sim.apply(("submit", "a", "ingest", 4, 0, "default"))
    sim.apply(("run", 8))                    # replays 3 ingests + runs 1
    assert sess.mem_groups == 4


# -- 4. lifecycle regressions ------------------------------------------

def _mk_mgr(cfg, **kw):
    arena = SessionArena.for_online(cfg, n_slots=3, cache_len=8,
                                    mem_slots=2)
    return SessionManager(arena, **kw)


def test_close_unknown_sid_is_structured_noop(tiny_cfg):
    mgr = _mk_mgr(tiny_cfg)
    res = mgr.close("ghost")
    assert isinstance(res, CloseResult)
    assert res.status == "unknown" and not res.closed
    mgr.create("a")
    mgr.activate("a")
    first = mgr.close("a")
    assert first.closed and first.was_resident
    assert mgr.close("a").status == "unknown"    # double close: no-op
    assert mgr.arena.n_free == 3                 # slot actually freed


def test_engine_close_unknown_sid(tiny_cfg):
    sim = ServeSimulation(tiny_cfg)
    res = sim.engine.close_session("ghost")
    assert res.status == "unknown"
    sim.apply(("create", "a", "default"))
    sim.apply(("submit", "a", "ingest", 4, 0, "default"))
    assert sim.engine.close_session("a").closed
    assert sim.engine.close_session("a").status == "unknown"
    # queued work was cancelled, side tables cleared
    assert not sim.engine.scheduler.queued(sid="a")
    assert "a" not in sim.engine._kind and "a" not in sim.engine._tenant


def test_close_drops_async_inflight_references(tiny_cfg):
    """Closing a session whose async offload is still in flight must
    drop its host references and leave sync() safe (it used to strand
    the buffer: the session dict entry kept the per-row view alive)."""
    mgr = _mk_mgr(tiny_cfg, async_offload=True)
    mgr.create("a")
    mgr.activate("a")
    res = mgr.offload("a")
    assert res.status == "offloaded" and len(mgr._inflight) == 1
    out = mgr.close("a")
    assert out.closed and not out.was_resident
    assert "a" not in mgr.sessions
    mgr.sync()                               # barrier still clean
    assert not mgr._inflight


def test_async_offload_sets_bandwidth_gauge(tiny_cfg):
    """Async transfers must feed the bandwidth gauge at the sync()
    barrier — they used to leave it at 0 (only measured=True sync
    offloads set it), blinding calibration exactly when async was on."""
    mgr = _mk_mgr(tiny_cfg, async_offload=True, batched_offload=True)
    for sid in ("a", "b"):
        mgr.create(sid)
    mgr.activate_batch(["a", "b"])
    mgr.offload_batch(["a", "b"])
    assert float(mgr._g_bw.value) == 0.0     # nothing measured yet
    mgr.sync()
    assert float(mgr._g_bw.value) > 0.0
    assert float(mgr._m_sync_s.value) > 0.0


def test_latch_history_policy(tiny_cfg):
    """latch_history=True drops history on a transfer-wins decision
    (old behavior, now opt-out); False keeps recording so a later rate
    change can still flip to recompute."""
    def one(latch):
        mgr = _mk_mgr(tiny_cfg,
                      cost_model=OffloadCostModel(host_bandwidth=1e15,
                                                  replay_tokens_per_s=1.0,
                                                  latch_history=latch),
                      replay_fn=lambda sid, slot, hist: None)
        mgr.create("a")
        mgr.activate("a")
        mgr.record("a", "ingest", np.zeros(4, np.int32))
        assert mgr.offload("a").status == "offloaded"   # transfer won
        return mgr.sessions["a"].history

    assert one(True) is None
    assert one(False) is not None


def test_calibrated_model_flips_latch_free_session_to_recompute(tiny_cfg):
    """Bandwidth degrading mid-run: with ``calibrated=True`` and the
    latch off, the decision tracks the measured gauge — transfer while
    the link is fast, recompute once it collapses.  With the (default)
    latch ON the first transfer-wins decision would have thrown the
    history away and pinned the session to the transfer path forever."""
    mgr = _mk_mgr(tiny_cfg,
                  cost_model=OffloadCostModel(host_bandwidth=1e15,
                                              replay_tokens_per_s=1.0,
                                              calibrated=True,
                                              latch_history=False),
                  replay_fn=lambda sid, slot, hist: None)
    mgr.create("a")
    mgr.record("a", "ingest", np.zeros(8, np.int32))
    mgr.activate("a")
    mgr._g_bw.set(1e15)                      # fast link measured
    assert mgr.offload("a").status == "offloaded"
    assert mgr.sessions["a"].history is not None
    mgr.activate("a")                        # restore
    mgr._g_bw.set(1.0)                       # link collapsed
    assert mgr.effective_cost_model().host_bandwidth == 1.0
    assert mgr.offload("a").status == "recompute"
    assert mgr.sessions["a"].needs_replay


def test_effective_cost_model_calibration_sources(tiny_cfg):
    base = OffloadCostModel(host_bandwidth=123.0, replay_tokens_per_s=7.0,
                            calibrated=True)
    mgr = _mk_mgr(tiny_cfg, cost_model=base)
    # no sensor data yet: operator constants pass through
    assert mgr.effective_cost_model() == base
    mgr._g_bw.set(5e8)
    mgr._m_replay_tokens.inc(1000)
    mgr._m_replay_s.inc(2.0)
    eff = mgr.effective_cost_model()
    assert eff.host_bandwidth == 5e8
    assert eff.replay_tokens_per_s == 500.0
    assert eff.calibrated and base.host_bandwidth == 123.0
    # uncalibrated models never substitute
    mgr2 = _mk_mgr(tiny_cfg,
                   cost_model=OffloadCostModel(host_bandwidth=123.0))
    mgr2._g_bw.set(5e8)
    assert mgr2.effective_cost_model().host_bandwidth == 123.0


def test_replay_seconds_counter_ticks(tiny_cfg):
    """The replay path books blocked seconds so calibration can derive
    an achieved tokens/s (new offload_replay_seconds_total family)."""
    sim = ServeSimulation(
        tiny_cfg, n_slots=3, cache_len=32,
        obs=Observability.tracing(clock=ManualClock()),
        offload_cost_model=OffloadCostModel(host_bandwidth=1.0,
                                            replay_tokens_per_s=1e12))
    eng = sim.engine
    sim.apply(("create", "a", "default"))
    sim.apply(("submit", "a", "ingest", 4, 0, "default"))
    sim.apply(("run", 8))
    assert eng.offload_session("a").status == "recompute"
    sim.apply(("submit", "a", "ingest", 4, 0, "default"))
    sim.apply(("run", 8))                    # triggers the replay
    mgr = eng._mgr["online"]
    assert int(mgr._m_replays.value) == 1
    # ManualClock never advances inside activate, so the counter exists
    # but stays 0 here; the live-clock property is covered by
    # test_async_offload_sets_bandwidth_gauge's real-clock pattern
    assert float(mgr._m_replay_s.value) >= 0.0
    assert "offload_replay_seconds_total" in eng.obs.registry.snapshot()


def test_controller_unit_ladder_with_synthetic_callbacks():
    """The controller is pure control plane: drive it with lambdas over
    a synthetic table (no engine, no device)."""
    table = {
        "old": dict(resident=True, last_used=1, mem_groups=4, kv=0),
        "new": dict(resident=True, last_used=2, mem_groups=4, kv=0),
    }

    class Row:
        def __init__(self, sid, d):
            self.sid, self.resident = sid, d["resident"]
            self.last_used, self.mem_groups = d["last_used"], d["mem_groups"]

    def recompress(sid):
        g = table[sid]["mem_groups"]
        ng = -(-g // 2)
        table[sid]["mem_groups"] = ng
        return (g - ng) * 2

    def offload(sid):
        table[sid]["resident"] = False
        return type("R", (), {"moved": True})()

    ctl = MemoryPressureController(
        PressurePolicy(capacity_tokens=100),
        sessions_fn=lambda: [Row(s, d) for s, d in table.items()],
        footprint_fn=lambda s: table[s]["mem_groups"] * 2 + table[s]["kv"],
        queued_tokens_fn=lambda: 0,
        has_queued_fn=lambda s: False,
        recompress_fn=recompress,
        offload_fn=offload)
    assert ctl.used_tokens() == 16
    # small deficit: one LRU recompression suffices, offload untouched
    assert ctl.relieve(3) == 4
    assert table["old"]["mem_groups"] == 2 and table["new"]["mem_groups"] == 4
    assert [d["lever"] for d in ctl.decisions] == ["recompress"]
    # big deficit: recompress to fixpoint (re-enumerated per round, so
    # "new" takes two steps: 4 -> 2 -> 1), then offload LRU-first, then
    # a shed handoff for the unmeetable remainder
    freed = ctl.relieve(1000)
    levers = [d["lever"] for d in ctl.decisions]
    assert levers == ["recompress",                       # first call
                      "recompress", "recompress", "recompress",
                      "offload", "offload", "shed"]
    assert freed == sum(d["freed"] for d in list(ctl.decisions)[1:-1])
    shed = list(ctl.decisions)[-1]
    assert shed["recompress_candidates"] == 0
    assert shed["offload_candidates"] == 0
