"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED config and runs one forward/train step on CPU,
asserting output shapes and no NaNs. Full configs are exercised only via
the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, get_config
from repro.core import masks as M
from repro.data.synthetic import sample_kv_batch
from repro.launch.specs import train_layout
from repro.models import transformer as T
from repro.optim.losses import next_token_loss
from repro.launch.train import trainable_mask_for
from repro.optim import partition as PT
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    layout = M.segment_layout(cfg.ccm.max_steps, 8, cfg.ccm.comp_len, 8)
    B = 2
    batch = sample_kv_batch(jax.random.PRNGKey(1), layout, B)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = jnp.zeros((B, 16, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        kw["patches"] = jnp.zeros((B, cfg.n_frontend_tokens, 1024),
                                  jnp.float32)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    logits = T.train_forward(params, cfg, batch["tokens"], layout, **kw)
    assert logits.shape == (B, layout.tail_len, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    # one full train step: loss finite, trainable params move
    trainable = trainable_mask_for(cfg, params)
    tp, fp = PT.partition(params, trainable)
    opt = init_adamw(tp)

    def loss_fn(tp_):
        lg = T.train_forward(PT.merge(tp_, fp), cfg, batch["tokens"],
                             layout, **kw)
        tail = batch["tokens"][:, layout.seq_len - layout.tail_len:]
        return next_token_loss(lg, tail, batch["loss_mask"])

    loss, grads = jax.value_and_grad(loss_fn)(tp)
    assert np.isfinite(float(loss))
    new_tp, _, metrics = adamw_update(AdamWConfig(), tp, grads, opt)
    assert np.isfinite(float(metrics["grad_norm"]))
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), tp, new_tp))
    assert any(moved), "no parameter moved after a step"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_online_inference(arch):
    """ingest -> prefill -> decode on the reduced config."""
    from repro.core import inference as I
    cfg = get_config(arch, smoke=True)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    B = 2
    state = I.init_online_state(cfg, B, max_cache_len=32)
    if cfg.family == "encdec":
        frames = jnp.zeros((B, 16, cfg.d_model), jnp.float32)
        state = state._replace(cross=I.encode_cross(params, cfg, frames))
    state = I.ingest_context(params, cfg, state,
                             jnp.ones((B, 8), jnp.int32))
    if cfg.ccm.enabled and cfg.family != "ssm":
        assert int(state.mem.slots) >= 1
    patches = jnp.zeros((B, cfg.n_frontend_tokens, 1024), jnp.float32) \
        if cfg.family == "vlm" else None
    lg, state = I.prefill(params, cfg, state, jnp.ones((B, 8), jnp.int32),
                          patches=patches)
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any())
    lg, state = I.decode_step(params, cfg, state,
                              jnp.ones((B, 1), jnp.int32))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg.astype(jnp.float32)).any())
