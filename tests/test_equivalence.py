"""THE paper-correctness property: the parallelized training forward
(Fig. 3) is an exact unroll of the recursive online process (Eq. 1-3).
Validated for concat & merge, dense & MoE & hybrid families, plus
SSD chunked-vs-sequential equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import inference as I
from repro.core import masks as M
from repro.data.synthetic import sample_kv_batch
from repro.models import ssm as SSM
from repro.models import transformer as T
from repro.models.config import CCMConfig, ModelConfig


def _roundtrip(cfg, layout, toks):
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    # perturb so LoRA deltas are non-trivial
    params = jax.tree.map(
        lambda p: p + 0.02 * jax.random.normal(jax.random.PRNGKey(7),
                                               p.shape, p.dtype)
        if p.dtype == jnp.float32 else p, params)
    lg_train = T.train_forward(params, cfg, toks, layout)
    state = I.init_online_state(cfg, toks.shape[0], max_cache_len=32)
    step = layout.chunk_len + layout.comp_len
    for j in range(layout.t_steps):
        chunk = toks[:, j * step:(j + 1) * step - layout.comp_len]
        state = I.ingest_context(params, cfg, state, chunk)
    tail = toks[:, layout.t_steps * step:]
    logits, state = I.prefill(params, cfg, state, tail)
    return (np.asarray(lg_train[:, -1]), np.asarray(logits[:, -1]))


@pytest.mark.parametrize("mode", ["concat", "merge"])
@pytest.mark.parametrize("family,extra", [
    ("dense", {}),
    ("moe", dict(n_experts=4, top_k=2)),
])
def test_parallel_equals_recursive(mode, family, extra):
    cfg = ModelConfig(name="t", family=family, n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      compute_dtype="float32",
                      ccm=CCMConfig(comp_len=2, max_steps=4, mode=mode),
                      **extra)
    layout = M.segment_layout(4, 8, 2, 8)
    toks = sample_kv_batch(jax.random.PRNGKey(1), layout, 2)["tokens"]
    a, b = _roundtrip(cfg, layout, toks)
    np.testing.assert_allclose(a, b, atol=2e-4)


def test_parallel_equals_recursive_hybrid():
    cfg = ModelConfig(name="h", family="hybrid", n_layers=5, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
                      ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
                      attn_every=2, compute_dtype="float32",
                      ccm=CCMConfig(comp_len=2, max_steps=4))
    layout = M.segment_layout(4, 8, 2, 8)
    toks = sample_kv_batch(jax.random.PRNGKey(1), layout, 2)["tokens"]
    # NOTE: hybrid train/inference differ by design: in parallel training the
    # SSM layers see the full packed sequence (incl. other segments' raw
    # tokens), online they see the actual stream. The equivalence therefore
    # holds only for the ATTENTION memory, checked structurally here: the
    # compression path runs and memory fills.
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    lg = T.train_forward(params, cfg, toks, layout)
    assert not bool(jnp.isnan(lg).any())
    state = I.init_online_state(cfg, 2, max_cache_len=32)
    state = I.ingest_context(params, cfg, state, toks[:, :8])
    assert int(state.mem.slots) == 1
    assert float(jnp.abs(state.mem.k).sum()) > 0


def test_merge_ema_variant_matches_recursion():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      compute_dtype="float32",
                      ccm=CCMConfig(comp_len=2, max_steps=4, mode="merge",
                                    merge_alpha=0.5))
    layout = M.segment_layout(4, 8, 2, 8)
    toks = sample_kv_batch(jax.random.PRNGKey(2), layout, 2)["tokens"]
    a, b = _roundtrip(cfg, layout, toks)
    np.testing.assert_allclose(a, b, atol=2e-4)


def test_ssd_chunked_equals_sequential():
    cfg = ModelConfig(name="s", family="ssm", n_layers=1, d_model=64,
                      vocab_size=128, ssm_state=16, ssm_head_dim=16,
                      ssm_chunk=16, compute_dtype="float32",
                      ccm=CCMConfig(enabled=False))
    p = SSM.init_mamba(jax.random.PRNGKey(3), cfg, 64)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 48, 64))
    y_par, st_par = SSM.apply_mamba(cfg, p, x, None, decode=False)
    y_seq, st_seq = SSM.apply_mamba(cfg, p, x, None, decode=True)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_par["ssm"]),
                               np.asarray(st_seq["ssm"]), atol=1e-4)


def test_ssd_state_carry():
    """Splitting a sequence across two calls == one call (state carry)."""
    cfg = ModelConfig(name="s", family="ssm", n_layers=1, d_model=32,
                      vocab_size=64, ssm_state=8, ssm_head_dim=8,
                      ssm_chunk=8, compute_dtype="float32",
                      ccm=CCMConfig(enabled=False))
    p = SSM.init_mamba(jax.random.PRNGKey(3), cfg, 32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, 32))
    y_full, _ = SSM.apply_mamba(cfg, p, x, None, decode=False)
    y1, st = SSM.apply_mamba(cfg, p, x[:, :16], None, decode=False)
    y2, _ = SSM.apply_mamba(cfg, p, x[:, 16:], st, decode=False)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.concatenate([y1, y2], axis=1), atol=1e-4)


def test_unroll_equals_scan():
    """cfg.unroll_layers (dry-run cost calibration) is semantics-preserving."""
    cfg = ModelConfig(name="t", family="dense", n_layers=3, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      compute_dtype="float32",
                      ccm=CCMConfig(comp_len=2, max_steps=4))
    layout = M.segment_layout(4, 8, 2, 8)
    toks = sample_kv_batch(jax.random.PRNGKey(1), layout, 2)["tokens"]
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    a = T.train_forward(params, cfg, toks, layout)
    b = T.train_forward(params, cfg.replace(unroll_layers=True, remat=False),
                        toks, layout)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
