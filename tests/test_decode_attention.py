"""Segmented attention subsystem: segmented-vs-dense equivalence across
layouts (mem only / mem+cache / mem+cache+self, ragged lanes, GQA), the
Pallas kernel vs the concat oracle, in-kernel int8 dequant vs the
full-dequant path, and the O(block) ragged window write."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import inference as I
from repro.core import masks as M
from repro.kernels import ops, ref
from repro.models import attention as A
from repro.models import transformer as T
from repro.models.config import CCMConfig, ModelConfig


def _cfg(Hq=4, Hkv=2, D=16, **kw):
    return ModelConfig(name="t", d_model=Hq * D, n_heads=Hq, n_kv_heads=Hkv,
                       head_dim=D, compute_dtype="float32", **kw)


def _kv(key, B, S, Hkv, D):
    return (jax.random.normal(key, (B, S, Hkv, D)),
            jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D)))


def _quantize(k, v):
    """Production int8 layout (the helper under test, not a re-impl)."""
    k8, ks = I.quantize_kv(k)
    v8, vs = I.quantize_kv(v)
    return k8, v8, ks, vs


def _self_info(Sq, valid=None):
    return A.KeyInfo(idx=jnp.arange(Sq, dtype=jnp.int32),
                     seg=jnp.ones((Sq,), jnp.int32),
                     comp=jnp.zeros((Sq,), bool), valid=valid)


# ---------------------------------------------------------------------------
# attend_segments (jnp online-softmax) == materialized-concat baseline
# ---------------------------------------------------------------------------

LAYOUTS = [
    # (Hq, Hkv, mem_S, mem_len, cache_S, cache_len, Sq)
    (4, 2, 0, 0, 0, 0, 9),          # self only
    (4, 2, 16, 10, 0, 0, 9),        # mem + self, partial mem
    (4, 2, 16, 16, 96, 40, 9),      # mem + cache + self (GQA)
    (8, 1, 16, 2, 100, 77, 5),      # MQA, unaligned cache length
    (4, 4, 16, 0, 64, 0, 7),        # MHA, everything empty but self
    (4, 2, 16, 16, 64, 64, 1),      # decode shape: 1-token q, full cache
]


@pytest.mark.parametrize("case", LAYOUTS)
def test_segmented_equals_concat(case):
    Hq, Hkv, mS, mL, cS, cL, Sq = case
    D = 16
    cfg = _cfg(Hq, Hkv, D).replace(attn_seg_block=32)
    key = jax.random.PRNGKey(sum(case))
    q = jax.random.normal(key, (2, Sq, Hq, D))
    segs = []
    if mS:
        mk, mv = _kv(jax.random.fold_in(key, 2), 2, mS, Hkv, D)
        segs.append(A.KVSegment(k=mk, v=mv, length=jnp.asarray(mL)))
    if cS:
        ck, cv = _kv(jax.random.fold_in(key, 3), 2, cS, Hkv, D)
        segs.append(A.KVSegment(k=ck, v=cv, length=jnp.asarray(cL)))
    sk, sv = _kv(jax.random.fold_in(key, 4), 2, Sq, Hkv, D)
    info = _self_info(Sq)
    segs.append(A.KVSegment(k=sk, v=sv, info=info))
    out = A.attend_segments(cfg, q, segs, info)
    want = A.attend_segments(cfg, q, segs, info, impl="concat")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_segmented_ragged_lane_and_layered():
    """Ragged self validity (mid-sequence hole, as ragged ingest produces)
    plus a stacked-layer cache segment read via KVSegment.layer."""
    Hq, Hkv, D, Sq, Lyr, cS = 4, 2, 16, 12, 3, 64
    cfg = _cfg(Hq, Hkv, D).replace(attn_seg_block=32)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, Sq, Hq, D))
    CK = jax.random.normal(jax.random.fold_in(key, 1), (Lyr, 2, cS, Hkv, D))
    CV = jax.random.normal(jax.random.fold_in(key, 2), (Lyr, 2, cS, Hkv, D))
    sk, sv = _kv(jax.random.fold_in(key, 3), 2, Sq, Hkv, D)
    valid = M.lane_valid(Sq, jnp.asarray(7), tail_start=10)  # hole [7, 10)
    info = _self_info(Sq, valid=valid)
    for li in (0, Lyr - 1):
        segs = [A.KVSegment(k=CK, v=CV, length=jnp.asarray(33),
                            layer=jnp.asarray(li)),
                A.KVSegment(k=sk, v=sv, info=info)]
        out = A.attend_segments(cfg, q, segs, info)
        want = A.attend_segments(cfg, q, segs, info, impl="concat")
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)


def test_segmented_large_q_chunked_path():
    """Sq beyond the q-chunk exercises the per-q-block scan (prefill)."""
    cfg = _cfg(4, 2, 16).replace(attn_chunk=16, attn_seg_block=32)
    key = jax.random.PRNGKey(5)
    Sq = 50
    q = jax.random.normal(key, (1, Sq, 4, 16))
    mk, mv = _kv(jax.random.fold_in(key, 1), 1, 24, 2, 16)
    sk, sv = _kv(jax.random.fold_in(key, 2), 1, Sq, 2, 16)
    info = _self_info(Sq)
    segs = [A.KVSegment(k=mk, v=mv, length=jnp.asarray(13)),
            A.KVSegment(k=sk, v=sv, info=info)]
    out = A.attend_segments(cfg, q, segs, info)
    want = A.attend_segments(cfg, q, segs, info, impl="concat")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# Pallas kernel (interpret) vs the concat oracle
# ---------------------------------------------------------------------------

def test_pallas_segmented_vs_ref():
    B, Hq, Hkv, D = 2, 4, 2, 32
    key = jax.random.PRNGKey(0)
    Sq, mS, cS = 40, 24, 100
    q = jax.random.normal(key, (B, Sq, Hq, D))
    mk, mv = _kv(jax.random.fold_in(key, 1), B, mS, Hkv, D)
    ck, cv = _kv(jax.random.fold_in(key, 2), B, cS, Hkv, D)
    ck8, cv8, ks, vs = _quantize(ck, cv)
    sk, sv = _kv(jax.random.fold_in(key, 3), B, Sq, Hkv, D)
    info = _self_info(Sq, valid=jnp.arange(Sq) < Sq - 3)
    none4 = dict(idx=None, seg=None, comp=None, valid=None)
    segs = [dict(k=mk, v=mv, k_scale=None, v_scale=None, layer=None,
                 length=jnp.asarray(17), **none4),
            dict(k=ck8, v=cv8, k_scale=ks, v_scale=vs, layer=None,
                 length=jnp.asarray(70), **none4),
            dict(k=sk, v=sv, k_scale=None, v_scale=None, layer=None,
                 length=None, idx=info.idx, seg=info.seg, comp=info.comp,
                 valid=info.valid)]
    out = ops.segmented_attention(q, segs, info.idx, info.seg,
                                  1 / np.sqrt(D), block_q=16, block_k=32,
                                  interpret=True)
    want = ref.segmented_attention_ref(q, segs, info.idx, info.seg,
                                       1 / np.sqrt(D))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_pallas_segmented_layered_cache():
    """Stacked-state segment: the kernel DMAs blocks of one layer via the
    scalar-prefetched layer id."""
    B, Hq, Hkv, D, Lyr, cS, Sq = 1, 4, 2, 32, 3, 64, 8
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (B, Sq, Hq, D))
    CK = jax.random.normal(jax.random.fold_in(key, 1), (Lyr, B, cS, Hkv, D))
    CV = jax.random.normal(jax.random.fold_in(key, 2), (Lyr, B, cS, Hkv, D))
    sk, sv = _kv(jax.random.fold_in(key, 3), B, Sq, Hkv, D)
    info = _self_info(Sq)
    none4 = dict(idx=None, seg=None, comp=None, valid=None)
    for li in (0, 2):
        segs = [dict(k=CK, v=CV, k_scale=None, v_scale=None,
                     layer=jnp.asarray(li), length=jnp.asarray(40), **none4),
                dict(k=sk, v=sv, k_scale=None, v_scale=None, layer=None,
                     length=None, idx=info.idx, seg=info.seg,
                     comp=info.comp, valid=info.valid)]
        out = ops.segmented_attention(q, segs, info.idx, info.seg,
                                      1 / np.sqrt(D), block_q=8, block_k=16,
                                      interpret=True)
        want = ref.segmented_attention_ref(q, segs, info.idx, info.seg,
                                           1 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)


def test_pallas_segmented_layered_quantized():
    """Layered AND int8-quantized — the exact segment the decode path
    emits with attn_impl='pallas' on an int8 cache (stacked scales are
    indexed by the prefetched layer id too)."""
    B, Hq, Hkv, D, Lyr, cS, Sq = 1, 4, 2, 32, 2, 48, 8
    key = jax.random.PRNGKey(3)
    CK = jax.random.normal(jax.random.fold_in(key, 1), (Lyr, B, cS, Hkv, D))
    CV = jax.random.normal(jax.random.fold_in(key, 2), (Lyr, B, cS, Hkv, D))
    ck8, cv8, ks, vs = _quantize(CK, CV)
    q = jax.random.normal(key, (B, Sq, Hq, D))
    sk, sv = _kv(jax.random.fold_in(key, 3), B, Sq, Hkv, D)
    info = _self_info(Sq)
    none4 = dict(idx=None, seg=None, comp=None, valid=None)
    for li in (0, 1):
        segs = [dict(k=ck8, v=cv8, k_scale=ks, v_scale=vs,
                     layer=jnp.asarray(li), length=jnp.asarray(30), **none4),
                dict(k=sk, v=sv, k_scale=None, v_scale=None, layer=None,
                     length=None, idx=info.idx, seg=info.seg,
                     comp=info.comp, valid=info.valid)]
        out = ops.segmented_attention(q, segs, info.idx, info.seg,
                                      1 / np.sqrt(D), block_q=8, block_k=16,
                                      interpret=True)
        want = ref.segmented_attention_ref(q, segs, info.idx, info.seg,
                                           1 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# int8 cache decode: tile-wise dequant == full-dequant concat path
# ---------------------------------------------------------------------------

def test_int8_decode_matches_full_dequant():
    cfg = ModelConfig(name="q8", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=128,
                      compute_dtype="float32", kv_cache_dtype="int8",
                      attn_seg_block=16,
                      ccm=CCMConfig(comp_len=2, max_steps=4))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, 128)
    state = I.init_online_state(cfg, 2, max_cache_len=48)
    _, state = I.prefill(params, cfg, state, toks)
    assert state.cache.quantized and int(state.cache.length) == 20
    lg, _ = I.decode_step(params, cfg, state, toks[:, :1])
    # 'concat' materializes the dequantized full cache before attending —
    # the pre-segmented int8 path
    lg_full, _ = I.decode_step(params, cfg, state, toks[:, :1],
                               impl="concat")
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_full),
                               atol=5e-5)


def test_decode_ignores_cache_capacity():
    """Same prefix in a small and a 4x larger cache decodes identically —
    the work (and the numerics) depend on length, not capacity."""
    cfg = ModelConfig(name="cap", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=128,
                      compute_dtype="float32", attn_seg_block=16,
                      ccm=CCMConfig(comp_len=2, max_steps=4))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, 128)
    outs = []
    for cap in (32, 128):
        st = I.init_online_state(cfg, 1, max_cache_len=cap)
        _, st = I.prefill(params, cfg, st, toks)
        lg, _ = I.decode_step(params, cfg, st, toks[:, :1])
        outs.append(np.asarray(lg))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# O(block) ragged window write == whole-buffer oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start,valid", [(0, 3), (5, 4), (13, 4), (14, 2),
                                         (10, 0)])
def test_ragged_window_write_matches_oracle(start, valid):
    buf = jnp.arange(16 * 3, dtype=jnp.float32).reshape(16, 3)
    blk = -jnp.ones((4, 3))
    got = M.ragged_block_write(buf, blk, jnp.asarray(start),
                               jnp.asarray(valid), axis=0)
    want = ref.ragged_block_write_ref(buf, blk, start, valid, axis=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ragged_window_write_layered():
    """The stacked-state form: only layer li's window changes."""
    buf = jnp.zeros((3, 2, 10, 4))
    blk = jnp.ones((1, 2, 4, 4))
    out = M.ragged_window_write(buf, blk, (1, 0, 6, 0), jnp.asarray(2),
                                axis=2)
    out = np.asarray(out)
    assert out[1, :, 6:8].all() and out[1, :, 8:].sum() == 0
    assert out[0].sum() == 0 and out[2].sum() == 0 and out[1, :, :6].sum() == 0
