"""Segmented attention subsystem: segmented-vs-dense equivalence across
layouts (mem only / mem+cache / mem+cache+self, ragged lanes, GQA), the
Pallas kernel vs the concat oracle, in-kernel int8 dequant vs the
full-dequant path, the O(block) ragged window write, and the
LANE-BATCHED route (per-lane tile skip under vmap: kernel vs the batched
oracle, custom_vmap vs per-lane loops, select-path equivalence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import inference as I
from repro.core import masks as M
from repro.kernels import ops, ref
from repro.models import attention as A
from repro.models import transformer as T
from repro.models.config import CCMConfig, ModelConfig


def _cfg(Hq=4, Hkv=2, D=16, **kw):
    return ModelConfig(name="t", d_model=Hq * D, n_heads=Hq, n_kv_heads=Hkv,
                       head_dim=D, compute_dtype="float32", **kw)


def _kv(key, B, S, Hkv, D):
    return (jax.random.normal(key, (B, S, Hkv, D)),
            jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D)))


def _quantize(k, v):
    """Production int8 layout (the helper under test, not a re-impl)."""
    k8, ks = I.quantize_kv(k)
    v8, vs = I.quantize_kv(v)
    return k8, v8, ks, vs


def _self_info(Sq, valid=None):
    return A.KeyInfo(idx=jnp.arange(Sq, dtype=jnp.int32),
                     seg=jnp.ones((Sq,), jnp.int32),
                     comp=jnp.zeros((Sq,), bool), valid=valid)


# ---------------------------------------------------------------------------
# attend_segments (jnp online-softmax) == materialized-concat baseline
# ---------------------------------------------------------------------------

LAYOUTS = [
    # (Hq, Hkv, mem_S, mem_len, cache_S, cache_len, Sq)
    (4, 2, 0, 0, 0, 0, 9),          # self only
    (4, 2, 16, 10, 0, 0, 9),        # mem + self, partial mem
    (4, 2, 16, 16, 96, 40, 9),      # mem + cache + self (GQA)
    (8, 1, 16, 2, 100, 77, 5),      # MQA, unaligned cache length
    (4, 4, 16, 0, 64, 0, 7),        # MHA, everything empty but self
    (4, 2, 16, 16, 64, 64, 1),      # decode shape: 1-token q, full cache
]


@pytest.mark.parametrize("case", LAYOUTS)
def test_segmented_equals_concat(case):
    Hq, Hkv, mS, mL, cS, cL, Sq = case
    D = 16
    cfg = _cfg(Hq, Hkv, D).replace(attn_seg_block=32)
    key = jax.random.PRNGKey(sum(case))
    q = jax.random.normal(key, (2, Sq, Hq, D))
    segs = []
    if mS:
        mk, mv = _kv(jax.random.fold_in(key, 2), 2, mS, Hkv, D)
        segs.append(A.KVSegment(k=mk, v=mv, length=jnp.asarray(mL)))
    if cS:
        ck, cv = _kv(jax.random.fold_in(key, 3), 2, cS, Hkv, D)
        segs.append(A.KVSegment(k=ck, v=cv, length=jnp.asarray(cL)))
    sk, sv = _kv(jax.random.fold_in(key, 4), 2, Sq, Hkv, D)
    info = _self_info(Sq)
    segs.append(A.KVSegment(k=sk, v=sv, info=info))
    out = A.attend_segments(cfg, q, segs, info)
    want = A.attend_segments(cfg, q, segs, info, impl="concat")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_segmented_ragged_lane_and_layered():
    """Ragged self validity (mid-sequence hole, as ragged ingest produces)
    plus a stacked-layer cache segment read via KVSegment.layer."""
    Hq, Hkv, D, Sq, Lyr, cS = 4, 2, 16, 12, 3, 64
    cfg = _cfg(Hq, Hkv, D).replace(attn_seg_block=32)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, Sq, Hq, D))
    CK = jax.random.normal(jax.random.fold_in(key, 1), (Lyr, 2, cS, Hkv, D))
    CV = jax.random.normal(jax.random.fold_in(key, 2), (Lyr, 2, cS, Hkv, D))
    sk, sv = _kv(jax.random.fold_in(key, 3), 2, Sq, Hkv, D)
    valid = M.lane_valid(Sq, jnp.asarray(7), tail_start=10)  # hole [7, 10)
    info = _self_info(Sq, valid=valid)
    for li in (0, Lyr - 1):
        segs = [A.KVSegment(k=CK, v=CV, length=jnp.asarray(33),
                            layer=jnp.asarray(li)),
                A.KVSegment(k=sk, v=sv, info=info)]
        out = A.attend_segments(cfg, q, segs, info)
        want = A.attend_segments(cfg, q, segs, info, impl="concat")
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)


def test_segmented_large_q_chunked_path():
    """Sq beyond the q-chunk exercises the per-q-block scan (prefill)."""
    cfg = _cfg(4, 2, 16).replace(attn_chunk=16, attn_seg_block=32)
    key = jax.random.PRNGKey(5)
    Sq = 50
    q = jax.random.normal(key, (1, Sq, 4, 16))
    mk, mv = _kv(jax.random.fold_in(key, 1), 1, 24, 2, 16)
    sk, sv = _kv(jax.random.fold_in(key, 2), 1, Sq, 2, 16)
    info = _self_info(Sq)
    segs = [A.KVSegment(k=mk, v=mv, length=jnp.asarray(13)),
            A.KVSegment(k=sk, v=sv, info=info)]
    out = A.attend_segments(cfg, q, segs, info)
    want = A.attend_segments(cfg, q, segs, info, impl="concat")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# Pallas kernel (interpret) vs the concat oracle
# ---------------------------------------------------------------------------

def test_pallas_segmented_vs_ref():
    B, Hq, Hkv, D = 2, 4, 2, 32
    key = jax.random.PRNGKey(0)
    Sq, mS, cS = 40, 24, 100
    q = jax.random.normal(key, (B, Sq, Hq, D))
    mk, mv = _kv(jax.random.fold_in(key, 1), B, mS, Hkv, D)
    ck, cv = _kv(jax.random.fold_in(key, 2), B, cS, Hkv, D)
    ck8, cv8, ks, vs = _quantize(ck, cv)
    sk, sv = _kv(jax.random.fold_in(key, 3), B, Sq, Hkv, D)
    info = _self_info(Sq, valid=jnp.arange(Sq) < Sq - 3)
    none4 = dict(idx=None, seg=None, comp=None, valid=None)
    segs = [dict(k=mk, v=mv, k_scale=None, v_scale=None, layer=None,
                 length=jnp.asarray(17), **none4),
            dict(k=ck8, v=cv8, k_scale=ks, v_scale=vs, layer=None,
                 length=jnp.asarray(70), **none4),
            dict(k=sk, v=sv, k_scale=None, v_scale=None, layer=None,
                 length=None, idx=info.idx, seg=info.seg, comp=info.comp,
                 valid=info.valid)]
    out = ops.segmented_attention(q, segs, info.idx, info.seg,
                                  1 / np.sqrt(D), block_q=16, block_k=32,
                                  interpret=True)
    want = ref.segmented_attention_ref(q, segs, info.idx, info.seg,
                                       1 / np.sqrt(D))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_pallas_segmented_layered_cache():
    """Stacked-state segment: the kernel DMAs blocks of one layer via the
    scalar-prefetched layer id."""
    B, Hq, Hkv, D, Lyr, cS, Sq = 1, 4, 2, 32, 3, 64, 8
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (B, Sq, Hq, D))
    CK = jax.random.normal(jax.random.fold_in(key, 1), (Lyr, B, cS, Hkv, D))
    CV = jax.random.normal(jax.random.fold_in(key, 2), (Lyr, B, cS, Hkv, D))
    sk, sv = _kv(jax.random.fold_in(key, 3), B, Sq, Hkv, D)
    info = _self_info(Sq)
    none4 = dict(idx=None, seg=None, comp=None, valid=None)
    for li in (0, 2):
        segs = [dict(k=CK, v=CV, k_scale=None, v_scale=None,
                     layer=jnp.asarray(li), length=jnp.asarray(40), **none4),
                dict(k=sk, v=sv, k_scale=None, v_scale=None, layer=None,
                     length=None, idx=info.idx, seg=info.seg,
                     comp=info.comp, valid=info.valid)]
        out = ops.segmented_attention(q, segs, info.idx, info.seg,
                                      1 / np.sqrt(D), block_q=8, block_k=16,
                                      interpret=True)
        want = ref.segmented_attention_ref(q, segs, info.idx, info.seg,
                                           1 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)


def test_pallas_segmented_layered_quantized():
    """Layered AND int8-quantized — the exact segment the decode path
    emits with attn_impl='pallas' on an int8 cache (stacked scales are
    indexed by the prefetched layer id too)."""
    B, Hq, Hkv, D, Lyr, cS, Sq = 1, 4, 2, 32, 2, 48, 8
    key = jax.random.PRNGKey(3)
    CK = jax.random.normal(jax.random.fold_in(key, 1), (Lyr, B, cS, Hkv, D))
    CV = jax.random.normal(jax.random.fold_in(key, 2), (Lyr, B, cS, Hkv, D))
    ck8, cv8, ks, vs = _quantize(CK, CV)
    q = jax.random.normal(key, (B, Sq, Hq, D))
    sk, sv = _kv(jax.random.fold_in(key, 3), B, Sq, Hkv, D)
    info = _self_info(Sq)
    none4 = dict(idx=None, seg=None, comp=None, valid=None)
    for li in (0, 1):
        segs = [dict(k=ck8, v=cv8, k_scale=ks, v_scale=vs,
                     layer=jnp.asarray(li), length=jnp.asarray(30), **none4),
                dict(k=sk, v=sv, k_scale=None, v_scale=None, layer=None,
                     length=None, idx=info.idx, seg=info.seg,
                     comp=info.comp, valid=info.valid)]
        out = ops.segmented_attention(q, segs, info.idx, info.seg,
                                      1 / np.sqrt(D), block_q=8, block_k=16,
                                      interpret=True)
        want = ref.segmented_attention_ref(q, segs, info.idx, info.seg,
                                           1 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# int8 cache decode: tile-wise dequant == full-dequant concat path
# ---------------------------------------------------------------------------

def test_int8_decode_matches_full_dequant():
    cfg = ModelConfig(name="q8", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=128,
                      compute_dtype="float32", kv_cache_dtype="int8",
                      attn_seg_block=16,
                      ccm=CCMConfig(comp_len=2, max_steps=4))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, 128)
    state = I.init_online_state(cfg, 2, max_cache_len=48)
    _, state = I.prefill(params, cfg, state, toks)
    assert state.cache.quantized and int(state.cache.length) == 20
    lg, _ = I.decode_step(params, cfg, state, toks[:, :1])
    # 'concat' materializes the dequantized full cache before attending —
    # the pre-segmented int8 path
    lg_full, _ = I.decode_step(params, cfg, state, toks[:, :1],
                               impl="concat")
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_full),
                               atol=5e-5)


def test_decode_ignores_cache_capacity():
    """Same prefix in a small and a 4x larger cache decodes identically —
    the work (and the numerics) depend on length, not capacity."""
    cfg = ModelConfig(name="cap", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=128,
                      compute_dtype="float32", attn_seg_block=16,
                      ccm=CCMConfig(comp_len=2, max_steps=4))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, 128)
    outs = []
    for cap in (32, 128):
        st = I.init_online_state(cfg, 1, max_cache_len=cap)
        _, st = I.prefill(params, cfg, st, toks)
        lg, _ = I.decode_step(params, cfg, st, toks[:, :1])
        outs.append(np.asarray(lg))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# lane-batched route: per-lane tile skip under vmap
# ---------------------------------------------------------------------------


def _lane_case(key, N=3, Sq=8, Hq=4, Hkv=2, D=32, Lyr=3, Smax=64,
               quant=False):
    """Mixed-occupancy serve-style lane batch: per-lane memory lengths,
    a per-lane (lane-major) stacked cache at per-lane layers, and a
    ragged self segment."""
    q = jax.random.normal(key, (N, Sq, Hq, D))
    CK = jax.random.normal(jax.random.fold_in(key, 1), (N, Lyr, Smax, Hkv, D))
    CV = jax.random.normal(jax.random.fold_in(key, 2), (N, Lyr, Smax, Hkv, D))
    sk = jax.random.normal(jax.random.fold_in(key, 3), (N, Sq, Hkv, D))
    sv = jax.random.normal(jax.random.fold_in(key, 4), (N, Sq, Hkv, D))
    mk = jax.random.normal(jax.random.fold_in(key, 5), (N, 16, Hkv, D))
    mv = jax.random.normal(jax.random.fold_in(key, 6), (N, 16, Hkv, D))
    lens = jnp.array([5, 33, 0], jnp.int32)[:N]
    mlens = jnp.array([16, 4, 7], jnp.int32)[:N]
    layers = jnp.array([0, Lyr - 1, 1], jnp.int32)[:N]
    valid = jnp.arange(Sq)[None] < jnp.array([Sq, 5, 2])[:N, None]
    info = _self_info(Sq)
    cache = dict(k=CK, v=CV, k_scale=None, v_scale=None, layer=layers,
                 lane_major=True, length=lens,
                 idx=None, seg=None, comp=None, valid=None)
    if quant:
        ck8, cv8, ks, vs = _quantize(CK, CV)
        cache.update(k=ck8, v=cv8, k_scale=ks, v_scale=vs)
    segs = [dict(k=mk, v=mv, k_scale=None, v_scale=None, layer=None,
                 length=mlens, idx=None, seg=None, comp=None, valid=None),
            cache,
            dict(k=sk, v=sv, k_scale=None, v_scale=None, layer=None,
                 length=None, idx=info.idx, seg=info.seg, comp=info.comp,
                 valid=valid)]
    return q, segs, info


@pytest.mark.parametrize("quant", [False, True])
def test_lane_kernel_vs_batched_oracle(quant):
    """Lane grid axis + 2-D scalar prefetch: mixed per-lane lengths,
    per-lane layer ids into a lane-major stacked cache, per-lane ragged
    self validity, GQA — fp32 and int8 — against the per-lane oracle."""
    q, segs, info = _lane_case(jax.random.PRNGKey(11), quant=quant)
    D = q.shape[-1]
    out = ops.segmented_attention(q, segs, info.idx, info.seg,
                                  1 / np.sqrt(D), block_q=8, block_k=16,
                                  interpret=True)
    want = ref.segmented_attention_lanes_ref(q, segs, info.idx, info.seg,
                                             1 / np.sqrt(D))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_lane_online_vs_batched_oracle():
    """The jnp lane path (_attend_segments_lanes_online) against the
    per-lane oracle on the same mixed-occupancy batch."""
    from repro.models.attention import _attend_segments_lanes_online
    q, segs, info = _lane_case(jax.random.PRNGKey(13))
    D = q.shape[-1]
    cfg = _cfg(4, 2, D).replace(attn_seg_block=16)
    # the jnp path takes a lane-shared layer (serve: same layer for all
    # lanes inside the scanned body) and boolean metadata
    for s in segs:
        if s.get("layer") is not None:
            s["layer"] = jnp.asarray(1, jnp.int32)
        for key in ("comp", "valid"):
            if s.get(key) is not None:
                s[key] = jnp.broadcast_to(jnp.asarray(s[key], bool),
                                          (q.shape[0], s["k"].shape[1]))
        for key in ("idx", "seg"):
            if s.get(key) is not None:
                s[key] = jnp.broadcast_to(jnp.asarray(s[key], jnp.int32),
                                          (q.shape[0], s["k"].shape[1]))
    qidx = jnp.broadcast_to(info.idx, (q.shape[0], q.shape[1]))
    qseg = jnp.broadcast_to(info.seg, (q.shape[0], q.shape[1]))
    out = _attend_segments_lanes_online(cfg, q, segs, qidx, qseg,
                                        1 / np.sqrt(D))
    want = ref.segmented_attention_lanes_ref(q, segs, qidx, qseg,
                                             1 / np.sqrt(D))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_attend_segments_vmap_routes_to_lanes():
    """attend_segments under jax.vmap (the serve session axis): the
    custom_vmap rule must (a) match running every lane unbatched, (b)
    match the legacy select-lowered path, and (c) keep the tile skip a
    real `cond` in the lowered jaxpr."""
    Hq, Hkv, D, Lyr, Smax, N = 4, 2, 16, 3, 96, 4
    cfg = _cfg(Hq, Hkv, D).replace(attn_seg_block=16)
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (N, 1, 1, Hq, D))
    CK = jax.random.normal(jax.random.fold_in(key, 1),
                           (N, Lyr, 1, Smax, Hkv, D))
    CV = jax.random.normal(jax.random.fold_in(key, 2),
                           (N, Lyr, 1, Smax, Hkv, D))
    sk = jax.random.normal(jax.random.fold_in(key, 3), (N, 1, 1, Hkv, D))
    sv = jax.random.normal(jax.random.fold_in(key, 4), (N, 1, 1, Hkv, D))
    lens = jnp.array([7, 45, 0, 96], jnp.int32)
    li = jnp.asarray(1, jnp.int32)
    info = A.KeyInfo(idx=jnp.full((1,), 2 ** 30, jnp.int32),
                     seg=jnp.ones((1,), jnp.int32),
                     comp=jnp.zeros((1,), bool))

    def one(cfg_, q, ck, cv, sk, sv, ln):
        segs = [A.KVSegment(k=ck, v=cv, length=ln, layer=li),
                A.KVSegment(k=sk, v=sv, info=info)]
        return A.attend_segments(cfg_, q, segs, info)

    import functools
    lane = jax.vmap(functools.partial(one, cfg))
    got = lane(q, CK, CV, sk, sv, lens)
    want = jnp.stack([one(cfg, q[i], CK[i], CV[i], sk[i], sv[i], lens[i])
                      for i in range(N)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    legacy = jax.vmap(functools.partial(
        one, cfg.replace(attn_lane_batched=False)))(q, CK, CV, sk, sv, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(legacy),
                               atol=1e-6)
    jp = str(jax.make_jaxpr(lane)(q, CK, CV, sk, sv, lens))
    assert "cond[" in jp   # tile skip survived the vmap as a real branch


def test_decode_vmap_lane_capacity_invariance():
    """End-to-end: vmapped decode_step over stacked per-lane states is
    numerically identical across cache capacities AND to per-lane decode
    (the lane route changes scheduling, never values)."""
    cfg = ModelConfig(name="lane", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=128,
                      compute_dtype="float32", attn_seg_block=16,
                      ccm=CCMConfig(comp_len=2, max_steps=4))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 20), 0, 128)
    prefix = [4, 12, 20]
    outs = []
    for cap in (32, 128):
        lanes = []
        for i, n in enumerate(prefix):
            st = I.init_online_state(cfg, 1, max_cache_len=cap)
            _, st = I.prefill(params, cfg, st, toks[i:i + 1, :n])
            lanes.append(st)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)
        lg, _ = jax.vmap(lambda s, t: I.decode_step(params, cfg, s, t))(
            stacked, toks[:, :1, None])
        outs.append(np.asarray(lg))
        if cap == 32:
            for i in range(3):
                lg1, _ = I.decode_step(params, cfg, lanes[i], toks[i:i+1, :1])
                np.testing.assert_allclose(outs[0][i], np.asarray(lg1),
                                           atol=1e-6)
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# O(block) ragged window write == whole-buffer oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start,valid", [(0, 3), (5, 4), (13, 4), (14, 2),
                                         (10, 0)])
def test_ragged_window_write_matches_oracle(start, valid):
    buf = jnp.arange(16 * 3, dtype=jnp.float32).reshape(16, 3)
    blk = -jnp.ones((4, 3))
    got = M.ragged_block_write(buf, blk, jnp.asarray(start),
                               jnp.asarray(valid), axis=0)
    want = ref.ragged_block_write_ref(buf, blk, start, valid, axis=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ragged_window_write_layered():
    """The stacked-state form: only layer li's window changes."""
    buf = jnp.zeros((3, 2, 10, 4))
    blk = jnp.ones((1, 2, 4, 4))
    out = M.ragged_window_write(buf, blk, (1, 0, 6, 0), jnp.asarray(2),
                                axis=2)
    out = np.asarray(out)
    assert out[1, :, 6:8].all() and out[1, :, 8:].sum() == 0
    assert out[0].sum() == 0 and out[2].sum() == 0 and out[1, :, :6].sum() == 0
