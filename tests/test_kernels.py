"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs the
ref.py pure-jnp oracles (interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import masks as M
from repro.kernels import ops, ref
from repro.models.attention import KeyInfo


def _ccm_meta(key, Sq, Sk, mem_len, seg_len):
    """Random CCM-shaped metadata: [mem prefix | segmented stream]."""
    n = Sk - mem_len
    seg = (jnp.arange(n) // seg_len + 1).astype(jnp.int32)
    comp = (jnp.arange(n) % seg_len) >= (seg_len - 2)
    ki = KeyInfo(
        idx=jnp.concatenate([jnp.full((mem_len,), -1, jnp.int32),
                             jnp.arange(n, dtype=jnp.int32)]),
        seg=jnp.concatenate([jnp.zeros(mem_len, jnp.int32), seg]),
        comp=jnp.concatenate([jnp.ones(mem_len, bool), comp]),
        valid=jnp.concatenate([jnp.arange(mem_len) < mem_len - 1,
                               jnp.ones(n, bool)]))
    qi = KeyInfo(idx=jnp.arange(Sq, dtype=jnp.int32) + (n - Sq),
                 seg=seg[-Sq:], comp=comp[-Sq:])
    return qi, ki


ATTN_CASES = [
    # (B, Hq, Hkv, Sq, Sk, D, dtype, bq, bk)
    (1, 2, 1, 64, 64, 32, jnp.float32, 32, 32),
    (2, 4, 2, 80, 112, 64, jnp.float32, 32, 32),   # GQA + padding
    (1, 8, 1, 128, 160, 32, jnp.float32, 64, 32),  # MQA
    (2, 2, 2, 96, 96, 16, jnp.bfloat16, 32, 64),   # bf16
    (1, 3, 3, 40, 72, 8, jnp.float32, 16, 16),     # odd heads, tiny D
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_ccm_attention_vs_ref(case):
    B, Hq, Hkv, Sq, Sk, D, dt, bq, bk = case
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, Sq, Hq, D), dt)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sk, Hkv, D), dt)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, Hkv, D), dt)
    qi, ki = _ccm_meta(key, Sq, Sk, mem_len=Sk - Sq, seg_len=16)
    scale = 1.0 / np.sqrt(D)
    out = ops.ccm_attention(q, k, v, qi, ki, scale, block_q=bq, block_k=bk,
                            interpret=True)
    want = ref.ccm_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), qi.idx, qi.seg, ki.idx, ki.seg, ki.comp,
        ki.valid, scale).transpose(0, 2, 1, 3)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_ccm_attention_matches_model_chunked():
    """Kernel == the model's chunked online-softmax path on a real layout."""
    from repro.models import attention as A
    lo = M.segment_layout(4, 12, 2, 8)
    S = lo.seq_len
    q = jax.random.normal(jax.random.PRNGKey(0), (2, S, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, S, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, S, 2, 32))
    info = A.KeyInfo(idx=jnp.arange(S, dtype=jnp.int32), seg=lo.seg_ids,
                     comp=lo.comp_mask)
    scale = 1 / np.sqrt(32)
    out_k = ops.ccm_attention(q, k, v, info, info, scale, 32, 32,
                              interpret=True)
    out_c = A.attend_chunked(q, k, v, info, info, scale, 16, 16)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_c),
                               atol=3e-5)


LORA_CASES = [
    (64, 128, 64, 4, jnp.float32, 32, 32, 64),
    (100, 200, 60, 8, jnp.float32, 32, 32, 64),   # padding everywhere
    (128, 256, 128, 16, jnp.bfloat16, 64, 64, 128),
]


@pytest.mark.parametrize("case", LORA_CASES)
def test_cond_lora_vs_ref(case):
    Mm, K, N, r, dt, bm, bn, bk = case
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (Mm, K), dt)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (K, N), dt)
         / np.sqrt(K)).astype(dt)
    a = (jax.random.normal(jax.random.fold_in(key, 2), (r, K), dt)
         / np.sqrt(K)).astype(dt)
    b = jax.random.normal(jax.random.fold_in(key, 3), (r, N), dt)
    g = (jax.random.uniform(jax.random.fold_in(key, 4), (Mm,)) > 0.5
         ).astype(dt)
    out = ops.cond_lora(x, w, a, b, g, 2.0, bm, bn, bk, interpret=True)
    want = ref.cond_lora_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                             a.astype(jnp.float32), b.astype(jnp.float32),
                             g.astype(jnp.float32), 2.0)
    tol = 1e-1 if dt == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=tol, rtol=1e-2)


def test_cond_lora_gate_zero_is_base_matmul():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) / 8
    a = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    b = jax.random.normal(jax.random.PRNGKey(3), (4, 32))
    out = ops.cond_lora(x, w, a, b, jnp.zeros(32), 2.0, 32, 32, 64,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w), atol=1e-4)


@given(st.integers(2, 6), st.integers(1, 4), st.sampled_from([1, 2, 3]))
@settings(max_examples=10, deadline=None)
def test_kv_merge_matches_running_mean(t_total, rows, cols_pow):
    cols = 8 * cols_pow
    key = jax.random.PRNGKey(t_total)
    hs = jax.random.normal(key, (t_total, rows, cols))
    mem = jnp.zeros((rows, cols))
    for t in range(1, t_total + 1):
        mem = ops.kv_merge_update(mem, hs[t - 1], 1.0 / t, interpret=True)
    np.testing.assert_allclose(np.asarray(mem),
                               np.asarray(hs.mean(axis=0)), atol=1e-5)


def test_kv_cummean_vs_ref():
    h = jax.random.normal(jax.random.PRNGKey(0), (6, 4, 8, 16))
    out = ops.kv_cummean(h, interpret=True)
    want = ref.kv_cummean_ref(h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
