"""Multi-device tests (pjit shardings, MoE EP/TP, grad compression,
elastic restore). Each runs in a SUBPROCESS with
--xla_force_host_platform_device_count so the main pytest process keeps a
single device (assignment: never set the flag globally)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# jax < 0.6 (no jax.shard_map) runs shard_map through the legacy
# experimental API; two cases hit version-specific limits there (see
# ROADMAP "jax-version compat")
OLD_JAX = not hasattr(jax, "shard_map")


def _run(body: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    prelude = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.models.config import ModelConfig, CCMConfig
        from repro.models import transformer as T
        from repro.core import masks as M
        from repro.launch.mesh import make_dist, make_debug_mesh
        from repro.launch.train import (make_train_step, jit_train_step,
                                        trainable_mask_for)
        from repro.optim import partition as PT
        from repro.optim.adamw import AdamWConfig, init_adamw
        from repro.data.synthetic import sample_kv_batch
    """)
    r = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(body)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_pjit_train_step_sharded():
    out = _run("""
        mesh = make_debug_mesh(2, 4)
        dist = make_dist(mesh)
        cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                          n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=128,
                          train_mode="lora",
                          ccm=CCMConfig(comp_len=2, max_steps=4))
        layout = M.segment_layout(4, 8, 2, 8)
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        trainable = trainable_mask_for(cfg, params)
        tp, fp = PT.partition(params, trainable)
        opt = init_adamw(tp)
        batch = sample_kv_batch(jax.random.PRNGKey(1), layout, 8)
        step = make_train_step(cfg, layout, AdamWConfig(), dist)
        jstep = jit_train_step(step, cfg, dist, params,
                               jax.eval_shape(init_adamw, tp), batch,
                               trainable)
        tp2, opt2, m, _ = jstep(tp, fp, opt, batch, None)
        assert np.isfinite(float(m["loss"]))
        print("OK", float(m["loss"]))
    """)
    assert "OK" in out


@pytest.mark.xfail(OLD_JAX, reason="legacy shard_map: TP/EP combine "
                   "exceeds tolerance on jax<0.6", strict=False)
def test_moe_tp_ep_equivalence():
    out = _run("""
        from repro.models import moe as MOE
        mesh = make_debug_mesh(2, 4)
        dist = make_dist(mesh)
        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
                          n_experts=8, top_k=2, compute_dtype="float32",
                          ccm=CCMConfig(comp_len=2, max_steps=4))
        p = MOE.init_moe(jax.random.PRNGKey(0), cfg, 64, 128)
        x = jax.random.normal(jax.random.PRNGKey(5), (8, 16, 64))
        y_local = MOE._moe_local(cfg, p, x.reshape(-1, 64))
        y_tp = MOE.apply_moe(cfg.replace(moe_impl="ragged_tp"), p, x,
                             dist).reshape(-1, 64)
        y_ep = MOE.apply_moe(cfg.replace(moe_impl="ep"), p, x,
                             dist).reshape(-1, 64)
        for y in (y_tp, y_ep):
            assert float(jnp.abs(y - y_local).max()) < 1e-4
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.xfail(OLD_JAX, reason="legacy shard_map partial-manual "
                   "reduce crashes XLA (IsManualSubgroup) on jax<0.6",
                   strict=False)
def test_grad_compression_distributed():
    out = _run("""
        from repro.optim.grad_compress import EFState
        mesh = make_debug_mesh(2, 4)
        dist = make_dist(mesh)
        cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                          n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=128,
                          train_mode="lora",
                          ccm=CCMConfig(comp_len=2, max_steps=4))
        layout = M.segment_layout(4, 8, 2, 8)
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        trainable = trainable_mask_for(cfg, params)
        tp, fp = PT.partition(params, trainable)
        opt = init_adamw(tp)
        batch = sample_kv_batch(jax.random.PRNGKey(1), layout, 8)
        ef = EFState(jax.tree.map(
            lambda p: jnp.zeros((2,) + p.shape, jnp.float32), tp))
        # int8-compressed step loss matches uncompressed step loss exactly
        # (loss is computed before the reduce)
        s_c = jax.jit(make_train_step(cfg, layout, AdamWConfig(), dist,
                                      grad_codec="int8"))
        s_u = jax.jit(make_train_step(cfg, layout, AdamWConfig(), dist))
        _, _, m_c, nef = s_c(tp, fp, opt, batch, ef)
        _, _, m_u, _ = s_u(tp, fp, opt, batch, None)
        # fp reduction-order noise between pmean-of-shard-means and
        # the global mean: tolerance is relative ~4e-4 at loss ~5.5
        assert abs(float(m_c["loss"]) - float(m_u["loss"])) < 2e-3
        resid = sum(float(jnp.abs(r).sum())
                    for r in jax.tree.leaves(nef.residual))
        assert np.isfinite(resid)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_restore_new_mesh(tmp_path):
    out = _run(f"""
        from repro.launch.train import TrainLoop
        from repro.distributed.elastic import simulate_failure_and_recover
        cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=128,
                          train_mode="lora",
                          ccm=CCMConfig(comp_len=2, max_steps=2))
        layout = M.segment_layout(2, 6, 2, 8)
        from repro.optim.adamw import AdamWConfig
        def factory(dist):
            return TrainLoop(cfg, layout, AdamWConfig(lr=1e-3,
                             total_steps=20), batch_size=8,
                             ckpt_dir={str(tmp_path)!r}, ckpt_every=4,
                             dist=None)
        mesh_a = make_debug_mesh(4, 2)   # 8 devices
        mesh_b = make_debug_mesh(2, 2)   # 'lost' half the fleet
        hist, start = simulate_failure_and_recover(
            factory, mesh_a, mesh_b, fail_after_steps=8, total_steps=12)
        assert start == 8 and len(hist) == 4
        print("OK resumed at", start)
    """)
    assert "OK" in out


def test_seq_sharded_decode():
    """SP: KV-cache sequence axis sharded over data (long-context decode)."""
    out = _run("""
        from repro.core import inference as I
        from repro.distributed import sharding as SH
        mesh = make_debug_mesh(2, 2)
        dist = make_dist(mesh)
        cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                          compute_dtype="float32",
                          ccm=CCMConfig(comp_len=2, max_steps=4))
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        state = I.init_online_state(cfg, 1, max_cache_len=64)
        state = state._replace(cache=state.cache._replace(
            length=jnp.asarray(64, jnp.int32)))
        sspec = SH.online_state_pspecs(cfg, dist, batch_sharded=False,
                                       shard_cache_seq=True)
        st_sh = SH.named(mesh, sspec)
        fn = jax.jit(lambda p, s, t: I.decode_step(p, cfg, s, t),
                     in_shardings=(None, st_sh, None))
        lg, _ = fn(params, state, jnp.ones((1, 1), jnp.int32))
        assert np.isfinite(np.asarray(lg, np.float32)).all()
        print("OK")
    """, devices=4)
    assert "OK" in out
