"""Multi-tenant serve subsystem: arena, scheduler, engine, LRU offload."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import inference as I
from repro.kernels import ops, ref
from repro.models import transformer as T
from repro.serve.arena import ArenaFull, SessionArena
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Scheduler
from repro.serve.session import SessionManager


@pytest.fixture(scope="module")
def params(tiny_cfg):
    return T.init_lm(jax.random.PRNGKey(0), tiny_cfg)


def _tokens(key, n, vocab=128):
    return jax.random.randint(jax.random.PRNGKey(key), (n,), 0, vocab)


# ---------------------------------------------------------------------------
# arena
# ---------------------------------------------------------------------------

def test_arena_alloc_free(tiny_cfg):
    arena = SessionArena.for_online(tiny_cfg, n_slots=3, cache_len=16)
    slots = [arena.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2]
    assert arena.pad_slot == 3 and arena.pad_slot not in slots
    with pytest.raises(ArenaFull):
        arena.alloc()
    arena.free(slots[1])
    assert arena.n_free == 1 and arena.alloc() == slots[1]
    with pytest.raises(ValueError):
        arena.free(99)


def test_arena_pack_unpack_roundtrip(tiny_cfg):
    arena = SessionArena.for_online(tiny_cfg, n_slots=4, cache_len=8)
    for slot in (arena.alloc(), arena.alloc(), arena.alloc()):
        state = jax.tree.map(
            lambda s: jnp.full(s.shape, float(slot + 1), s.dtype)
            if jnp.issubdtype(s.dtype, jnp.floating)
            else jnp.full(s.shape, slot + 1, s.dtype),
            arena.template)
        arena.write_slot(slot, state)
    packed = arena.pack([2, 0, arena.pad_slot])
    assert packed.mem.k.shape[0] == 3
    np.testing.assert_array_equal(np.asarray(packed.mem.k[0]), 3.0)
    np.testing.assert_array_equal(np.asarray(packed.mem.k[1]), 1.0)
    np.testing.assert_array_equal(np.asarray(packed.mem.k[2]), 0.0)  # scratch
    assert int(packed.pos[0]) == 3 and int(packed.pos[1]) == 1
    # mutate and scatter back; untouched slots must be unaffected
    bumped = jax.tree.map(lambda x: x + 1, packed)
    arena.unpack([2, 0, arena.pad_slot], bumped)
    assert float(arena.read_slot(2).mem.k[0, 0, 0, 0, 0]) == 4.0
    assert float(arena.read_slot(0).mem.k[0, 0, 0, 0, 0]) == 2.0
    assert float(arena.read_slot(1).mem.k[0, 0, 0, 0, 0]) == 2.0  # untouched


def test_session_gather_scatter_kernel_matches_ref():
    """Pallas kernel (interpret mode) vs pure-jnp oracle, dup ids incl."""
    key = jax.random.PRNGKey(7)
    slab = jax.random.normal(key, (6, 40))
    ids = jnp.array([5, 0, 5, 3], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(ops.session_gather(slab, ids, interpret=True)),
        np.asarray(ref.session_gather_ref(slab, ids)), atol=0)
    rows = jax.random.normal(jax.random.PRNGKey(8), (2, 40))
    ids2 = jnp.array([1, 4], jnp.int32)
    # ops.session_scatter donates the slab — take the oracle first
    expect = np.asarray(ref.session_scatter_ref(slab, ids2, rows))
    got = np.asarray(ops.session_scatter(slab, ids2, rows, interpret=True))
    np.testing.assert_allclose(got, expect, atol=0)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_groups_by_kind_and_shape():
    sch = Scheduler(batch_buckets=(1, 2, 4))
    for s in range(3):
        sch.submit(f"s{s}", "ingest", np.zeros(8, np.int32))
    sch.submit("s0", "query", np.zeros(4, np.int32))
    sch.submit("s3", "ingest", np.zeros(16, np.int32))  # different shape
    b1 = sch.next_batch()
    assert (b1.kind, b1.token_len, b1.bucket) == ("ingest", 8, 4)
    assert [r.sid for r in b1.requests] == ["s0", "s1", "s2"] and b1.pad == 1
    b2 = sch.next_batch()
    assert (b2.kind, b2.token_len) == ("query", 4) and b2.bucket == 1
    b3 = sch.next_batch()
    assert (b3.kind, b3.token_len) == ("ingest", 16)
    assert sch.next_batch() is None


def test_scheduler_session_program_order():
    """A session's ops never reorder (even across priorities) and never
    co-batch."""
    sch = Scheduler(batch_buckets=(1, 2, 4))
    sch.submit("a", "ingest", np.zeros(8, np.int32), priority=1)
    sch.submit("a", "query", np.zeros(8, np.int32), priority=0)
    sch.submit("a", "ingest", np.zeros(8, np.int32), priority=0)
    kinds = []
    while (b := sch.next_batch()) is not None:
        assert len(b.requests) == 1
        kinds.append(b.kind)
    assert kinds == ["ingest", "query", "ingest"]


def test_scheduler_priority_fifo():
    sch = Scheduler(batch_buckets=(1, 2))
    sch.submit("a", "ingest", np.zeros(8, np.int32), priority=5)
    sch.submit("b", "ingest", np.zeros(8, np.int32), priority=0)
    sch.submit("c", "ingest", np.zeros(8, np.int32), priority=0)
    b1 = sch.next_batch()
    assert [r.sid for r in b1.requests] == ["b", "c"]


# ---------------------------------------------------------------------------
# engine: correctness, compile churn, offload
# ---------------------------------------------------------------------------

def test_engine_matches_single_session(tiny_cfg, params):
    """Batched multi-tenant execution == direct per-session ops."""
    chunks = [np.asarray(_tokens(i, 8)) for i in range(3)]
    query = np.asarray(_tokens(9, 4))
    eng = ServeEngine(params, tiny_cfg, n_slots=4, cache_len=32,
                      batch_buckets=(1, 2, 4))
    for s in range(3):
        eng.create_session(f"s{s}")
        eng.ingest(f"s{s}", chunks[s])
    reqs = [eng.query(f"s{s}", query) for s in range(3)]
    eng.run()
    for s in range(3):
        st = I.init_online_state(tiny_cfg, 1, max_cache_len=32)
        st = I.ingest_context(params, tiny_cfg, st, chunks[s][None])
        lg, _ = I.prefill(params, tiny_cfg, st, query[None],
                          full_logits=True)
        np.testing.assert_allclose(np.asarray(reqs[s].result),
                                   np.asarray(lg[0]), atol=1e-5)


def test_engine_no_recompile_churn(tiny_cfg, params):
    """Mixed op kinds over bucketed shapes: compile count stays at one
    program per (kind, bucket, token_len) combination."""
    eng = ServeEngine(params, tiny_cfg, n_slots=8, cache_len=64,
                      batch_buckets=(1, 2, 4))
    for s in range(4):
        eng.create_session(f"s{s}")
    for wave in range(3):
        for s in range(4):
            eng.ingest(f"s{s}", np.asarray(_tokens(10 * wave + s, 8)))
        for s in range(wave + 1):   # 1, 2, 3 queries -> buckets 1, 2, 4
            eng.query(f"s{s}", np.asarray(_tokens(99 + s, 4)))
        eng.run()
    stats = eng.compile_stats()
    # ingest: always 4 sessions -> single (B=4, len=8) program
    assert stats["ingest"] == 1
    # query: batches of 1, 2, 3 -> buckets 1, 2, 4 -> three programs
    assert stats["query"] == 3
    assert eng.stats["ingest"]["batches"] == 3
    # re-run same shapes: no new programs
    for s in range(4):
        eng.ingest(f"s{s}", np.asarray(_tokens(500 + s, 8)))
    eng.run()
    assert eng.compile_stats() == stats


def test_lru_offload_restore(tiny_cfg):
    arena = SessionArena.for_online(tiny_cfg, n_slots=2, cache_len=8)
    mgr = SessionManager(arena, max_resident=2)
    for s in ("a", "b", "c"):
        mgr.create(s)
    mgr.activate("a"), mgr.activate("b")
    marked = jax.tree.map(
        lambda s: jnp.full(s.shape, 7, s.dtype), arena.template)
    arena.write_slot(mgr.sessions["a"].slot, marked)
    mgr.activate("c")                       # evicts LRU = "a"
    assert not mgr.sessions["a"].resident
    assert mgr.sessions["a"].n_offloads == 1
    assert mgr.sessions["b"].resident and mgr.sessions["c"].resident
    mgr.activate("a")                       # evicts LRU = "b", restores "a"
    assert not mgr.sessions["b"].resident
    got = arena.read_slot(mgr.sessions["a"].slot)
    for leaf, exp in zip(jax.tree.leaves(got), jax.tree.leaves(marked)):
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(exp))
    # pinned sessions are never evicted
    with pytest.raises(ArenaFull):
        mgr.activate("b", pinned={"a", "c"})


def test_engine_offload_preserves_logits(tiny_cfg, params):
    """offload -> restore roundtrip reproduces query logits exactly."""
    chunk, query = np.asarray(_tokens(1, 8)), np.asarray(_tokens(2, 4))

    def run(offload):
        eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=32,
                          batch_buckets=(1, 2))
        eng.create_session("u")
        eng.ingest("u", chunk)
        eng.run()
        if offload:
            eng.offload_session("u")
            assert not eng._mgr["online"].sessions["u"].resident
        req = eng.query("u", query)
        eng.run()
        return np.asarray(req.result)

    np.testing.assert_array_equal(run(offload=False), run(offload=True))


def test_engine_stream_sessions(tiny_cfg, params):
    """Streaming sessions run through their own arena and match the
    direct stream_step path."""
    from repro.core import streaming as ST
    cfg = tiny_cfg.replace(ccm=tiny_cfg.ccm.__class__(
        comp_len=2, max_steps=4, stream_window=16, stream_sink=2,
        stream_chunk=4, stream_mem_slots=4))
    params2 = T.init_lm(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(params2, cfg, n_slots=1, cache_len=8,
                      stream_slots=2, batch_buckets=(1, 2))
    eng.create_session("u", kind="stream")
    toks = [np.asarray(_tokens(40 + i, 4)) for i in range(6)]
    reqs = [eng.stream("u", t) for t in toks]
    eng.run()
    st = ST.init_stream_state(cfg, 1)
    for t, req in zip(toks, reqs):
        lg, st = ST.stream_step(params2, cfg, st, t[None])
        np.testing.assert_allclose(np.asarray(req.result),
                                   np.asarray(lg[0]), atol=1e-5)
    with pytest.raises(ValueError):
        eng.ingest("u", toks[0])   # wrong op kind for a stream session


def test_stream_batches_capped_by_stream_arena(tiny_cfg, params):
    """A stream batch must fit the (smaller) stream arena even when the
    online arena is larger — regression for the shared max_batch cap."""
    cfg = tiny_cfg.replace(ccm=tiny_cfg.ccm.__class__(
        comp_len=2, max_steps=4, stream_window=16, stream_sink=2,
        stream_chunk=4, stream_mem_slots=4))
    params2 = T.init_lm(jax.random.PRNGKey(2), cfg)
    eng = ServeEngine(params2, cfg, n_slots=8, cache_len=8,
                      stream_slots=2, batch_buckets=(1, 2, 4, 8))
    reqs = []
    for s in range(3):
        eng.create_session(f"t{s}", kind="stream")
        reqs.append(eng.stream(f"t{s}", np.asarray(_tokens(60 + s, 4))))
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.stats["stream"]["requests"] == 3
    assert eng.stats["stream"]["batches"] == 2   # 2 + 1, capped at 2
    # oversized stream chunks are rejected at SUBMIT time, not mid-drain
    with pytest.raises(ValueError, match="stream_chunk"):
        eng.stream("t0", np.asarray(_tokens(70, 8)))   # 8 > stream_chunk 4


def test_close_session_cancels_queued_requests(tiny_cfg, params):
    """Closing a session drops its queued work (flagged cancelled);
    run() must not crash."""
    eng = ServeEngine(params, tiny_cfg, n_slots=4, cache_len=16,
                      batch_buckets=(1, 2, 4))
    eng.create_session("a")
    eng.create_session("b")
    ra = eng.ingest("a", np.asarray(_tokens(0, 8)))
    rb = eng.ingest("b", np.asarray(_tokens(1, 8)))
    eng.close_session("a")
    assert ra.cancelled and ra.done and ra.result is None
    assert eng.scheduler.pending == 1
    eng.run()
    assert rb.done and not rb.cancelled


def test_submit_validation_and_buffer_copy():
    """submit() rejects batched token arrays and copies caller buffers."""
    sch = Scheduler(batch_buckets=(1, 2))
    with pytest.raises(ValueError, match="one sequence"):
        sch.submit("a", "ingest", np.zeros((2, 8), np.int32))
    buf = np.arange(8, dtype=np.int32)
    req = sch.submit("a", "ingest", buf)
    buf[:] = -1                      # caller reuses the buffer pre-run
    np.testing.assert_array_equal(req.tokens[0], np.arange(8))


def test_engine_admission_guards(tiny_cfg, params):
    """KV-cache exhaustion and bad stream configs fail fast, not
    mid-drain."""
    eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=8,
                      batch_buckets=(1, 2))
    eng.create_session("u")
    eng.query("u", np.asarray(_tokens(0, 6)))
    with pytest.raises(ValueError, match="cache exhausted"):
        eng.query("u", np.asarray(_tokens(1, 6)))   # 6 + 6 > 8
    bad = tiny_cfg.replace(ccm=tiny_cfg.ccm.__class__(
        comp_len=2, max_steps=4, stream_window=8, stream_sink=4,
        stream_chunk=6))
    with pytest.raises(ValueError, match="stream_window"):
        ServeEngine(params, bad, n_slots=2, cache_len=8, stream_slots=1)


def test_reset_slots_beyond_largest_bucket(tiny_cfg):
    """reset_slots handles more stale slots than the largest batch
    bucket (regression: bucket < n crashed the zeroing scatter)."""
    from repro.launch.specs import SERVE_BATCH_BUCKETS
    n = max(SERVE_BATCH_BUCKETS) + 22
    arena = SessionArena.for_online(tiny_cfg, n_slots=n, cache_len=4)
    slots = [arena.alloc() for _ in range(n)]
    arena.mark_dirty(slots)
    arena.reset_slots(slots)     # must not raise
    assert float(jax.tree.leaves(arena.read_slot(slots[-1]))[0].sum()) == 0
