"""Multi-tenant serve subsystem: arena, scheduler, engine, LRU offload,
ragged token-bucket batching (masked lanes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import inference as I
from repro.core import masks as M
from repro.kernels import ops, ref
from repro.launch import serve as SRV
from repro.models import transformer as T
from repro.serve.arena import ArenaFull, SessionArena
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Scheduler
from repro.serve.session import SessionManager


def _assert_state_close(got, want, atol=2e-6):
    """Leafwise compare two state pytrees: int leaves (counters, lengths)
    exactly, float leaves to a tight tolerance."""
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        g, w = np.asarray(g), np.asarray(w)
        assert g.shape == w.shape
        if np.issubdtype(g.dtype, np.integer):
            np.testing.assert_array_equal(g, w)
        else:
            np.testing.assert_allclose(g, w, atol=atol, rtol=0)


@pytest.fixture(scope="module")
def params(tiny_cfg):
    return T.init_lm(jax.random.PRNGKey(0), tiny_cfg)


def _tokens(key, n, vocab=128):
    return jax.random.randint(jax.random.PRNGKey(key), (n,), 0, vocab)


# ---------------------------------------------------------------------------
# arena
# ---------------------------------------------------------------------------

def test_arena_alloc_free(tiny_cfg):
    arena = SessionArena.for_online(tiny_cfg, n_slots=3, cache_len=16)
    slots = [arena.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2]
    assert arena.pad_slot == 3 and arena.pad_slot not in slots
    with pytest.raises(ArenaFull):
        arena.alloc()
    arena.free(slots[1])
    assert arena.n_free == 1 and arena.alloc() == slots[1]
    with pytest.raises(ValueError):
        arena.free(99)


def test_arena_pack_unpack_roundtrip(tiny_cfg):
    arena = SessionArena.for_online(tiny_cfg, n_slots=4, cache_len=8)
    for slot in (arena.alloc(), arena.alloc(), arena.alloc()):
        state = jax.tree.map(
            lambda s: jnp.full(s.shape, float(slot + 1), s.dtype)
            if jnp.issubdtype(s.dtype, jnp.floating)
            else jnp.full(s.shape, slot + 1, s.dtype),
            arena.template)
        arena.write_slot(slot, state)
    packed = arena.pack([2, 0, arena.pad_slot])
    assert packed.mem.k.shape[0] == 3
    np.testing.assert_array_equal(np.asarray(packed.mem.k[0]), 3.0)
    np.testing.assert_array_equal(np.asarray(packed.mem.k[1]), 1.0)
    np.testing.assert_array_equal(np.asarray(packed.mem.k[2]), 0.0)  # scratch
    assert int(packed.pos[0]) == 3 and int(packed.pos[1]) == 1
    # mutate and scatter back; untouched slots must be unaffected
    bumped = jax.tree.map(lambda x: x + 1, packed)
    arena.unpack([2, 0, arena.pad_slot], bumped)
    assert float(arena.read_slot(2).mem.k[0, 0, 0, 0, 0]) == 4.0
    assert float(arena.read_slot(0).mem.k[0, 0, 0, 0, 0]) == 2.0
    assert float(arena.read_slot(1).mem.k[0, 0, 0, 0, 0]) == 2.0  # untouched


def test_session_gather_scatter_kernel_matches_ref():
    """Pallas kernel (interpret mode) vs pure-jnp oracle, dup ids incl."""
    key = jax.random.PRNGKey(7)
    slab = jax.random.normal(key, (6, 40))
    ids = jnp.array([5, 0, 5, 3], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(ops.session_gather(slab, ids, interpret=True)),
        np.asarray(ref.session_gather_ref(slab, ids)), atol=0)
    rows = jax.random.normal(jax.random.PRNGKey(8), (2, 40))
    ids2 = jnp.array([1, 4], jnp.int32)
    # ops.session_scatter donates the slab — take the oracle first
    expect = np.asarray(ref.session_scatter_ref(slab, ids2, rows))
    got = np.asarray(ops.session_scatter(slab, ids2, rows, interpret=True))
    np.testing.assert_allclose(got, expect, atol=0)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_groups_by_kind_and_shape():
    sch = Scheduler(batch_buckets=(1, 2, 4))
    for s in range(3):
        sch.submit(f"s{s}", "ingest", np.zeros(8, np.int32))
    sch.submit("s0", "query", np.zeros(4, np.int32))
    sch.submit("s3", "ingest", np.zeros(16, np.int32))  # different shape
    b1 = sch.next_batch()
    assert (b1.kind, b1.token_len, b1.bucket) == ("ingest", 8, 4)
    assert [r.sid for r in b1.requests] == ["s0", "s1", "s2"] and b1.pad == 1
    b2 = sch.next_batch()
    assert (b2.kind, b2.token_len) == ("query", 4) and b2.bucket == 1
    b3 = sch.next_batch()
    assert (b3.kind, b3.token_len) == ("ingest", 16)
    assert sch.next_batch() is None


def test_scheduler_session_program_order():
    """A session's ops never reorder (even across priorities) and never
    co-batch."""
    sch = Scheduler(batch_buckets=(1, 2, 4))
    sch.submit("a", "ingest", np.zeros(8, np.int32), priority=1)
    sch.submit("a", "query", np.zeros(8, np.int32), priority=0)
    sch.submit("a", "ingest", np.zeros(8, np.int32), priority=0)
    kinds = []
    while (b := sch.next_batch()) is not None:
        assert len(b.requests) == 1
        kinds.append(b.kind)
    assert kinds == ["ingest", "query", "ingest"]


def test_scheduler_priority_fifo():
    sch = Scheduler(batch_buckets=(1, 2))
    sch.submit("a", "ingest", np.zeros(8, np.int32), priority=5)
    sch.submit("b", "ingest", np.zeros(8, np.int32), priority=0)
    sch.submit("c", "ingest", np.zeros(8, np.int32), priority=0)
    b1 = sch.next_batch()
    assert [r.sid for r in b1.requests] == ["b", "c"]


# ---------------------------------------------------------------------------
# engine: correctness, compile churn, offload
# ---------------------------------------------------------------------------

def test_engine_matches_single_session(tiny_cfg, params):
    """Batched multi-tenant execution == direct per-session ops."""
    chunks = [np.asarray(_tokens(i, 8)) for i in range(3)]
    query = np.asarray(_tokens(9, 4))
    eng = ServeEngine(params, tiny_cfg, n_slots=4, cache_len=32,
                      batch_buckets=(1, 2, 4))
    for s in range(3):
        eng.create_session(f"s{s}")
        eng.ingest(f"s{s}", chunks[s])
    reqs = [eng.query(f"s{s}", query).request for s in range(3)]
    eng.run()
    for s in range(3):
        st = I.init_online_state(tiny_cfg, 1, max_cache_len=32)
        st = I.ingest_context(params, tiny_cfg, st, chunks[s][None])
        lg, _ = I.prefill(params, tiny_cfg, st, query[None],
                          full_logits=True)
        np.testing.assert_allclose(np.asarray(reqs[s].result),
                                   np.asarray(lg[0]), atol=1e-5)


def test_engine_no_recompile_churn(tiny_cfg, params):
    """Mixed op kinds over bucketed shapes: compile count stays at one
    program per (kind, bucket, token_len) combination."""
    eng = ServeEngine(params, tiny_cfg, n_slots=8, cache_len=64,
                      batch_buckets=(1, 2, 4))
    for s in range(4):
        eng.create_session(f"s{s}")
    for wave in range(3):
        for s in range(4):
            eng.ingest(f"s{s}", np.asarray(_tokens(10 * wave + s, 8)))
        for s in range(wave + 1):   # 1, 2, 3 queries -> buckets 1, 2, 4
            eng.query(f"s{s}", np.asarray(_tokens(99 + s, 4)))
        eng.run()
    stats = eng.compile_stats()
    # ingest: always 4 sessions -> single (B=4, len=8) program
    assert stats["ingest"] == 1
    # query: batches of 1, 2, 3 -> buckets 1, 2, 4 -> three programs
    assert stats["query"] == 3
    assert eng.stats["ingest"]["batches"] == 3
    # re-run same shapes: no new programs
    for s in range(4):
        eng.ingest(f"s{s}", np.asarray(_tokens(500 + s, 8)))
    eng.run()
    assert eng.compile_stats() == stats


def test_lru_offload_restore(tiny_cfg):
    arena = SessionArena.for_online(tiny_cfg, n_slots=2, cache_len=8)
    mgr = SessionManager(arena, max_resident=2)
    for s in ("a", "b", "c"):
        mgr.create(s)
    mgr.activate("a"), mgr.activate("b")
    marked = jax.tree.map(
        lambda s: jnp.full(s.shape, 7, s.dtype), arena.template)
    arena.write_slot(mgr.sessions["a"].slot, marked)
    mgr.activate("c")                       # evicts LRU = "a"
    assert not mgr.sessions["a"].resident
    assert mgr.sessions["a"].n_offloads == 1
    assert mgr.sessions["b"].resident and mgr.sessions["c"].resident
    mgr.activate("a")                       # evicts LRU = "b", restores "a"
    assert not mgr.sessions["b"].resident
    got = arena.read_slot(mgr.sessions["a"].slot)
    for leaf, exp in zip(jax.tree.leaves(got), jax.tree.leaves(marked)):
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(exp))
    # pinned sessions are never evicted
    with pytest.raises(ArenaFull):
        mgr.activate("b", pinned={"a", "c"})


def test_engine_offload_preserves_logits(tiny_cfg, params):
    """offload -> restore roundtrip reproduces query logits exactly."""
    chunk, query = np.asarray(_tokens(1, 8)), np.asarray(_tokens(2, 4))

    def run(offload):
        eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=32,
                          batch_buckets=(1, 2))
        eng.create_session("u")
        eng.ingest("u", chunk)
        eng.run()
        if offload:
            eng.offload_session("u")
            assert not eng._mgr["online"].sessions["u"].resident
        req = eng.query("u", query).request
        eng.run()
        return np.asarray(req.result)

    np.testing.assert_array_equal(run(offload=False), run(offload=True))


def test_engine_stream_sessions(tiny_cfg, params):
    """Streaming sessions run through their own arena and match the
    direct stream_step path."""
    from repro.core import streaming as ST
    cfg = tiny_cfg.replace(ccm=tiny_cfg.ccm.__class__(
        comp_len=2, max_steps=4, stream_window=16, stream_sink=2,
        stream_chunk=4, stream_mem_slots=4))
    params2 = T.init_lm(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(params2, cfg, n_slots=1, cache_len=8,
                      stream_slots=2, batch_buckets=(1, 2))
    eng.create_session("u", kind="stream")
    toks = [np.asarray(_tokens(40 + i, 4)) for i in range(6)]
    reqs = [eng.stream("u", t).request for t in toks]
    eng.run()
    st = ST.init_stream_state(cfg, 1)
    for t, req in zip(toks, reqs):
        lg, st = ST.stream_step(params2, cfg, st, t[None])
        np.testing.assert_allclose(np.asarray(req.result),
                                   np.asarray(lg[0]), atol=1e-5)
    with pytest.raises(ValueError):
        eng.ingest("u", toks[0])   # wrong op kind for a stream session


def _stream_cfg(tiny_cfg):
    from repro.models.config import CCMConfig
    return tiny_cfg.replace(ccm=CCMConfig(
        comp_len=2, max_steps=4, stream_window=16, stream_sink=2,
        stream_chunk=4, stream_mem_slots=4))


def test_stream_lanes_eviction_gated_per_lane(tiny_cfg, params):
    """stream_step_lanes: a batch where ONE lane overflows must (a) match
    running each lane through the single-session stream_step bit-exactly
    in every state leaf, (b) leave non-overflowing lanes' memory and
    counters untouched by the masked eviction, and (c) keep the whole
    eviction/compression pass under a REAL `cond` (predicated on the
    batch-level any-lane-pending scalar, not a per-lane select)."""
    from repro.core import streaming as ST
    cfg = _stream_cfg(tiny_cfg)
    key = jax.random.PRNGKey(5)
    warm = [4, 1, 0]   # win_len 16 / 4 / 0 -> only lane 0 overflows on +4
    lanes = []
    for i, w in enumerate(warm):
        st = ST.init_stream_state(cfg, 1)
        for j in range(w):
            t = jax.random.randint(jax.random.fold_in(key, i * 10 + j),
                                   (1, 4), 0, 128)
            _, st = ST.stream_step(params, cfg, st, t)
        lanes.append(st)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)
    toks = jax.random.randint(jax.random.fold_in(key, 99), (3, 1, 4),
                              0, 128)
    pending = ST.eviction_pending(cfg, stacked, jnp.full((3,), 4))
    assert list(np.asarray(pending)) == [True, False, False]
    fn = jax.jit(lambda s, t: ST.stream_step_lanes(params, cfg, s, t))
    lg, new = fn(stacked, toks)
    for i in range(3):
        lg1, st1 = ST.stream_step(params, cfg, lanes[i], toks[i])
        np.testing.assert_allclose(np.asarray(lg[i]), np.asarray(lg1),
                                   atol=2e-5, rtol=0)
        lane_new = jax.tree.map(lambda a: a[i], new)
        for g, w in zip(jax.tree.leaves(lane_new), jax.tree.leaves(st1)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # non-overflow lanes: compression never touched memory or counters
    for i in (1, 2):
        np.testing.assert_array_equal(np.asarray(new.mem.k[i]),
                                      np.asarray(stacked.mem.k[i]))
        np.testing.assert_array_equal(np.asarray(new.mem.slots[i]),
                                      np.asarray(stacked.mem.slots[i]))
        assert int(new.pos[i]) == int(stacked.pos[i]) + 4
    jp = str(jax.make_jaxpr(
        lambda s, t: ST.stream_step_lanes(params, cfg, s, t))(stacked, toks))
    assert "cond[" in jp


def test_stream_lanes_no_overflow_skips_compression(tiny_cfg, params):
    """A batch with NO pending lane leaves every memory leaf bit-identical
    to the input — the gated branch was the identity."""
    from repro.core import streaming as ST
    cfg = _stream_cfg(tiny_cfg)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[ST.init_stream_state(cfg, 1) for _ in range(3)])
    toks = jax.random.randint(jax.random.PRNGKey(7), (3, 1, 4), 0, 128)
    _, new = ST.stream_step_lanes(params, cfg, stacked, toks)
    for g, w in zip(jax.tree.leaves(new.mem), jax.tree.leaves(stacked.mem)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_stream_lanes_ragged_matches_unpadded(tiny_cfg, params):
    """Ragged stream lanes through stream_step_lanes: a lane padded into
    a larger token bucket (valid_len < padded width) must match the
    unpadded single-session run bit-exactly — including the per-lane
    eviction trigger, which fires on valid lengths, not bucket widths."""
    from repro.core import streaming as ST
    cfg = _stream_cfg(tiny_cfg)
    key = jax.random.PRNGKey(9)
    # warm lane 0 to the brink: 4 more VALID tokens would overflow, but
    # its next request is only 2 valid tokens -> must NOT evict
    lanes = []
    for i, w in enumerate([4, 2]):
        st = ST.init_stream_state(cfg, 1)
        for j in range(w):
            t = jax.random.randint(jax.random.fold_in(key, i * 10 + j),
                                   (1, 4), 0, 128)
            _, st = ST.stream_step(params, cfg, st, t)
        lanes.append(st)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)
    toks = jax.random.randint(jax.random.fold_in(key, 77), (2, 1, 4), 0, 128)
    vls = jnp.array([2, 4], jnp.int32)
    lg, new = ST.stream_step_lanes(params, cfg, stacked, toks, lengths=vls)
    for i in range(2):
        vl = int(vls[i])
        lg1, st1 = ST.stream_step(params, cfg, lanes[i], toks[i][:, :vl])
        np.testing.assert_allclose(np.asarray(lg[i][:, :vl]),
                                   np.asarray(lg1), atol=2e-5, rtol=0)
        # counters (incl. the eviction trigger) exact; written float rows
        # to tolerance (padded-shape programs fuse matmuls differently)
        _assert_state_close(jax.tree.map(lambda a: a[i], new), st1)


def test_stream_batches_capped_by_stream_arena(tiny_cfg, params):
    """A stream batch must fit the (smaller) stream arena even when the
    online arena is larger — regression for the shared max_batch cap."""
    cfg = tiny_cfg.replace(ccm=tiny_cfg.ccm.__class__(
        comp_len=2, max_steps=4, stream_window=16, stream_sink=2,
        stream_chunk=4, stream_mem_slots=4))
    params2 = T.init_lm(jax.random.PRNGKey(2), cfg)
    eng = ServeEngine(params2, cfg, n_slots=8, cache_len=8,
                      stream_slots=2, batch_buckets=(1, 2, 4, 8))
    reqs = []
    for s in range(3):
        eng.create_session(f"t{s}", kind="stream")
        reqs.append(eng.stream(f"t{s}", np.asarray(_tokens(60 + s, 4))).request)
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.stats["stream"]["requests"] == 3
    assert eng.stats["stream"]["batches"] == 2   # 2 + 1, capped at 2
    # oversized stream chunks are rejected at SUBMIT time, not mid-drain
    with pytest.raises(ValueError, match="stream_chunk"):
        eng.stream("t0", np.asarray(_tokens(70, 8)))   # 8 > stream_chunk 4


def test_close_session_cancels_queued_requests(tiny_cfg, params):
    """Closing a session drops its queued work (flagged cancelled);
    run() must not crash."""
    eng = ServeEngine(params, tiny_cfg, n_slots=4, cache_len=16,
                      batch_buckets=(1, 2, 4))
    eng.create_session("a")
    eng.create_session("b")
    ra = eng.ingest("a", np.asarray(_tokens(0, 8))).request
    rb = eng.ingest("b", np.asarray(_tokens(1, 8))).request
    eng.close_session("a")
    assert ra.cancelled and ra.done and ra.result is None
    assert eng.scheduler.pending == 1
    eng.run()
    assert rb.done and not rb.cancelled


def test_submit_validation_and_buffer_copy():
    """submit() rejects batched token arrays and copies caller buffers."""
    sch = Scheduler(batch_buckets=(1, 2))
    with pytest.raises(ValueError, match="one sequence"):
        sch.submit("a", "ingest", np.zeros((2, 8), np.int32))
    buf = np.arange(8, dtype=np.int32)
    req = sch.submit("a", "ingest", buf)
    buf[:] = -1                      # caller reuses the buffer pre-run
    np.testing.assert_array_equal(req.tokens[0], np.arange(8))


def test_engine_admission_guards(tiny_cfg, params):
    """KV-cache exhaustion and bad stream configs fail fast, not
    mid-drain."""
    eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=8,
                      batch_buckets=(1, 2))
    eng.create_session("u")
    eng.query("u", np.asarray(_tokens(0, 6)))
    with pytest.raises(ValueError, match="cache exhausted"):
        eng.query("u", np.asarray(_tokens(1, 6)))   # 6 + 6 > 8
    bad = tiny_cfg.replace(ccm=tiny_cfg.ccm.__class__(
        comp_len=2, max_steps=4, stream_window=8, stream_sink=4,
        stream_chunk=6))
    with pytest.raises(ValueError, match="stream_window"):
        ServeEngine(params, bad, n_slots=2, cache_len=8, stream_slots=1)


def test_reset_slots_beyond_largest_bucket(tiny_cfg):
    """reset_slots handles more stale slots than the largest batch
    bucket (regression: bucket < n crashed the zeroing scatter)."""
    from repro.launch.specs import SERVE_BATCH_BUCKETS
    n = max(SERVE_BATCH_BUCKETS) + 22
    arena = SessionArena.for_online(tiny_cfg, n_slots=n, cache_len=4)
    slots = [arena.alloc() for _ in range(n)]
    arena.mark_dirty(slots)
    arena.reset_slots(slots)     # must not raise
    assert float(jax.tree.leaves(arena.read_slot(slots[-1]))[0].sum()) == 0


# ---------------------------------------------------------------------------
# ragged token-bucket batching (masked lanes)
# ---------------------------------------------------------------------------

def test_ragged_block_write_matches_ref():
    """core.masks.ragged_block_write vs the kernels.ref oracle, including
    a block that overhangs the buffer end (where dynamic_update_slice
    would clamp-shift and corrupt earlier rows)."""
    key = jax.random.PRNGKey(3)
    buf = jax.random.normal(key, (2, 10, 3))
    blk = jax.random.normal(jax.random.PRNGKey(4), (2, 6, 3))
    got = M.ragged_block_write(buf, blk, 5, 4, axis=1)
    want = ref.ragged_block_write_ref(buf, blk, 5, 4, axis=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # full-valid write == dynamic_update_slice bit-for-bit
    got_full = M.ragged_block_write(buf, blk, 2, 6, axis=1)
    dus = jax.lax.dynamic_update_slice_in_dim(buf, blk, 2, axis=1)
    np.testing.assert_array_equal(np.asarray(got_full), np.asarray(dus))
    # overhang: start+6 > 10 — valid prefix written, rest frozen, no shift
    got_over = M.ragged_block_write(buf, blk, 8, 2, axis=1)
    want_over = ref.ragged_block_write_ref(buf, blk, 8, 2, axis=1)
    np.testing.assert_array_equal(np.asarray(got_over), np.asarray(want_over))
    np.testing.assert_array_equal(np.asarray(got_over)[:, :8], np.asarray(buf)[:, :8])


def test_scheduler_ragged_fill_shares_bucket():
    """Mixed-length requests of one kind share the head's token bucket;
    longer requests wait for their own batch."""
    sch = Scheduler(batch_buckets=(1, 2, 4), token_buckets=(4, 8, 16))
    sch.submit("a", "ingest", np.zeros(5, np.int32))
    sch.submit("b", "ingest", np.zeros(8, np.int32))
    sch.submit("c", "ingest", np.zeros(3, np.int32))
    sch.submit("d", "ingest", np.zeros(11, np.int32))   # > bucket 8: waits
    b1 = sch.next_batch()
    assert b1.token_len == 8 and [r.sid for r in b1.requests] == ["a", "b", "c"]
    assert b1.valid_lens == [5, 8, 3]
    b2 = sch.next_batch()
    assert b2.token_len == 16 and [r.sid for r in b2.requests] == ["d"]
    assert sch.next_batch() is None


def test_aging_prevents_starvation():
    """A low-priority request that can never share the flood's token
    bucket drains once its effective priority ages below the flood's —
    and provably starves with aging disabled (the ROADMAP bug)."""
    def flood_rounds(aging, rounds=60):
        sch = Scheduler(batch_buckets=(1, 2), token_buckets=(8, 16),
                        aging=aging)
        lo = sch.submit("lo", "ingest", np.zeros(16, np.int32), priority=5)
        for i in range(rounds):
            sch.submit(f"hi{2 * i}", "ingest", np.zeros(8, np.int32))
            sch.submit(f"hi{2 * i + 1}", "ingest", np.zeros(8, np.int32))
            batch = sch.next_batch()
            if any(r is lo for r in batch.requests):
                return i
        return None
    assert flood_rounds(aging=None) is None        # starves forever
    drained_at = flood_rounds(aging=4)
    # priority gap 5 x aging 4 -> head within ~20 rounds
    assert drained_at is not None and drained_at <= 24


def test_ragged_ingest_query_equivalence(tiny_cfg, params):
    """Mixed-length requests batched into one token bucket produce
    logits AND post-state numerically identical to unpadded runs."""
    eng = ServeEngine(params, tiny_cfg, n_slots=4, cache_len=32,
                      batch_buckets=(1, 2, 4))
    assert eng.ragged
    lens, qlens = [5, 8, 3], [4, 2, 3]
    chunks = [np.asarray(_tokens(i, L)) for i, L in enumerate(lens)]
    queries = [np.asarray(_tokens(9 + i, L)) for i, L in enumerate(qlens)]
    for s, c in enumerate(chunks):
        eng.create_session(f"s{s}")
        eng.ingest(f"s{s}", c)
    reqs = [eng.query(f"s{s}", q).request for s, q in enumerate(queries)]
    eng.run()
    # all three lengths shared ONE batch per op kind (the point of
    # ragged batching — exact grouping would have taken 3 + 3 batches)
    assert eng.stats["ingest"]["batches"] == 1
    assert eng.stats["query"]["batches"] == 1
    mgr = eng._mgr["online"]
    for s in range(3):
        st = I.init_online_state(tiny_cfg, 1, max_cache_len=32)
        st = I.ingest_context(params, tiny_cfg, st, chunks[s][None])
        lg, st = I.prefill(params, tiny_cfg, st, queries[s][None],
                           full_logits=True)
        assert reqs[s].result.shape[0] == qlens[s]   # sliced by valid_len
        np.testing.assert_allclose(np.asarray(reqs[s].result),
                                   np.asarray(lg[0]), atol=2e-6, rtol=0)
        got = mgr.arena.read_slot(mgr.sessions[f"s{s}"].slot)
        _assert_state_close(got, st)


def test_ragged_stream_equivalence(tiny_cfg, params):
    """Stream chunks padded up to stream_chunk match the unpadded path
    bit-for-bit, including across eviction boundaries."""
    from repro.core import streaming as ST
    cfg = tiny_cfg.replace(ccm=tiny_cfg.ccm.__class__(
        comp_len=2, max_steps=4, stream_window=16, stream_sink=2,
        stream_chunk=4, stream_mem_slots=4))
    params2 = T.init_lm(jax.random.PRNGKey(5), cfg)
    eng = ServeEngine(params2, cfg, n_slots=1, cache_len=8,
                      stream_slots=2, batch_buckets=(1, 2))
    eng.create_session("u", kind="stream")
    # 8 chunks of 3 tokens (padded to the stream_chunk-4 bucket) push the
    # 16-token window through multiple evictions
    toks = [np.asarray(_tokens(70 + i, 3)) for i in range(8)]
    reqs = [eng.stream("u", t).request for t in toks]
    eng.run()
    assert eng.stats["stream"]["pad_tokens"] == 8    # one pad per chunk
    st = ST.init_stream_state(cfg, 1)
    for t, req in zip(toks, reqs):
        lg, st = ST.stream_step(params2, cfg, st, t[None])
        assert req.result.shape[0] == 3
        np.testing.assert_allclose(np.asarray(req.result),
                                   np.asarray(lg[0]), atol=2e-6, rtol=0)
    assert int(st.mem.slots) > 0                     # evictions compressed
    mgr = eng._mgr["stream"]
    got = mgr.arena.read_slot(mgr.sessions["u"].slot)
    _assert_state_close(got, st)


def test_ragged_matches_exact_scheduling(tiny_cfg, params):
    """The same mixed-length traffic through token-bucketed vs exact-
    length scheduling yields identical results — padding is semantics-
    free; only the batching (and compile count) differs."""
    lens = [3, 5, 8, 5, 3, 8]

    def run(token_buckets):
        eng = ServeEngine(params, tiny_cfg, n_slots=8, cache_len=32,
                          batch_buckets=(1, 2, 4, 8),
                          token_buckets=token_buckets)
        outs = []
        for s, L in enumerate(lens):
            eng.create_session(f"s{s}")
            eng.ingest(f"s{s}", np.asarray(_tokens(s, L)))
        reqs = [eng.query(f"s{s}", np.asarray(_tokens(50 + s, L))).request
                for s, L in enumerate(lens)]
        eng.run()
        return ([np.asarray(r.result) for r in reqs],
                sum(s["batches"] for s in eng.stats.values()),
                eng.compiled_programs())

    ragged_out, ragged_batches, ragged_progs = run("auto")
    exact_out, exact_batches, exact_progs = run(None)
    for a, b in zip(ragged_out, exact_out):
        np.testing.assert_allclose(a, b, atol=2e-6, rtol=0)
    assert ragged_batches < exact_batches
    assert ragged_progs < exact_progs


def test_make_arena_step_golden_rows(tiny_cfg, params):
    """Golden regression: gather->op->scatter leaves untouched slab rows
    bit-identical, and pad lanes only ever land on the scratch row — the
    silent-corruption class the PR 1 overflow guard fixed."""
    arena = SessionArena.for_online(tiny_cfg, n_slots=4, cache_len=16)
    for slot in range(4):
        arena.alloc()
        state = jax.tree.map(
            lambda s: jnp.full(s.shape, float(slot + 1), s.dtype)
            if jnp.issubdtype(s.dtype, jnp.floating)
            else jnp.full(s.shape, slot + 1, s.dtype),
            arena.template)
        arena.write_slot(slot, state)
    before = [np.array(leaf) for leaf in jax.tree.leaves(arena.slabs)]
    step = SRV.make_arena_step(tiny_cfg, "ingest", ragged=True)
    pad = arena.pad_slot
    ids = jnp.asarray([1, pad, pad], jnp.int32)      # dup pad lanes
    toks = np.zeros((3, 1, 8), np.int32)
    toks[0, 0, :5] = np.asarray(_tokens(30, 5))
    lengths = np.asarray([5, 8, 8], np.int32)
    out, slabs = step(params, arena.slabs, ids, toks, lengths)
    arena.slabs = slabs
    assert out is None
    after = [np.asarray(leaf) for leaf in jax.tree.leaves(arena.slabs)]
    changed = False
    for b, a in zip(before, after):
        # rows 0, 2, 3 were NOT in the batch: bit-identical
        for row in (0, 2, 3):
            np.testing.assert_array_equal(a[row], b[row])
        changed = changed or not np.array_equal(a[1], b[1])
    assert changed                                   # the live row did run
    # the live row's update equals the direct unpadded op on its state
    st = jax.tree.map(
        lambda s: jnp.full(s.shape, 2.0, s.dtype)
        if jnp.issubdtype(s.dtype, jnp.floating)
        else jnp.full(s.shape, 2, s.dtype), arena.template)
    want = I.ingest_context(params, tiny_cfg, st, jnp.asarray(toks[0, :, :5]))
    _assert_state_close(arena.read_slot(1), want)


# ---------------------------------------------------------------------------
# admission verdicts + batched offload (PR 5)
# ---------------------------------------------------------------------------

def test_submit_returns_admitted_verdict(tiny_cfg, params):
    """Default (unbounded) engine: every submit returns Admitted and the
    request handle rides on the verdict."""
    from repro.serve import Admitted
    eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=16,
                      batch_buckets=(1, 2))
    eng.create_session("u")
    v = eng.ingest("u", np.asarray(_tokens(0, 4)))
    assert isinstance(v, Admitted) and not v.shed_victims
    eng.run()
    assert v.request.done and not v.request.shed


def test_offload_structured_noop_statuses(tiny_cfg, params):
    """Offloading an unknown, never-activated, or already-offloaded
    session is a structured no-op — it used to KeyError (unknown) or
    silently pass (already offloaded)."""
    eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=16,
                      batch_buckets=(1, 2))
    assert eng.offload_session("ghost").status == "unknown"
    eng.create_session("u")
    assert eng.offload_session("u").status == "fresh"       # never ran
    eng.ingest("u", np.asarray(_tokens(0, 4)))
    eng.run()
    r = eng.offload_session("u")
    assert r.status == "offloaded" and r.moved and r.n_bytes > 0
    assert eng.offload_session("u").status == "already-offloaded"
    # the SessionManager-level per-victim path agrees
    mgr = eng._mgr["online"]
    assert mgr.offload("u").status == "already-offloaded"
    assert mgr.offload("ghost").status == "unknown"
    # and the session still restores bit-exactly after the no-ops
    q = eng.query("u", np.asarray(_tokens(1, 3))).request
    eng.run()
    assert q.done and q.result.shape == (3, tiny_cfg.vocab_size)


def _offload_interleaved_trace(cfg, params, *, batched, async_off,
                               seed):
    """Shared fuzz body: 5 warm sessions, k-victim offload, interleaved
    cancel() + re-activation of a session mid-offload, final drain.
    Returns (offload statuses, s0 host-state leaves, result logits)."""
    rng = np.random.RandomState(seed)
    lens = rng.randint(2, 9, size=5)
    eng = ServeEngine(params, cfg, n_slots=6, cache_len=32,
                      batch_buckets=(1, 2, 4), batched_offload=batched,
                      async_offload=async_off)
    for s in range(5):
        eng.create_session(f"s{s}")
        eng.ingest(f"s{s}", np.asarray(_tokens(100 * seed + s,
                                               int(lens[s]))))
    eng.run()
    mgr = eng._mgr["online"]
    # k victims at once, with a duplicate and an unknown mixed in
    res = mgr.offload_batch(["s0", "s1", "s2", "s0", "nope"])
    # mid-offload interleavings: queue work on an offloaded session
    # (restore), cancel another's queued work, close one while offloaded
    rq = eng.query("s1", np.asarray(_tokens(50 + seed, 3)))      # restore
    rc = eng.ingest("s3", np.asarray(_tokens(60 + seed, 4)))
    eng.close_session("s3")                                      # cancel
    eng.close_session("s2")                                      # offloaded
    r4 = eng.query("s4", np.asarray(_tokens(70 + seed, 2)))      # resident
    eng.run()
    mgr.sync()
    host0 = [np.asarray(x)
             for x in jax.tree.leaves(mgr.sessions["s0"].host_state)]
    assert rc.request.cancelled and rc.request.result is None
    return ([r.status for r in res], host0,
            [np.asarray(rq.request.result), np.asarray(r4.request.result)])


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batched_offload_bitexact_vs_per_victim(tiny_cfg, params, seed):
    """k-victim stacked offload/restore == per-victim path bit-for-bit:
    same no-op statuses, same host bytes, same post-restore logits —
    including interleaved cancel() and re-activation mid-offload, and
    with the async double-buffer on."""
    base = _offload_interleaved_trace(tiny_cfg, params, batched=False,
                                      async_off=False, seed=seed)
    for batched, async_off in ((True, False), (True, True)):
        got = _offload_interleaved_trace(tiny_cfg, params, batched=batched,
                                         async_off=async_off, seed=seed)
        assert got[0] == base[0] == ["offloaded", "offloaded", "offloaded",
                                     "already-offloaded", "unknown"]
        for a, b in zip(got[1], base[1]):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(got[2], base[2]):
            np.testing.assert_array_equal(a, b)


def test_offload_cost_model_decision():
    """Pure decision function: transfer cost is the round trip, replay
    cost is history tokens at the replay rate."""
    from repro.serve import OffloadCostModel
    cm = OffloadCostModel(host_bandwidth=1e9, replay_tokens_per_s=100.0)
    assert cm.transfer_seconds(5 * 10**8) == pytest.approx(1.0)
    assert cm.replay_seconds(50) == pytest.approx(0.5)
    assert cm.prefers_recompute(5 * 10**8, 50)        # 0.5 s < 1.0 s
    assert not cm.prefers_recompute(5 * 10**8, 200)   # 2.0 s > 1.0 s


def test_recompute_offload_replays_history(tiny_cfg, params):
    """A cost model that always prefers recompute drops the state (no
    host copy) and replays the session's recorded requests on the next
    activation; logits match the transfer path."""
    from repro.serve import OffloadCostModel
    chunk, query = np.asarray(_tokens(3, 6)), np.asarray(_tokens(4, 4))

    def run(cm):
        eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=32,
                          batch_buckets=(1, 2), offload_cost_model=cm)
        eng.create_session("u")
        eng.ingest("u", chunk)
        eng.run()
        r = eng.offload_session("u")
        q = eng.query("u", query).request
        eng.run()
        return r.status, np.asarray(q.result)

    always = OffloadCostModel(host_bandwidth=1.0, replay_tokens_per_s=1e12)
    s1, rec = run(always)
    s2, xfer = run(None)
    assert (s1, s2) == ("recompute", "offloaded")
    # replay runs the same B=1 programs here -> bit-exact; keep a small
    # tolerance anyway (replay is only numerically, not bitwise,
    # guaranteed when the original ops ran at a different batch shape)
    np.testing.assert_allclose(rec, xfer, atol=1e-5, rtol=0)


def test_shed_query_releases_exact_cache_reservation(tiny_cfg, params):
    """Regression: a query shed at SUBMIT time must leave the KV-cache
    token accounting exactly where it was — the old code decremented a
    reservation that was never made, under-counting the cache and
    letting a later oversized query slip past the exhaustion guard."""
    from repro.serve import Shed
    eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=16,
                      batch_buckets=(1, 2),
                      admission_policy="reject-new", max_queued_tokens=6)
    eng.create_session("u")
    v1 = eng.query("u", np.asarray(_tokens(0, 4)))   # cached: 4, queued: 4
    v2 = eng.query("u", np.asarray(_tokens(1, 5)))   # queue 4+5 > 6: shed
    assert isinstance(v2, Shed) and v2.request.shed
    assert eng._cached["u"] == 4      # reservation reversed, not drained
    eng.run()
    assert v1.request.done
    # 4 cached + 13 > cache_len 16: the guard must still fire (the old
    # under-count of 0 would have let this through to corrupt the cache)
    with pytest.raises(ValueError, match="cache exhausted"):
        eng.query("u", np.asarray(_tokens(2, 13)))
    # and a fitting query still passes
    v3 = eng.query("u", np.asarray(_tokens(3, 4)))
    eng.run()
    assert v3.request.done and eng._cached["u"] == 8


def test_explicit_quota_overrides_default_lane_cap(tiny_cfg, params):
    """Regression: a tenant with an explicit TenantQuota whose
    max_resident is None is residency-UNBOUNDED even when default_quota
    caps residency — batch formation must not throttle it to the
    default (one batch of 4, not 4 single-lane batches)."""
    from repro.serve import TenantQuota
    eng = ServeEngine(params, tiny_cfg, n_slots=6, cache_len=16,
                      batch_buckets=(1, 2, 4),
                      tenant_quotas={"vip": TenantQuota(
                          max_queued_tokens=100)},
                      default_quota=TenantQuota(max_resident=1))
    for s in range(4):
        eng.create_session(f"v{s}", tenant="vip")
        eng.ingest(f"v{s}", np.asarray(_tokens(s, 4)))
    eng.run()
    assert eng.stats["ingest"]["batches"] == 1    # one 4-lane batch
    # default-quota tenants ARE capped to one lane per batch
    for s in range(3):
        eng.create_session(f"d{s}")              # tenant="default"
        eng.ingest(f"d{s}", np.asarray(_tokens(10 + s, 4)))
    eng.run()
    assert eng.stats["ingest"]["batches"] == 4    # 1 + three 1-lane


def test_invalid_submit_leaves_no_reservation(tiny_cfg, params):
    """Regression: a shape-validation error at submit must raise with
    ZERO side effects — the old order reserved KV-cache tokens before
    validating, permanently inflating the session's accounting."""
    eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=16,
                      batch_buckets=(1, 2))
    eng.create_session("u")
    with pytest.raises(ValueError, match="one sequence"):
        eng.query("u", np.zeros((2, 5), np.int32))   # batched tokens
    assert eng._cached.get("u", 0) == 0              # nothing leaked
    v = eng.query("u", np.asarray(_tokens(0, 8)))    # 8 <= 16: admitted
    eng.run()
    assert v.request.done and eng._cached["u"] == 8


def test_zero_batch_run_syncs_async_offload(tiny_cfg, params):
    """Regression: run() on an empty queue must still barrier async
    offload transfers — `if n:` used to skip sync(), pinning the
    stacked host buffers of explicit offload_session() calls forever."""
    eng = ServeEngine(params, tiny_cfg, n_slots=2, cache_len=16,
                      batch_buckets=(1, 2), async_offload=True)
    eng.create_session("u")
    eng.ingest("u", np.asarray(_tokens(0, 4)))
    eng.run()
    assert eng.offload_session("u").status == "offloaded"
    mgr = eng._mgr["online"]
    assert len(mgr._inflight) == 1       # transfer in flight
    assert eng.run() == 0                # zero batches popped...
    assert len(mgr._inflight) == 0       # ...but the barrier still ran
