"""Pressure-ladder property suite: monotonicity proven on synthetic
tables AND on the real serve simulation.

The ISSUE-7 acceptance invariant is LADDER MONOTONICITY — the
controller never sheds while a cheaper lever was available.  Three
layers of evidence:

  1. a hypothesis fuzz over the PURE controller (synthetic session
     tables behind plain lambdas, no engine, no device): within every
     `relieve()` slice, all recompressions precede all offloads, a shed
     handoff appears only last and only with BOTH candidate lists empty
     at decision time; returned `freed` equals the sum the decision log
     claims; offload never victimizes a session with queued work;
     counters in the metrics registry match the log;
  2. a hypothesis fuzz over random traces through `ServeSimulation`
     with the controller wired into the REAL engine: every shed entry
     in the ladder log has zero remaining candidates, the seq numbers
     are strictly increasing, the arena free-list stays consistent, and
     at quiescence the drain hook has done its job (usage above the
     high watermark implies the levers are genuinely exhausted);
  3. a deterministic capacity sweep (runs even without hypothesis):
     controller-on sheds no more than levers-off at every capacity, and
     strictly less where the ladder has room to work — the bench
     acceptance criterion in miniature.

CI runs the derandomized "ci" hypothesis profile (conftest.py);
failures print a `@reproduce_failure` blob that replays locally.
"""
import math

import pytest

from repro.serve import PressurePolicy
from repro.serve.pressure import MemoryPressureController

# SIDS and the trace strategy come from the shared traffic model in
# tests/simulation.py (same vocabulary as the admission/deadline suites)
from simulation import SIDS, ServeSimulation, event_strategy

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

COMP_LEN = 2           # token value of one memory group in the model


# -- 1. pure-controller model checker -----------------------------------

class _Row:
    def __init__(self, sid, resident, last_used, mem_groups, kv, queued):
        self.sid = sid
        self.resident = resident
        self.last_used = last_used
        self.mem_groups = mem_groups
        self.kv = kv
        self.queued = queued


def _drive_synthetic(rows, queued_tokens, policy, deficits):
    """Run `relieve()` over a mutable synthetic table, checking every
    slice of the decision log against the ladder contract."""
    table = {r.sid: r for r in rows}

    def recompress(sid):
        r = table[sid]
        new_g = -(-r.mem_groups // policy.recompress_group)
        freed = (r.mem_groups - new_g) * COMP_LEN
        r.mem_groups = new_g
        return freed

    def offload(sid):
        table[sid].resident = False
        return type("R", (), {"moved": True})()

    ctl = MemoryPressureController(
        policy,
        sessions_fn=lambda: list(table.values()),
        footprint_fn=lambda s: table[s].mem_groups * COMP_LEN + table[s].kv,
        queued_tokens_fn=lambda: queued_tokens,
        has_queued_fn=lambda s: table[s].queued,
        recompress_fn=recompress,
        offload_fn=offload)

    # accounting recount
    want_used = queued_tokens + sum(
        r.mem_groups * COMP_LEN + r.kv for r in table.values() if r.resident)
    assert ctl.used_tokens() == want_used

    for deficit in deficits:
        before = len(ctl.decisions)
        groups_before = {s: r.mem_groups for s, r in table.items()}
        freed = ctl.relieve(deficit)
        slice_ = list(ctl.decisions)[before:]

        if deficit <= 0:
            assert freed == 0 and not slice_
            continue

        levers = [d["lever"] for d in slice_]
        # strict ladder order within the slice: recompress* offload* shed?
        order = {"recompress": 0, "offload": 1, "shed": 2}
        assert levers == sorted(levers, key=order.__getitem__), levers
        assert levers.count("shed") <= 1
        if "shed" in levers:
            assert levers[-1] == "shed"

        work = [d for d in slice_ if d["lever"] != "shed"]
        assert freed == sum(d["freed"] for d in work)
        for d in work:
            assert d["freed"] > 0
            if d["lever"] == "offload":
                r = table[d["sid"]]
                assert not r.queued, "offloaded a session with queued work"
                assert not r.resident        # the lever actually fired
            else:
                assert groups_before[d["sid"]] >= policy.min_groups
                assert table[d["sid"]].mem_groups < groups_before[d["sid"]]

        if freed >= deficit:
            assert "shed" not in levers
        else:
            # the monotonicity witness: the shed entry itself records
            # that no cheaper lever remained at decision time
            shed = slice_[-1]
            assert shed["lever"] == "shed"
            assert shed["recompress_candidates"] == 0
            assert shed["offload_candidates"] == 0
            assert shed["unmet"] == deficit - freed
            assert not ctl.recompress_candidates()
            assert not ctl.offload_candidates()

    # registry counters agree with the full log
    log = list(ctl.decisions)
    for lever in ("recompress", "offload", "shed"):
        got = int(ctl._m_decisions.labels(lever=lever).value)
        assert got == sum(1 for d in log if d["lever"] == lever)
    for lever in ("recompress", "offload"):
        got = ctl._m_freed.labels(lever=lever).value
        assert got == sum(d["freed"] for d in log
                          if d["lever"] == lever)
    # seq strictly increasing
    seqs = [d["seq"] for d in log]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    return ctl


if HAVE_HYPOTHESIS:
    rows_st = st.lists(
        st.builds(_Row,
                  sid=st.sampled_from(SIDS),
                  resident=st.booleans(),
                  last_used=st.integers(0, 50),
                  mem_groups=st.integers(0, 6),
                  kv=st.integers(0, 12),
                  queued=st.booleans()),
        min_size=0, max_size=5,
        unique_by=lambda r: r.sid)
    policy_st = st.builds(
        PressurePolicy,
        capacity_tokens=st.integers(1, 120),
        recompress_group=st.integers(2, 4),
        min_groups=st.integers(1, 3),
        enable_recompress=st.booleans(),
        enable_offload=st.booleans())

    @settings(max_examples=200, deadline=None)
    @given(rows=rows_st,
           queued_tokens=st.integers(0, 40),
           policy=policy_st,
           deficits=st.lists(st.integers(-5, 200), min_size=1,
                             max_size=6))
    def test_ladder_contract_synthetic(rows, queued_tokens, policy,
                                       deficits):
        _drive_synthetic(rows, queued_tokens, policy, deficits)


def test_ladder_contract_deterministic_sweep():
    """Hypothesis-free fallback: a seeded sweep through the same model
    checker (always runs, even where hypothesis is absent)."""
    import random
    rng = random.Random(1234)
    for _ in range(60):
        sids = rng.sample(SIDS, rng.randint(0, 5))
        rows = [_Row(s, rng.random() < 0.7, rng.randrange(50),
                     rng.randrange(7), rng.randrange(13),
                     rng.random() < 0.3) for s in sids]
        policy = PressurePolicy(
            capacity_tokens=rng.randint(1, 120),
            recompress_group=rng.randint(2, 4),
            min_groups=rng.randint(1, 3),
            enable_recompress=rng.random() < 0.8,
            enable_offload=rng.random() < 0.8)
        deficits = [rng.randint(-5, 200)
                    for _ in range(rng.randint(1, 6))]
        _drive_synthetic(rows, rng.randrange(40), policy, deficits)


# -- 2. real-engine fuzz -------------------------------------------------

def _check_pressure_trace(sim):
    eng = sim.engine
    ctl = eng.pressure
    for snap in sim.snapshots:
        assert not snap.consistency, snap.consistency
        assert snap.pressure_capacity == ctl.capacity
    log = list(ctl.decisions)
    seqs = [d["seq"] for d in log]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    for d in log:
        if d["lever"] == "shed":
            assert d["recompress_candidates"] == 0, d
            assert d["offload_candidates"] == 0, d
            assert d["unmet"] > 0
        else:
            assert d["freed"] > 0
    # mem_groups bookkeeping stays within the arena's representable range
    for s in eng._mgr["online"].sessions.values():
        assert 0 <= s.mem_groups <= eng._max_mem_groups
    # drain-hook liveness at quiescence: above the high watermark, the
    # cheap levers must be genuinely exhausted (else maybe_relieve would
    # have consumed them after the last batch)
    used = ctl.used_tokens()
    if used > ctl.policy.high_watermark * ctl.capacity:
        assert not ctl.recompress_candidates()
        assert not ctl.offload_candidates()


def _run_pressure_sim(cfg, conf, events):
    sim = ServeSimulation(
        cfg, n_slots=conf["n_slots"], cache_len=32,
        policy=conf["policy"],
        pressure_policy=PressurePolicy(
            capacity_tokens=conf["capacity"],
            enable_recompress=conf["recompress"],
            enable_offload=conf["offload"]))
    for ev in events:
        sim.apply(ev)
    sim.finish()
    _check_pressure_trace(sim)
    return sim


if HAVE_HYPOTHESIS:
    event_st = event_strategy(lengths=(2, 4, 8), tenants=("default",),
                              max_run=4)
    conf_st = st.fixed_dictionaries({
        "n_slots": st.integers(3, 5),
        "policy": st.sampled_from(("block", "shed-lowest-priority",
                                   "reject-new")),
        "capacity": st.integers(12, 64),
        "recompress": st.booleans(),
        "offload": st.booleans()})

    @settings(max_examples=60, deadline=None)
    @given(conf=conf_st,
           events=st.lists(event_st, min_size=4, max_size=30))
    def test_pressure_invariants_on_real_engine(tiny_cfg, conf, events):
        _run_pressure_sim(tiny_cfg, conf, events)


def test_pressure_invariants_deterministic_trace(tiny_cfg):
    """Hypothesis-free real-engine check: a fixed trace that exercises
    every lever (saturating ingest across 3 sessions, tight budget)."""
    events = [("create", s, "default") for s in ("s0", "s1", "s2")]
    for _ in range(8):
        events += [("submit", s, "ingest", 8, 0, "default")
                   for s in ("s0", "s1", "s2")]
        events += [("run", 8)]
    sim = _run_pressure_sim(
        tiny_cfg, {"n_slots": 4, "policy": "shed-lowest-priority",
                   "capacity": 26, "recompress": True, "offload": True},
        events)
    fired = {d["lever"] for d in sim.engine.pressure.decisions}
    assert "recompress" in fired and "shed" in fired


# -- 3. on/off capacity sweep (the bench criterion in miniature) ---------

@pytest.mark.parametrize("capacity", [20, 26, 32])
def test_controller_never_sheds_more_than_levers_off(tiny_cfg, capacity):
    def drive(on):
        sim = ServeSimulation(
            tiny_cfg, n_slots=4, cache_len=32,
            policy="shed-lowest-priority",
            pressure_policy=PressurePolicy(
                capacity_tokens=capacity,
                enable_recompress=on, enable_offload=on))
        for s in ("s0", "s1", "s2"):
            sim.apply(("create", s, "default"))
        for _ in range(8):
            for s in ("s0", "s1", "s2"):
                sim.apply(("submit", s, "ingest", 8, 0, "default"))
            sim.apply(("run", 8))
        sim.finish()
        _check_pressure_trace(sim)
        return sum(1 for r in sim._submitted if r.shed)

    shed_on, shed_off = drive(True), drive(False)
    assert shed_on <= shed_off, (capacity, shed_on, shed_off)
    if capacity == 26:                    # the bench's operating point
        assert shed_on < shed_off, (shed_on, shed_off)
