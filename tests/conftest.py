"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests see 1 device;
multi-device tests run in subprocesses (test_distributed.py)."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.core import masks as M
from repro.models.config import CCMConfig, ModelConfig

try:
    from hypothesis import settings as _hyp_settings
    # "ci" (selected via HYPOTHESIS_PROFILE in .github/workflows/ci.yml):
    # derandomized — property tests draw a fixed example sequence so CI
    # is deterministic; the default profile keeps fuzzing locally.
    # print_blob: a CI failure prints a @reproduce_failure blob that
    # replays the exact trace locally.  Suites that need more examples
    # (e.g. test_admission_properties: 200) override max_examples in
    # their own @settings; derandomize/print_blob are inherited.
    _hyp_settings.register_profile(
        "ci", derandomize=True, max_examples=60, deadline=None,
        print_blob=True)
    _hyp_settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:          # property tests skip without hypothesis
    pass


@pytest.fixture(scope="session")
def tiny_cfg():
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                       compute_dtype="float32",
                       ccm=CCMConfig(comp_len=2, max_steps=4))


@pytest.fixture(scope="session")
def tiny_layout():
    return M.segment_layout(t_steps=4, chunk_len=8, comp_len=2, tail_len=8)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
