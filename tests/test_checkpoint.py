"""Checkpoint manager: atomicity, integrity, async, gc, restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layers": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.arange(4.0)},
            "step_scalar": jnp.asarray(3, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    cm.save(7, t, extra={"iterator": {"step": 7, "seed": 0}})
    assert cm.latest() == 7
    restored, extra = cm.restore(7, jax.eval_shape(lambda: t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), restored, t)
    assert extra["iterator"]["step"] == 7


def test_async_save(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=True)
    cm.save(1, _tree())
    cm.wait()
    assert cm.latest() == 1


def test_gc_keeps_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s))
    assert cm.all_steps() == [3, 4]


def test_corruption_detected(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    cm.save(1, t)
    d = os.path.join(str(tmp_path), "step_0000000001")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, victim))
    np.save(os.path.join(d, victim), arr + 1)
    with pytest.raises(IOError, match="corruption"):
        cm.restore(1, jax.eval_shape(lambda: t))


def test_partial_checkpoint_ignored(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(1, _tree())
    # a crash mid-save leaves a .tmp dir — must not count as latest
    os.makedirs(os.path.join(str(tmp_path), "step_0000000002.tmp"))
    assert cm.latest() == 1
    # a dir without manifest is also skipped
    os.makedirs(os.path.join(str(tmp_path), "step_0000000003"))
    assert cm.latest() == 1


def test_restart_resumes_training(tmp_path):
    """TrainLoop: crash after N steps, restart resumes from checkpoint."""
    from repro.core import masks as M
    from repro.launch.train import TrainLoop
    from repro.models.config import CCMConfig, ModelConfig
    from repro.optim.adamw import AdamWConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                      train_mode="lora",
                      ccm=CCMConfig(comp_len=2, max_steps=2))
    layout = M.segment_layout(2, 6, 2, 8)
    mk = lambda: TrainLoop(cfg, layout, AdamWConfig(lr=1e-3, total_steps=20),
                           batch_size=4, ckpt_dir=str(tmp_path),
                           ckpt_every=5)
    loop = mk()
    loop.run(10, log_every=0)
    loop.ckpt.wait()
    loop2 = mk()
    start = loop2.maybe_restore()
    assert start == 10
    assert loop2.it.step == 10    # data order resumes, no replay
    h = loop2.run(12, start_step=start, log_every=0)
    assert len(h) == 2
