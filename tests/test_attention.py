"""Attention implementation equivalences + conditional-LoRA semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import masks as M
from repro.core.lora import cond_linear, init_lora, lora_scale
from repro.models import attention as A


def _rand_kv(key, B, Sq, Sk, Hq, Hkv, D):
    q = jax.random.normal(key, (B, Sq, Hq, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sk, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, Hkv, D))
    return q, k, v


@given(st.integers(0, 5), st.sampled_from([(4, 2), (4, 4), (8, 1)]),
       st.sampled_from([16, 24, 48]))
@settings(max_examples=12, deadline=None)
def test_chunked_equals_dense(seed, heads, Sq):
    Hq, Hkv = heads
    key = jax.random.PRNGKey(seed)
    lo = M.segment_layout(3, 6, 2, Sq - 24 if Sq > 24 else 8)
    S = lo.seq_len
    q, k, v = _rand_kv(key, 2, S, S, Hq, Hkv, 16)
    info = A.KeyInfo(idx=jnp.arange(S, dtype=jnp.int32), seg=lo.seg_ids,
                     comp=lo.comp_mask)
    dense = A.attend_dense(q, k, v, A.mask_from_info(info, info),
                           0.25)
    chunked = A.attend_chunked(q, k, v, info, info, 0.25, q_chunk=16,
                               k_chunk=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5)


def test_chunked_with_memory_prefix_and_padding():
    key = jax.random.PRNGKey(0)
    B, Sq, mem, Hq, Hkv, D = 2, 33, 7, 4, 2, 16   # deliberately unaligned
    q, _, _ = _rand_kv(key, B, Sq, Sq, Hq, Hkv, D)
    k = jax.random.normal(jax.random.fold_in(key, 3), (B, mem + Sq, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 4), (B, mem + Sq, Hkv, D))
    q_info = A.plain_causal_info(Sq)
    k_info = A.concat_info(
        A.mem_key_info(mem, valid=jnp.arange(mem) < 5),
        A.plain_causal_info(Sq))
    dense = A.attend_dense(q, k, v, A.mask_from_info(q_info, k_info), 0.25)
    chunked = A.attend_chunked(q, k, v, q_info, k_info, 0.25,
                               q_chunk=16, k_chunk=8)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5)


def test_gqa_grouping_matches_repeat():
    """GQA via grouping == materialized head repetition."""
    key = jax.random.PRNGKey(1)
    B, S, Hq, Hkv, D = 1, 16, 6, 2, 8
    q, k, v = _rand_kv(key, B, S, S, Hq, Hkv, D)
    info = A.plain_causal_info(S)
    out = A.attend_dense(q, k, v, A.mask_from_info(info, info), 0.3)
    k_rep = jnp.repeat(k, Hq // Hkv, axis=2)
    v_rep = jnp.repeat(v, Hq // Hkv, axis=2)
    out_rep = A.attend_dense(q, k_rep, v_rep,
                             A.mask_from_info(info, info), 0.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_rep),
                               atol=1e-5)


def test_cond_lora_zero_at_init_and_gated():
    key = jax.random.PRNGKey(0)
    lora = init_lora(key, 16, 8, rank=4)
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 8))
    x = jax.random.normal(jax.random.fold_in(key, 2), (3, 5, 16))
    gate = jnp.asarray([[0., 1., 0., 1., 0.]] * 3)
    # B=0 at init -> no delta anywhere
    y = cond_linear(x, w, lora, gate, scale=2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-6)
    # nonzero B: delta only at gated rows
    lora = {**lora, "b": jax.random.normal(jax.random.fold_in(key, 3),
                                           (4, 8))}
    y = cond_linear(x, w, lora, gate, scale=2.0)
    base = x @ w
    diff = np.abs(np.asarray(y - base)).sum(axis=-1)
    assert (diff[:, [0, 2, 4]] < 1e-6).all()
    assert (diff[:, [1, 3]] > 1e-4).all()


def test_rope_positions_shift_invariance():
    """RoPE attention depends only on relative positions."""
    from repro.models.layers import apply_rope, rope_cos_sin
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 8, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 2, 16))
    def logits(offset):
        pos = jnp.arange(8) + offset
        cos, sin = rope_cos_sin(pos, 16, 1e4)
        qr, kr = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        return jnp.einsum("bqhd,bkhd->bhqk", qr, kr)
    np.testing.assert_allclose(np.asarray(logits(0)),
                               np.asarray(logits(1000)), atol=1e-3)
