"""CCM mask / layout invariants (unit + hypothesis property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import masks as M

LAYOUT_STRAT = st.tuples(
    st.integers(1, 8),    # t_steps
    st.integers(2, 12),   # chunk_len
    st.integers(1, 4),    # comp_len
    st.integers(2, 12),   # tail_len
)


@given(LAYOUT_STRAT)
@settings(max_examples=40, deadline=None)
def test_layout_structure(args):
    t, lc, m, tail = args
    lo = M.segment_layout(t, lc, m, tail)
    assert lo.seq_len == t * (lc + m) + tail
    segs = np.asarray(lo.seg_ids)
    comp = np.asarray(lo.comp_mask)
    # exactly m comp tokens per context segment, none in the tail
    for j in range(1, t + 1):
        assert comp[segs == j].sum() == m
        # comp tokens are the last m of the segment
        seg_comp = comp[segs == j]
        assert seg_comp[-m:].all() and not seg_comp[:-m].any()
    assert not comp[segs == t + 1].any()
    assert (np.asarray(lo.positions) == np.arange(lo.seq_len)).all()


@given(LAYOUT_STRAT)
@settings(max_examples=30, deadline=None)
def test_concat_mask_semantics(args):
    """allow(q,k) = causal & (same_seg | comp_k) — and its consequences:
    no raw cross-segment leakage; tail sees all comp tokens; c(j) sees
    exactly Mem(j-1) + itself."""
    t, lc, m, tail = args
    lo = M.segment_layout(t, lc, m, tail)
    mask = np.asarray(M.ccm_mask_concat(lo.seg_ids, lo.comp_mask))
    segs = np.asarray(lo.seg_ids)
    comp = np.asarray(lo.comp_mask)
    S = lo.seq_len
    q_idx, k_idx = np.meshgrid(np.arange(S), np.arange(S), indexing="ij")
    causal = k_idx <= q_idx
    assert not (mask & ~causal).any(), "a-causal attention"
    # raw (non-comp) keys only visible within the same segment
    cross_raw = mask & ~comp[None, :] & (segs[:, None] != segs[None, :])
    assert not cross_raw.any(), "raw token leaked across segments"
    # tail rows see every earlier comp token
    tail_rows = segs == t + 1
    assert (mask[tail_rows][:, comp] == causal[tail_rows][:, comp]).all()


def test_merge_coefficients_mean():
    w = np.asarray(M.merge_coefficients(5, None))
    for j in range(5):
        expect = np.zeros(5)
        expect[:j + 1] = 1.0 / (j + 1)
        np.testing.assert_allclose(w[j], expect, rtol=1e-6)


@given(st.integers(1, 8), st.floats(0.05, 0.95))
@settings(max_examples=20, deadline=None)
def test_merge_coefficients_ema_sum_to_one(t, a):
    w = np.asarray(M.merge_coefficients(t, a))
    np.testing.assert_allclose(w.sum(axis=1), np.ones(t), rtol=1e-5)
    # recurrence check: Mem(t) = (1-a) Mem(t-1) + a h(t)
    for j in range(1, t):
        np.testing.assert_allclose(w[j, :j], (1 - a) * w[j - 1, :j],
                                   rtol=1e-5)
        np.testing.assert_allclose(w[j, j], a, rtol=1e-6)


def test_merge_slot_mask():
    lo = M.segment_layout(3, 4, 1, 4)
    sm = np.asarray(M.merge_slot_mask(lo.seg_ids, 3))
    segs = np.asarray(lo.seg_ids)
    # segment j attends slot j-1 only; segment 1 attends nothing
    for q in range(lo.seq_len):
        j = segs[q]
        want = np.zeros(3, bool)
        if j >= 2:
            want[j - 2] = True
        np.testing.assert_array_equal(sm[q], want)


def test_merge_virtual_kv_is_cummean():
    import jax
    t, m, H, D = 4, 2, 3, 5
    lo = M.segment_layout(t, 4, m, 4)
    k = jax.random.normal(jax.random.PRNGKey(0), (2, lo.seq_len, H, D))
    v = jax.random.normal(jax.random.PRNGKey(1), (2, lo.seq_len, H, D))
    mk, mv = M.merge_virtual_kv(k, v, lo.comp_mask, t, m, None)
    idx = np.nonzero(np.asarray(lo.comp_mask))[0]
    hk = np.asarray(k)[:, idx].reshape(2, t, m, H, D)
    run = np.cumsum(hk, axis=1) / np.arange(1, t + 1)[None, :, None, None, None]
    np.testing.assert_allclose(np.asarray(mk).reshape(2, t, m, H, D), run,
                               rtol=1e-5)


def test_comp_offset_array():
    lo = M.segment_layout(2, 3, 3, 2)
    off = np.asarray(M.comp_offset_array(lo.comp_mask))
    comp = np.asarray(lo.comp_mask)
    assert (off[~comp] == 0).all()
    assert (off[comp].reshape(2, 3) == [[0, 1, 2], [0, 1, 2]]).all()
