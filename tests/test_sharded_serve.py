"""Session-sharded serving: sharded arena layout, per-shard scheduler
pops, placement + wrong-shard routing, compacted stream-lane eviction,
and single-shard vs multi-shard bit-exactness.

The mesh (`shard_map`) hot path needs more than one device, so those
cases run in a SUBPROCESS with --xla_force_host_platform_device_count=4
(the test_distributed.py pattern); everything else exercises the loop
path in-process on the single main-process device — same control plane,
same batch formation, per-shard calls into the single-device fused step.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import streaming as ST
from repro.launch import serve as SRV
from repro.models import transformer as T
from repro.serve.arena import ArenaFull, SessionArena
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Scheduler

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def params(tiny_cfg):
    return T.init_lm(jax.random.PRNGKey(0), tiny_cfg)


def _toks(key, n, vocab=128):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(key), (n,),
                                         0, vocab))


# ---------------------------------------------------------------------------
# arena: sharded layout
# ---------------------------------------------------------------------------

def test_sharded_arena_layout(tiny_cfg):
    arena = SessionArena.for_online(tiny_cfg, n_slots=6, cache_len=16,
                                    n_shards=2)
    assert arena.slots_per_shard == 3
    assert list(arena.shard_slots(0)) == [0, 1, 2]
    assert arena.pad_slot_of(0) == 3
    assert list(arena.shard_slots(1)) == [4, 5, 6]
    assert arena.pad_slot_of(1) == 7
    # global rows: 2 * (3 slots + 1 scratch)
    assert jax.tree.leaves(arena.slabs)[0].shape[0] == 8
    for s in (0, 1):
        for slot in arena.shard_slots(s):
            assert arena.shard_of(slot) == s
        assert arena.local_row(arena.pad_slot_of(s)) == 3
    # per-shard free lists: shard 1 exhausts independently of shard 0
    got = [arena.alloc(1) for _ in range(3)]
    assert got == [4, 5, 6]
    with pytest.raises(ArenaFull, match="shard 1"):
        arena.alloc(1)
    assert arena.shard_free(0) == 3 and arena.shard_free(1) == 0
    assert arena.alloc(0) == 0
    arena.free(5)                       # shard inferred from the slot
    assert arena.shard_free(1) == 1 and arena.alloc(1) == 5
    assert not arena.consistency_errors()
    sample = arena.metrics_sample()
    assert len(sample["shards"]) == 2
    assert sample["shards"][1]["live"] == 3


def test_sharded_arena_rejects_indivisible(tiny_cfg):
    with pytest.raises(ValueError):
        SessionArena.for_online(tiny_cfg, n_slots=5, cache_len=16,
                                n_shards=2)


def test_single_shard_arena_matches_seed_layout(tiny_cfg):
    """n_shards=1 must be the exact seed layout: slots [0, n), one
    scratch row at n — nothing downstream can tell the difference."""
    arena = SessionArena.for_online(tiny_cfg, n_slots=3, cache_len=16)
    assert arena.n_shards == 1 and arena.slots_per_shard == 3
    assert arena.pad_slot == 3 and arena.pad_slot_of(0) == 3
    assert jax.tree.leaves(arena.slabs)[0].shape[0] == 4
    assert [arena.alloc() for _ in range(3)] == [0, 1, 2]


# ---------------------------------------------------------------------------
# scheduler: sharded pops
# ---------------------------------------------------------------------------

def _submit(sch, sid, kind, n, shard, **kw):
    req = sch.make_request(sid, kind, np.zeros(n, np.int32), **kw)
    req.shard = shard
    return sch.enqueue(req)


def test_sharded_pop_common_bucket_and_empty_shards():
    sch = Scheduler(batch_buckets=(1, 2, 4), token_buckets=(8,))
    _submit(sch, "a", "ingest", 8, 0)
    _submit(sch, "b", "ingest", 8, 0)
    _submit(sch, "c", "ingest", 8, 0)
    _submit(sch, "d", "ingest", 8, 2)
    sb = sch.next_sharded_batches(4)
    assert sb.kind == "ingest" and sb.token_len == 8
    # widest shard has 3 lanes -> every sub-batch padded to bucket 4
    assert sb.bucket == 4 and len(sb.shards) == 4
    assert [len(s.requests) for s in sb.shards] == [3, 0, 1, 0]
    assert all(s.bucket == 4 for s in sb.shards)
    assert [r.sid for r in sb.requests] == ["a", "b", "c", "d"]
    assert sb.n_requests == 4 and sch.next_sharded_batches(4) is None


def test_sharded_pop_per_shard_and_total_caps():
    sch = Scheduler(batch_buckets=(1, 2, 4, 8), token_buckets=(4,))
    for i in range(4):
        _submit(sch, f"a{i}", "ingest", 4, 0)
        _submit(sch, f"b{i}", "ingest", 4, 1)
    sb = sch.next_sharded_batches(2, per_shard_cap=2, max_total=3)
    assert [len(s.requests) for s in sb.shards] == [2, 1]
    # one pop = one aging round, regardless of shard count
    assert sch._round == 1
    sb2 = sch.next_sharded_batches(2, per_shard_cap={"ingest": 2},
                                   max_total={"ingest": 8})
    assert [len(s.requests) for s in sb2.shards] == [2, 2]


def test_sharded_pop_tenant_caps_apply_globally():
    """A tenant's lane cap bounds its lanes across the WHOLE pop (all
    shards sum), matching the one-activate_batch-call residency rule."""
    sch = Scheduler(batch_buckets=(1, 2, 4), token_buckets=(4,))
    for i, shard in enumerate((0, 0, 1, 1)):
        _submit(sch, f"t{i}", "ingest", 4, shard, tenant="t0")
    _submit(sch, "u", "ingest", 4, 1, tenant="t1")
    sb = sch.next_sharded_batches(2, tenant_lane_caps={"t0": 2})
    t0_lanes = [r.sid for r in sb.requests if r.tenant == "t0"]
    assert len(t0_lanes) == 2
    assert "u" in [r.sid for r in sb.requests]


def test_sharded_pop_rejects_out_of_range_shard():
    sch = Scheduler(batch_buckets=(1, 2), token_buckets=(4,))
    _submit(sch, "a", "ingest", 4, 3)
    with pytest.raises(ValueError, match="shard 3"):
        sch.next_sharded_batches(2)


# ---------------------------------------------------------------------------
# engine: placement, verdict routing, wrong-shard no-ops (loop path)
# ---------------------------------------------------------------------------

def _null_engine(cfg, n_shards, n_slots=4, **kw):
    return ServeEngine(None, cfg, n_slots=n_slots, cache_len=32,
                       n_shards=n_shards, step_factory=SRV.make_null_step,
                       batch_buckets=(1, 2, 4), token_buckets=(4, 8), **kw)


def test_placement_least_loaded_and_explicit(tiny_cfg):
    eng = _null_engine(tiny_cfg, 2)
    assert [eng.create_session(f"s{i}") for i in range(4)] == [0, 1, 0, 1]
    assert eng.shard_of("s2") == 0
    eng.close_session("s0")
    # the freed slot makes shard 0 least-loaded again
    assert eng.create_session("s4") == 0
    assert eng.create_session("s5", shard=1) == 1      # explicit pin
    with pytest.raises(ValueError):
        eng.create_session("s6", shard=2)


def test_verdict_carries_owning_shard(tiny_cfg):
    eng = _null_engine(tiny_cfg, 2)
    eng.create_session("a")
    eng.create_session("b")
    va = eng.ingest("a", _toks(0, 4))
    vb = eng.ingest("b", _toks(1, 4))
    assert va.shard == eng.shard_of("a") == 0
    assert vb.shard == eng.shard_of("b") == 1
    assert va.request.shard == 0 and vb.request.shard == 1


def test_wrong_shard_close_and_offload_are_structured_noops(tiny_cfg):
    """Routing a sid to the wrong shard must come back as a structured
    verdict — never a KeyError, never touching the session."""
    eng = _null_engine(tiny_cfg, 2)
    eng.create_session("a")                            # shard 0
    eng.ingest("a", _toks(0, 4))
    eng.run()
    wrong = (eng.shard_of("a") + 1) % 2
    res = eng.offload_session("a", shard=wrong)
    assert res.status == "wrong-shard" and res.sid == "a"
    assert eng._mgr["online"].sessions["a"].resident    # untouched
    res = eng.close_session("a", shard=wrong)
    assert res.status == "wrong-shard"
    assert "a" in eng._kind                             # still open
    # correct hint proceeds normally
    assert eng.offload_session("a", shard=eng.shard_of("a")).status \
        == "offloaded"
    assert eng.close_session("a", shard=0).status == "closed"
    assert "a" not in eng._kind


def test_mesh_requires_matching_shards_and_stock_steps(tiny_cfg):
    class FakeMesh:
        axis_names = ("data",)
        shape = {"data": 2}
    with pytest.raises(ValueError, match="shards"):
        ServeEngine(None, tiny_cfg, n_slots=4, mesh=FakeMesh(),
                    step_factory=SRV.make_null_step)


def test_sharded_gauges_render_in_prometheus(tiny_cfg):
    eng = _null_engine(tiny_cfg, 2)
    for i in range(3):
        eng.create_session(f"s{i}")
        eng.ingest(f"s{i}", _toks(i, 4))
    eng.run()
    text = eng.metrics_prometheus()
    assert 'serve_shard_occupancy{arena="online",shard="0"}' in text
    assert 'serve_shard_resident_sessions{arena="online",shard="1"}' in text
    assert 'serve_shard_queue_depth{shard="0"}' in text
    assert "serve_cross_shard_moves_total 0" in text


# ---------------------------------------------------------------------------
# bit-exactness: multi-shard vs single-shard (loop path, real params)
# ---------------------------------------------------------------------------

def _drive(params, cfg, n_shards, mesh=None):
    eng = ServeEngine(params, cfg, n_slots=4, cache_len=32,
                      n_shards=n_shards, mesh=mesh,
                      batch_buckets=(1, 2, 4), token_buckets=(4, 8))
    for i in range(4):
        eng.create_session(f"s{i}")
    reqs, k = [], 0
    for _ in range(2):
        for i in range(4):
            reqs.append(eng.ingest(f"s{i}", _toks(k, 8)).request)
            k += 1
        for i in range(4):
            reqs.append(eng.query(f"s{i}", _toks(k, 3 + i % 2)).request)
            k += 1
        eng.run()
    return eng, reqs


def test_multi_shard_loop_path_bit_exact_vs_single(params, tiny_cfg):
    """Identical mixed ragged traffic through a 1-shard and a 2-shard
    engine (loop path): every delivered logit row must match BIT-exactly
    — sharding only regroups lanes, it never changes a lane's math."""
    e1, r1 = _drive(params, tiny_cfg, 1)
    e2, r2 = _drive(params, tiny_cfg, 2)
    assert all(r.done for r in r1 + r2)
    for a, b in zip(r1, r2):
        if a.result is None:
            assert b.result is None
            continue
        np.testing.assert_array_equal(np.asarray(a.result),
                                      np.asarray(b.result))
    # steady state never moved a session across shards
    assert e2._m_cross_shard.value == 0
    errs = e2._mgr["online"].arena.consistency_errors()
    assert not errs, errs


# ---------------------------------------------------------------------------
# compacted stream-lane eviction (dense sub-batch) vs masked oracle
# ---------------------------------------------------------------------------

def test_compact_stream_eviction_bit_exact_vs_masked(params, tiny_cfg):
    """`stream_step_lanes(compact=True)` gathers pending lanes into a
    dense power-of-2 sub-batch before the compression pass; outputs and
    every state leaf must match the all-lanes masked path bit-exactly,
    across pending counts 0..N (each hitting a different bucket)."""
    from repro.models.config import CCMConfig
    cfg = tiny_cfg.replace(ccm=CCMConfig(
        comp_len=2, max_steps=4, stream_window=16, stream_sink=2,
        stream_chunk=4, stream_mem_slots=4))
    cc = cfg.ccm.stream_chunk
    n_lanes = 5

    def stacked_state(n_over):
        lanes = []
        for i in range(n_lanes):
            st = ST.init_stream_state(cfg, 1)
            # 4 warm chunks fill the 16-token window -> next chunk evicts
            for j in range(4 if i < n_over else 0):
                _, st = ST.stream_step(params, cfg, st,
                                       _toks(100 + i * 31 + j, cc)[None])
            lanes.append(st)
        return jax.tree.map(lambda *xs: np.stack(xs), *lanes)

    for n_over in (0, 1, 3, n_lanes):
        st = stacked_state(n_over)
        toks = np.stack([_toks(7 + i, cc)[None] for i in range(n_lanes)])
        lg_m, new_m = ST.stream_step_lanes(params, cfg, st, toks,
                                           compact=False)
        lg_c, new_c = ST.stream_step_lanes(params, cfg, st, toks,
                                           compact=True)
        np.testing.assert_array_equal(np.asarray(lg_m), np.asarray(lg_c))
        for a, b in zip(jax.tree.leaves(new_m), jax.tree.leaves(new_c)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# mesh hot path (subprocess, 4 forced CPU devices)
# ---------------------------------------------------------------------------

def _run(body: str, devices: int = 4, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    prelude = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.config import ModelConfig, CCMConfig
        from repro.models import transformer as T
        from repro.serve.engine import ServeEngine
        from repro.launch.mesh import make_session_mesh

        cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=128, compute_dtype="float32",
                          ccm=CCMConfig(comp_len=2, max_steps=4))
        params = T.init_lm(jax.random.PRNGKey(0), cfg)

        def toks(key, n):
            return np.asarray(jax.random.randint(
                jax.random.PRNGKey(key), (n,), 0, 128))

        def drive(n_shards, mesh=None, n_sessions=8):
            eng = ServeEngine(params, cfg, n_slots=8, cache_len=32,
                              n_shards=n_shards, mesh=mesh,
                              batch_buckets=(1, 2, 4),
                              token_buckets=(4, 8))
            for i in range(n_sessions):
                eng.create_session(f"s{i}")
            reqs, k = [], 0
            for _ in range(2):
                for i in range(n_sessions):
                    reqs.append(eng.ingest(f"s{i}", toks(k, 8)).request)
                    k += 1
                for i in range(n_sessions):
                    reqs.append(
                        eng.query(f"s{i}", toks(k, 3 + i % 2)).request)
                    k += 1
                eng.run()
            return eng, reqs
    """)
    r = subprocess.run([sys.executable, "-c",
                        prelude + textwrap.dedent(body)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_mesh_engine_bit_exact_vs_single_shard():
    """THE acceptance gate: 4-shard engine on a 4-device session mesh
    (shard_map hot path, donated shard-resident slabs) returns BIT-exact
    results vs the 1-shard engine on identical mixed ragged traffic,
    with zero cross-shard session moves."""
    out = _run("""
        assert jax.device_count() == 4
        e1, r1 = drive(1)
        e4, r4 = drive(4, mesh=make_session_mesh(4))
        assert all(r.done for r in r1 + r4)
        for a, b in zip(r1, r4):
            if a.result is None:
                assert b.result is None
                continue
            assert np.array_equal(np.asarray(a.result),
                                  np.asarray(b.result))
        assert e4._m_cross_shard.value == 0
        assert [e4.shard_of(f"s{i}") for i in range(8)] \\
            == [0, 1, 2, 3, 0, 1, 2, 3]
        errs = e4._mgr["online"].arena.consistency_errors()
        assert not errs, errs
        print("BITEXACT", len(r1))
    """)
    assert "BITEXACT 32" in out


def test_mesh_arena_rows_live_on_owning_devices():
    """Each shard's row block (slots + scratch) must be resident on its
    own mesh device, and per-shard offload must keep it there."""
    out = _run("""
        mesh = make_session_mesh(4)
        eng, _ = drive(4, mesh=mesh)
        leaf = jax.tree.leaves(eng._mgr["online"].arena.slabs)[0]
        shardmap = {d: idx for d, idx in
                    leaf.sharding.devices_indices_map(leaf.shape).items()}
        assert len(shardmap) == 4
        stride = leaf.shape[0] // 4
        for d, idx in shardmap.items():
            rows = idx[0]
            assert rows.stop - rows.start == stride
        eng.offload_session("s0")
        eng.query("s0", toks(999, 4))   # restore via the serve path
        eng.run()
        leaf2 = jax.tree.leaves(eng._mgr["online"].arena.slabs)[0]
        assert len(leaf2.sharding.device_set) == 4
        print("PLACED")
    """)
    assert "PLACED" in out
