"""End-to-end system behaviour: the compressed memory demonstrably carries
task information after REAL training (miniature of paper Fig. 6), and the
serving path consumes strictly less KV than full context."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C
from repro.core import inference as I
from repro.data.synthetic import sample_kv_batch
from repro.models import transformer as T
from repro.models.config import CCMConfig


@pytest.fixture(scope="module")
def trained():
    base = C.pretrain_base(steps=800, lr=3e-3)
    cfg = C.bench_cfg()
    params = C.train_compression(base, cfg, steps=800, lr=3e-3)
    return base, cfg, params


def test_compression_beats_no_context(trained):
    """Accuracy from compressed memory must clearly beat no-context —
    the core claim that Mem(t) carries C(t)'s information."""
    base, cfg, params = trained
    acc = C.eval_at_timesteps(params, cfg, ts=(4,))[4]
    from benchmarks.tables import _eval_no_context
    acc0 = _eval_no_context(base, cfg, ts=(4,))[4]
    assert acc > acc0 + 0.08, (acc, acc0)


def test_accuracy_improves_with_time_steps(trained):
    """More compressed context -> better answers (paper Fig. 7 trend).

    Two fixes over the old seed-sensitive assertion:

    - queries are drawn from the WHOLE key space (``query_pool="all"``),
      so accumulating chunks adds answerable evidence — the quantity the
      paper's trend is about.  The old eval queried only keys already
      shown in context, which measures per-retrieval fidelity and
      *falls* with t for any lossy memory (each query reads 4x more
      compressed material at t=4 with zero added evidence), inverting
      the trend at every seed.
    - the trend is averaged over several eval seeds: a single 96-example
      draw is noisy enough to blur it; the paper's claim is about the
      expectation."""
    _, cfg, params = trained
    seeds = (99, 100, 101, 102, 103)
    acc1 = acc4 = 0.0
    for seed in seeds:
        accs = C.eval_at_timesteps(params, cfg, ts=(1, 4), seed=seed,
                                   query_pool="all")
        acc1 += accs[1] / len(seeds)
        acc4 += accs[4] / len(seeds)
    assert acc4 >= acc1 + 0.02, (acc1, acc4)


def test_online_inference_matches_training_eval(trained):
    """Serving path (ingest->prefill) reproduces the parallel-eval logits
    — deployment behaves like training said it would."""
    _, cfg, params = trained
    layout = C.layout_for(4)
    batch = sample_kv_batch(jax.random.PRNGKey(11), layout, 8, C.TASK)
    toks = batch["tokens"]
    state = I.init_online_state(cfg, 8, max_cache_len=32)
    sl = layout.chunk_len + layout.comp_len
    m = cfg.ccm.comp_len
    for j in range(4):
        state = I.ingest_context(params, cfg, state,
                                 toks[:, j * sl:(j + 1) * sl - m])
    tail = toks[:, 4 * sl:]
    lg_o, _ = I.prefill(params, cfg, state, tail, full_logits=True)
    lg_p = T.train_forward(params, cfg, toks, layout)
    np.testing.assert_allclose(np.asarray(lg_o[:, -1]),
                               np.asarray(lg_p[:, -1]), atol=5e-3)


def test_memory_strictly_smaller_than_context(trained):
    _, cfg, params = trained
    t, lc, m = 4, C.CHUNK, cfg.ccm.comp_len
    state = I.init_online_state(cfg, 2, max_cache_len=16)
    layout = C.layout_for(t)
    batch = sample_kv_batch(jax.random.PRNGKey(1), layout, 2, C.TASK)
    sl = layout.chunk_len + layout.comp_len
    for j in range(t):
        state = I.ingest_context(params, cfg, state,
                                 batch["tokens"][:, j * sl:(j + 1) * sl - m])
    # memory holds exactly t*m KV tokens; raw context was t*lc
    assert int(state.mem.valid_len(m)) == t * m
    assert t * m < t * lc
    assert int(state.cache.length) == 0   # raw context never cached
