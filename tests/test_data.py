"""Synthetic data pipeline properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import masks as M
from repro.data.synthetic import (COMP, KVTaskConfig, ShardableIndexIterator,
                                  lm_stream, sample_kv_batch)


@given(st.integers(1, 6), st.integers(2, 8), st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_kv_batch_answers_in_context(t, npairs, seed):
    """Every queried key's (key, value) pair appeared in some context chunk
    — the compressible-signal guarantee."""
    task = KVTaskConfig(n_keys=16, n_vals=16)
    layout = M.segment_layout(t, 2 * npairs, 2, 8)
    b = sample_kv_batch(jax.random.PRNGKey(seed), layout, 3, task)
    toks = np.asarray(b["tokens"])
    segs = np.asarray(layout.seg_ids)
    comp = np.asarray(layout.comp_mask)
    ctx = toks[:, (segs <= t) & ~comp]           # raw context tokens
    tail = toks[:, segs == t + 1]
    lm = np.asarray(b["loss_mask"])
    for i in range(3):
        pairs = set(zip(ctx[i][0::2], ctx[i][1::2]))
        for pos in np.nonzero(lm[i])[0]:
            k_tok, v_tok = tail[i, pos], tail[i, pos + 1]
            assert (k_tok, v_tok) in pairs


def test_kv_batch_comp_positions():
    layout = M.segment_layout(3, 6, 2, 8)
    b = sample_kv_batch(jax.random.PRNGKey(0), layout, 2)
    toks = np.asarray(b["tokens"])
    comp = np.asarray(layout.comp_mask)
    assert (toks[:, comp] == COMP).all()
    assert (toks[:, ~comp] != COMP).all()


def test_iterator_deterministic_and_restartable():
    it1 = ShardableIndexIterator(seed=3, batch_per_host=4)
    keys1 = [np.asarray(it1.next_key()) for _ in range(5)]
    it2 = ShardableIndexIterator(seed=3, batch_per_host=4)
    it2.load_state_dict({"step": 3, "seed": 3})
    np.testing.assert_array_equal(np.asarray(it2.next_key()), keys1[3])
    # different hosts draw different keys
    ita = ShardableIndexIterator(seed=3, batch_per_host=4, n_hosts=2,
                                 host_id=1)
    assert not np.array_equal(np.asarray(ita.key_for(0)),
                              np.asarray(keys1[0]))


def test_lm_stream_in_vocab():
    toks = lm_stream(jax.random.PRNGKey(0), 2, 256, 64)
    assert int(toks.min()) >= 0 and int(toks.max()) < 64
