"""Serve-invariant property tests for the continuous-batching scheduler.

Random submit / cancel / drain traces are executed against `Scheduler`
and a model checker asserts, on every popped batch and at end of trace:

  * program order per session — a session's requests drain in submission
    order, never reordered by priority or token bucketing;
  * one request per session per batch — no duplicate sids in a batch;
  * priority-with-aging monotonicity — the batch head minimizes
    (effective priority, submission seq) over the eligible set at pop
    time, where effective priority ages down as rounds pass;
  * token-bucket membership — every request fits the batch's padded
    token length, which is the head's bucket (capped per kind);
  * terminal accounting — every submitted request ends ``done`` exactly
    once: either cancelled, or delivered in exactly one batch.

The checker is shared between a hypothesis fuzz (CI runs it with the
fixed "ci" profile, see conftest.py) and a seeded deterministic sweep
that runs even where hypothesis is not installed.
"""
import numpy as np
import pytest

from repro.serve.scheduler import Scheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KINDS = ("ingest", "query", "stream")
SIDS = tuple(f"s{i}" for i in range(6))
LENGTHS = (1, 2, 3, 5, 8, 13, 16)
TOKEN_BUCKETS = (2, 4, 8, 16)
BATCH_BUCKETS = (1, 2, 4)
MAX_TOKEN_LEN = {"stream": 8}


def check_token_len(sch: Scheduler, batch, head) -> None:
    """Independent statement of the bucket-choice contract (not a copy
    of the scheduler's own computation, so a bucketing regression cannot
    self-certify)."""
    tb = sch.token_buckets
    if tb is None:
        assert batch.token_len == head.token_len
        return
    cap = sch.max_token_len.get(batch.kind)
    # never truncates the head's request
    assert batch.token_len >= head.token_len
    # the padded length is a real bucket, unless the head itself exceeds
    # every admissible bucket (then it runs at its exact length)
    assert batch.token_len in tb or batch.token_len == head.token_len
    # per-kind cap respected whenever the head fits under it
    if cap is not None:
        assert batch.token_len <= max(cap, head.token_len)
    # minimality: any smaller bucket that fits the head must have been
    # inadmissible (over the kind's cap) — no oversized shapes compiled
    for b in tb:
        if head.token_len <= b < batch.token_len:
            assert cap is not None and b > cap


def run_trace(ops, aging, token_buckets):
    """Execute a trace and assert every serve invariant."""
    sch = Scheduler(batch_buckets=BATCH_BUCKETS, token_buckets=token_buckets,
                    max_token_len=dict(MAX_TOKEN_LEN), aging=aging)
    submitted = []            # every Request, in submission order
    pending = []              # mirror of the scheduler's queue
    delivered = {}            # id(req) -> number of batches it appeared in
    drain_log = []            # requests in the order they drained

    def pop_and_check():
        # eligible set and effective priorities BEFORE the pop (the pop
        # advances the aging clock)
        earliest = {}
        for r in pending:
            if r.sid not in earliest or r.seq < earliest[r.sid].seq:
                earliest[r.sid] = r
        elig = sorted(earliest.values(),
                      key=lambda r: (sch.effective_priority(r), r.seq))
        batch = sch.next_batch()
        if not elig:
            assert batch is None
            return None
        assert batch is not None and batch.requests
        head = batch.requests[0]
        # priority-with-aging monotonicity: the head is the minimum of
        # the eligible order — a starved request whose effective priority
        # aged below the flood's must win the pop
        assert head is elig[0]
        # one request per session per batch
        sids = [r.sid for r in batch.requests]
        assert len(set(sids)) == len(sids)
        # token-bucket membership + uniform kind
        check_token_len(sch, batch, head)
        for r in batch.requests:
            assert r.kind == batch.kind
            assert r.token_len <= batch.token_len
            if token_buckets is None:
                assert r.token_len == batch.token_len
        assert len(batch.requests) <= batch.bucket <= max(
            max(BATCH_BUCKETS), len(batch.requests))
        assert batch.valid_lens == [r.token_len for r in batch.requests]
        for r in batch.requests:
            assert not r.cancelled
            delivered[id(r)] = delivered.get(id(r), 0) + 1
            pending.remove(r)
            drain_log.append(r)
        return batch

    for op in ops:
        if op[0] == "submit":
            _, sid, kind, length, priority = op
            r = sch.submit(sid, kind, np.zeros(length, np.int32),
                           priority=priority)
            submitted.append(r)
            pending.append(r)
        elif op[0] == "cancel":
            dropped = sch.cancel(op[1])
            for r in dropped:
                assert r.cancelled and r.done
                pending.remove(r)
        else:  # drain one batch
            pop_and_check()
    while pop_and_check() is not None:
        pass
    assert sch.pending == 0 and not pending

    # terminal accounting: every submitted request reaches exactly one
    # terminal outcome — cancelled (flagged done by cancel()) or handed
    # to exactly one batch (the engine flags done at delivery)
    for r in submitted:
        assert delivered.get(id(r), 0) == (0 if r.cancelled else 1)
        assert r.done == r.cancelled
    # program order per session: the DRAIN order of a session's requests
    # equals its submission order (cancelled ones excluded)
    drained_per_sid, submitted_per_sid = {}, {}
    for r in drain_log:
        drained_per_sid.setdefault(r.sid, []).append(r.seq)
    for r in submitted:
        if not r.cancelled:
            submitted_per_sid.setdefault(r.sid, []).append(r.seq)
    assert drained_per_sid == submitted_per_sid


def _random_ops(rng, n):
    ops = []
    for _ in range(n):
        roll = rng.rand()
        if roll < 0.6:
            ops.append(("submit", SIDS[rng.randint(len(SIDS))],
                        KINDS[rng.randint(len(KINDS))],
                        int(LENGTHS[rng.randint(len(LENGTHS))]),
                        int(rng.randint(0, 4))))
        elif roll < 0.75:
            ops.append(("cancel", SIDS[rng.randint(len(SIDS))]))
        else:
            ops.append(("drain",))
    return ops


@pytest.mark.parametrize("aging", [0, 1, 3])
@pytest.mark.parametrize("token_buckets", [None, TOKEN_BUCKETS])
def test_seeded_traces_uphold_invariants(aging, token_buckets):
    """Deterministic sweep of the same checker (runs without hypothesis)."""
    rng = np.random.RandomState(1234 + aging)
    for _ in range(25):
        run_trace(_random_ops(rng, 40), aging, token_buckets)


if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.sampled_from(SIDS),
                      st.sampled_from(KINDS), st.sampled_from(LENGTHS),
                      st.integers(0, 3)),
            st.tuples(st.just("cancel"), st.sampled_from(SIDS)),
            st.tuples(st.just("drain")),
        ), max_size=60)

    @given(ops=OPS, aging=st.sampled_from([0, 1, 3]),
           token_buckets=st.sampled_from([None, TOKEN_BUCKETS]))
    @settings(max_examples=120, deadline=None)
    def test_property_traces_uphold_invariants(ops, aging, token_buckets):
        run_trace(ops, aging, token_buckets)
else:
    def test_property_traces_uphold_invariants():
        pytest.skip("property fuzz needs hypothesis")
