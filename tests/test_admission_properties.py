"""Admission/backpressure property suite over the serve simulation.

Random admit -> schedule -> offload -> restore -> cancel traces run
through `tests/simulation.py` (REAL engine/scheduler/session/arena
objects, null compute step) and a model checker asserts, after every
event and at end of trace:

  1. conservation — no request lost or duplicated: every submitted
     request ends in exactly one terminal state (delivered in exactly
     one batch, cancelled, or shed) and is flagged ``done``;
  2. per-tenant quotas never exceeded — resident sessions and queued
     tokens per tenant stay within `TenantQuota` at every step, and the
     controller's token accounting matches a recount of the raw queue;
  3. global bounds — resident count <= ``max_resident`` and queued
     tokens <= ``max_queued_tokens`` at every step;
  4. shed discipline — a shed victim always has STRICTLY lower
     effective priority (aging included) than the request that
     displaced it; non-shedding policies never displace queued work;
  5. backpressure liveness — blocked submits drain once capacity
     frees: after a final drain the backlog and queue are empty;
  6. arena integrity — the free list never double-frees or leaks a
     slot (checked after every event), and every live session ends
     resident, offloaded, or fresh — `ArenaFull` escaping anywhere
     fails the trace.

The checker is shared between a hypothesis fuzz (200 examples; CI runs
the fixed derandomized "ci" profile, see conftest.py — failures print a
`@reproduce_failure` blob that replays locally) and a seeded
deterministic sweep that runs even where hypothesis is not installed.
"""
import numpy as np
import pytest

from repro.serve import OffloadCostModel, TenantQuota
from repro.serve.admission import POLICIES, Queued, Shed

# the trace/config vocabulary (SIDS, LENGTHS, ...) and both trace
# generators are shared with the pressure and deadline suites — one
# traffic model, three checkers
from simulation import (ServeSimulation, event_strategy, expand_event,
                        random_events)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# offload cost models the fuzz sweeps: None (no recording), a model
# that always prefers recompute (state dropped, history replayed through
# the real activation path on every offload), and one that never does
# (recording on, transfer path taken) — the replay/eviction/cancel
# interleavings are exactly where a recompute regression would hide
COST_MODELS = {
    "none": None,
    "always-recompute": OffloadCostModel(host_bandwidth=1.0,
                                         replay_tokens_per_s=1e12),
    "never-recompute": OffloadCostModel(host_bandwidth=1e15,
                                        replay_tokens_per_s=1e-6),
}


def build_sim(cfg, conf) -> ServeSimulation:
    quotas = None
    if conf["quota_resident"] is not None or conf["quota_tokens"] is not None:
        quotas = {"t0": TenantQuota(max_resident=conf["quota_resident"],
                                    max_queued_tokens=conf["quota_tokens"])}
    default_quota = (TenantQuota(max_resident=conf["default_resident"])
                     if conf["default_resident"] is not None else None)
    return ServeSimulation(
        cfg, n_slots=conf["n_slots"], max_resident=conf["max_resident"],
        policy=conf["policy"], max_queued_tokens=conf["max_queued_tokens"],
        max_backlog=conf.get("max_backlog"),
        quotas=quotas, default_quota=default_quota,
        aging=conf["aging"], batched_offload=conf["batched"],
        async_offload=conf["async"],
        offload_cost_model=COST_MODELS[conf.get("cost_model", "none")],
        n_shards=conf.get("n_shards", 1))


def check_snapshot(snap, conf) -> None:
    # 6. arena integrity: free list consistent after EVERY event
    assert not snap.consistency, snap.consistency
    # 3. global residency bound
    assert snap.n_resident <= snap.max_resident
    # 2. per-tenant quotas + accounting-vs-recount agreement
    for t, n in snap.tenant_resident.items():
        cap = _resident_cap(t, conf)
        if cap is not None:
            assert n <= cap, (t, n, cap)
    tenants = set(snap.queued_tokens) | set(snap.true_queued_tokens)
    for t in tenants:
        acct = snap.queued_tokens.get(t, 0)
        true = snap.true_queued_tokens.get(t, 0)
        assert acct == true, f"accounting drift for {t}: {acct} != {true}"
        tq = _token_quota(t, conf)
        if tq is not None:
            assert true <= tq, (t, true, tq)
    assert snap.queued_tokens_total == sum(
        snap.true_queued_tokens.values())
    # 3. global queued-token bound
    if conf["max_queued_tokens"] is not None:
        assert snap.queued_tokens_total <= conf["max_queued_tokens"]
    # 3b. block-policy backlog bound (entries)
    if conf.get("max_backlog") is not None:
        assert snap.backlog <= conf["max_backlog"]
    # 8. sharded-arena invariants: the per-shard ledgers tile the
    # global ones exactly (sessions never migrate, so residency and
    # free slots decompose shard-by-shard at every step)
    n_shards = conf.get("n_shards", 1)
    assert snap.n_shards == n_shards
    assert len(snap.shard_resident) == n_shards
    assert sum(snap.shard_resident) == snap.n_resident
    spp = conf["n_slots"] // n_shards
    for s in range(n_shards):
        assert 0 <= snap.shard_resident[s] <= spp, (s, snap.shard_resident)
        assert snap.shard_free[s] == spp - snap.shard_resident[s], \
            (s, snap.shard_free, snap.shard_resident)


def _resident_cap(tenant, conf):
    if tenant == "t0" and conf["quota_resident"] is not None:
        return conf["quota_resident"]
    return conf["default_resident"]


def _token_quota(tenant, conf):
    if tenant == "t0":
        return conf["quota_tokens"]
    return None


def _hard_cap(tenant, conf):
    caps = [c for c in (_token_quota(tenant, conf),
                        conf["max_queued_tokens"]) if c is not None]
    return min(caps) if caps else None


def run_trace(cfg, events, conf) -> None:
    """Execute a trace and assert every admission/serve invariant.
    `ArenaFull` (or any other exception) escaping the engine fails the
    trace — overflow must always resolve to a structured verdict."""
    sim = build_sim(cfg, conf)
    prev_counters = None
    for ev in events:
        snap = sim.apply(expand_event(ev))
        check_snapshot(snap, conf)
        # 7. admission counters are MONOTONIC across events (the pump
        # counts under 'pumped' instead of mutating 'admitted')
        if prev_counters is not None:
            for k, v in snap.admission_counters.items():
                assert v >= prev_counters[k], (
                    f"counter {k} went backwards: "
                    f"{prev_counters[k]} -> {v}")
        prev_counters = snap.admission_counters
    check_snapshot(sim.finish(), conf)

    # 5. backpressure liveness: a final drain empties queue AND backlog
    assert sim.engine.scheduler.pending == 0
    assert len(sim.engine.admission.backlog) == 0

    # 1. conservation: exactly one terminal outcome per request
    acc = sim.accounting()
    for r in acc.submitted:
        n_batches = acc.delivered.get(id(r), 0)
        assert r.done, f"request {r.sid}/{r.kind} never resolved"
        if r.shed or r.cancelled:
            assert n_batches == 0, "terminal request also ran in a batch"
            assert not (r.shed and r.cancelled), "two terminal outcomes"
        else:
            assert n_batches == 1, \
                f"request ran in {n_batches} batches (lost or duplicated)"

    # 4. shed discipline
    for req, eff_new, victims in sim.shed_log:
        assert conf["policy"] == "shed-lowest-priority"
        for v, eff_v in victims:
            assert eff_v > eff_new, \
                f"shed victim eff={eff_v} not strictly lower-priority " \
                f"than incoming eff={eff_new}"
            assert v.shed and v.done
            assert v.sid != req.sid
    if conf["policy"] != "shed-lowest-priority":
        assert not sim.shed_log
    # non-shed policies shed a NEW request only when it could never fit
    for ev, verdict in sim.verdicts:
        if isinstance(verdict, Shed) and conf["policy"] != \
                "shed-lowest-priority":
            hard = _hard_cap(verdict.request.tenant, conf)
            if conf["policy"] == "block":
                oversized = hard is not None \
                    and verdict.request.token_len > hard
                backlog_full = "backlog full" in verdict.reason
                assert oversized or (backlog_full
                                     and conf.get("max_backlog")
                                     is not None), \
                    "block policy shed a request that could have waited"
        if isinstance(verdict, Queued):
            assert conf["policy"] == "block"

    # 6. every surviving session is in a legal terminal state
    assert set(sim.session_states().values()) <= {
        "resident", "offloaded", "fresh"}
    # engine stats agree with the ledger (nothing delivered off-book)
    delivered = sum(1 for r in acc.submitted
                    if not r.shed and not r.cancelled)
    assert sum(s["requests"]
               for s in sim.engine.stats.values()) == delivered


# ---------------------------------------------------------------------------
# deterministic sweep (runs without hypothesis)
# ---------------------------------------------------------------------------

def _random_conf(rng) -> dict:
    return {
        "policy": POLICIES[rng.randint(len(POLICIES))],
        "max_queued_tokens": (None, 12, 24)[rng.randint(3)],
        "quota_resident": (None, 1, 2)[rng.randint(3)],
        "quota_tokens": (None, 8, 16)[rng.randint(3)],
        "default_resident": (None, 2)[rng.randint(2)],
        "n_slots": (2, 4)[rng.randint(2)],
        "max_resident": (None, 2)[rng.randint(2)],
        "batched": bool(rng.randint(2)),
        "async": bool(rng.randint(2)),
        "aging": (0, 3)[rng.randint(2)],
        "cost_model": tuple(COST_MODELS)[rng.randint(len(COST_MODELS))],
        "max_backlog": (None, 2)[rng.randint(2)],
        # n_slots is 2 or 4, so 2 shards always divide evenly
        "n_shards": (1, 2)[rng.randint(2)],
    }


def test_seeded_traces_uphold_invariants(tiny_cfg):
    """Deterministic sweep of the same checker (runs without
    hypothesis)."""
    rng = np.random.RandomState(20260729)
    for _ in range(40):
        run_trace(tiny_cfg, random_events(rng, 35), _random_conf(rng))


def test_sharded_placement_balances_and_no_shard_starves(tiny_cfg):
    """Seeded 2-shard sweep: least-loaded auto-placement keeps the open
    sessions per shard within one of each other at every step (no
    closes), and no shard starves while another sheds — every shard
    that carried surviving traffic delivered all of it exactly once."""
    rng = np.random.RandomState(20260808)
    conf = {"policy": "shed-lowest-priority", "max_queued_tokens": 12,
            "quota_resident": None, "quota_tokens": None,
            "default_resident": None, "n_slots": 4, "max_resident": None,
            "batched": True, "async": False, "aging": 3, "n_shards": 2}
    for _ in range(8):
        sim = build_sim(tiny_cfg, conf)
        for ev in random_events(rng, 30):
            if ev[0] == "close":
                continue              # closes would skew the balance probe
            snap = sim.apply(ev)
            check_snapshot(snap, conf)
            assert max(snap.shard_open) - min(snap.shard_open) <= 1, \
                snap.shard_open
        check_snapshot(sim.finish(), conf)
        acc = sim.accounting()
        per_shard_delivered = [0, 0]
        per_shard_shed = [0, 0]
        for r in acc.submitted:
            assert r.done
            if r.shed:
                per_shard_shed[r.shard] += 1
            elif not r.cancelled:
                assert acc.delivered.get(id(r), 0) == 1
                per_shard_delivered[r.shard] += 1
        # liveness across shards: wherever sheds landed, the OTHER
        # shard's surviving work still drained (delivered above), and a
        # shard only came up empty if it truly had nothing survive
        for s in (0, 1):
            survivors = sum(1 for r in acc.submitted
                            if r.shard == s and not r.shed
                            and not r.cancelled)
            assert per_shard_delivered[s] == survivors


def test_backpressure_blocks_then_drains(tiny_cfg):
    """block policy: a submit over the tenant token quota is Queued (not
    shed, not enqueued), stays queued while the bound holds, and drains
    exactly once capacity frees."""
    conf = {"policy": "block", "max_queued_tokens": None,
            "quota_resident": None, "quota_tokens": 8,
            "default_resident": None, "n_slots": 3, "max_resident": None,
            "batched": True, "async": False, "aging": 0}
    sim = build_sim(tiny_cfg, conf)
    sim.apply(("submit", "s0", "ingest", 8, 0, "t0"))   # fills the quota
    snap = sim.apply(("submit", "s3", "ingest", 5, 0, "t0"))  # blocked
    _, v0 = sim.verdicts[0]
    _, v1 = sim.verdicts[1]
    assert type(v1).__name__ == "Queued" and snap.backlog == 1
    assert snap.queued_tokens["t0"] == 8
    snap = sim.apply(("run", 1))      # s0 pops -> pump admits s3
    assert snap.backlog == 0 and snap.queued_tokens["t0"] == 5
    sim.finish()
    assert v1.request.done and not v1.request.shed


def test_shed_policy_strict_priority(tiny_cfg):
    """shed-lowest-priority only displaces strictly-lower-priority
    queued work; an equal-priority newcomer is itself shed."""
    conf = {"policy": "shed-lowest-priority", "max_queued_tokens": 8,
            "quota_resident": None, "quota_tokens": None,
            "default_resident": None, "n_slots": 3, "max_resident": None,
            "batched": True, "async": False, "aging": 0}
    sim = build_sim(tiny_cfg, conf)
    sim.apply(("submit", "s0", "ingest", 8, 3, "t0"))    # low priority
    sim.apply(("submit", "s1", "ingest", 8, 1, "t1"))    # higher: sheds s0
    _, v0 = sim.verdicts[0]
    _, v1 = sim.verdicts[1]
    assert v0.request.shed and v0.request.done
    assert type(v1).__name__ == "Admitted"
    assert [v.sid for v in v1.shed_victims] == ["s0"]
    # equal priority: the NEWCOMER is shed, the queue is untouched
    sim.apply(("submit", "s2", "ingest", 8, 1, "t2"))
    _, v2 = sim.verdicts[2]
    assert isinstance(v2, Shed) and v2.request.shed
    assert not v1.request.shed
    sim.finish()
    run_trace(tiny_cfg, [], conf)     # empty trace sanity


def test_oversized_request_shed_under_every_policy(tiny_cfg):
    """A request that could NEVER fit its bound is shed immediately —
    blocking it would deadlock the backlog."""
    for policy in POLICIES:
        conf = {"policy": policy, "max_queued_tokens": 4,
                "quota_resident": None, "quota_tokens": None,
                "default_resident": None, "n_slots": 2,
                "max_resident": None, "batched": True, "async": False,
                "aging": 0}
        sim = build_sim(tiny_cfg, conf)
        sim.apply(("submit", "s0", "ingest", 13, 0, "t0"))
        _, v = sim.verdicts[0]
        assert isinstance(v, Shed) and v.request.shed and v.request.done
        sim.finish()


# ---------------------------------------------------------------------------
# hypothesis fuzz (200 examples; CI pins the derandomized profile)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    EVENTS = st.lists(event_strategy(), max_size=40)

    CONFIGS = st.fixed_dictionaries({
        "policy": st.sampled_from(POLICIES),
        "max_queued_tokens": st.sampled_from((None, 12, 24)),
        "quota_resident": st.sampled_from((None, 1, 2)),
        "quota_tokens": st.sampled_from((None, 8, 16)),
        "default_resident": st.sampled_from((None, 2)),
        "n_slots": st.sampled_from((2, 4)),
        "max_resident": st.sampled_from((None, 2)),
        "batched": st.booleans(),
        "async": st.booleans(),
        "aging": st.sampled_from((0, 3)),
        "cost_model": st.sampled_from(tuple(COST_MODELS)),
        "max_backlog": st.sampled_from((None, 2)),
        "n_shards": st.sampled_from((1, 2)),
    })

    @given(events=EVENTS, conf=CONFIGS)
    @settings(max_examples=200, deadline=None)
    def test_property_traces_uphold_invariants(tiny_cfg, events, conf):
        run_trace(tiny_cfg, events, conf)
else:
    def test_property_traces_uphold_invariants():
        pytest.skip("property fuzz needs hypothesis")
