"""Established context-compression baselines (paper §4 comparisons),
expressed in the same parallelized-training framework as CCM.

  Gisting-online (Mu et al. 2023, adapted to online use as in the paper):
      each chunk c(j) is compressed INDEPENDENTLY — its <COMP> tokens see
      only c(j) (not Mem(j-1)); inference concatenates the per-chunk gists.
      Mask: causal AND (same_seg OR (comp_k AND q in tail)).

  Compressive Transformer (Rae et al. 2020): old raw KV are pooled by a
      fixed function (mean-pool groups) into a shorter memory; implemented
      as per-segment virtual slots = mean-pooled raw-KV of that segment,
      visible to later segments and the tail.

Both train with the same conditional-LoRA budget and compression factor as
CCM (paper's fair-comparison protocol).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import masks as M


def gisting_online_mask(seg_ids: jnp.ndarray, comp_mask: jnp.ndarray,
                        t_steps: int) -> jnp.ndarray:
    """(S, S) bool: chunks are independent; gists visible only to the tail;
    gist tokens see their own chunk only."""
    S = seg_ids.shape[0]
    q_idx = jnp.arange(S)[:, None]
    k_idx = jnp.arange(S)[None, :]
    causal = k_idx <= q_idx
    same = seg_ids[:, None] == seg_ids[None, :]
    tail_q = (seg_ids == t_steps + 1)[:, None]
    return causal & (same | (comp_mask[None, :] & tail_q))


def compressive_virtual_kv(k: jnp.ndarray, v: jnp.ndarray,
                           seg_ids: jnp.ndarray, comp_mask: jnp.ndarray,
                           t_steps: int, comp_len: int
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-segment mean-pooled raw KV -> (B, T*m, H, D) memory slots.

    Segment j's chunk (raw tokens only) is pooled into ``comp_len`` slots
    (pool factor = chunk_len / comp_len — the paper-matched compression
    rate)."""
    B, S, H, D = k.shape
    raw = np.asarray(~np.asarray(comp_mask))
    segs = np.asarray(seg_ids)
    m = comp_len
    slots_k, slots_v = [], []
    for j in range(1, t_steps + 1):
        idx = np.nonzero(raw & (segs == j))[0]
        usable = (len(idx) // m) * m
        idx = jnp.asarray(idx[:usable])
        kj = k[:, idx].reshape(B, m, usable // m, H, D).mean(axis=2)
        vj = v[:, idx].reshape(B, m, usable // m, H, D).mean(axis=2)
        slots_k.append(kj)
        slots_v.append(vj)
    return (jnp.concatenate(slots_k, axis=1),
            jnp.concatenate(slots_v, axis=1))


def compressive_slot_mask(seg_ids: jnp.ndarray, t_steps: int,
                          comp_len: int) -> jnp.ndarray:
    """(Q, T*m): segment q attends every pooled slot of segments < seg_q."""
    slot_seg = jnp.repeat(jnp.arange(1, t_steps + 1), comp_len)[None, :]
    return slot_seg < seg_ids[:, None]
