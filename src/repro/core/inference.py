"""Online inference: g_comp / g_update / memory-conditioned decoding.

This is the runtime half of the paper (Eq. 1-3): contexts c(t) arrive and are
*compressed* (never cached raw); inputs I(t) are prefetched into a bounded KV
cache attending [Mem(t), I(t)]; decoding attends [Mem(t), cache].

Every function is functional state-in/state-out with fixed shapes, so each
online step is one jitted XLA program (dry-runnable with ShapeDtypeStructs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import masks as M
from repro.core.memory import MemState, init_memory, mem_layers, update_memory
from repro.distributed.context import DistContext
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.models.scan_utils import scan_layers


class KVCache(NamedTuple):
    k: jnp.ndarray        # (L, B, Smax, Hkv, hd) — bf16 or int8 (quantized)
    v: jnp.ndarray
    length: jnp.ndarray   # () int32 — filled positions
    k_scale: Optional[jnp.ndarray] = None   # (L, B, Smax, Hkv) if int8
    v_scale: Optional[jnp.ndarray] = None

    @property
    def max_len(self) -> int:
        return self.k.shape[2]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def quantize_kv(x: jnp.ndarray):
    """per-(token, head) symmetric int8: x (..., hd) -> (q, scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return q.astype(dtype) * scale[..., None].astype(dtype)


class SSMState(NamedTuple):
    ssm: jnp.ndarray      # (L, B, H, P, N)
    conv: jnp.ndarray     # (L, B, K-1, C)


class OnlineState(NamedTuple):
    cache: Optional[KVCache] = None
    mem: Optional[MemState] = None
    ssm: Optional[SSMState] = None
    cross: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
    pos: Optional[jnp.ndarray] = None   # () int32 virtual stream position


# ---------------------------------------------------------------------------
# state init
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               n_layers: Optional[int] = None) -> KVCache:
    Lc = n_layers if n_layers is not None else mem_layers(cfg)
    shape = (max(Lc, 1), batch, max_len, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_cache_dtype == "int8":
        z = jnp.zeros(shape, jnp.int8)
        sc = jnp.zeros(shape[:-1], jnp.float32)
        return KVCache(k=z, v=z, length=jnp.zeros((), jnp.int32),
                       k_scale=sc, v_scale=sc)
    z = jnp.zeros(shape, cfg.cdtype)
    return KVCache(k=z, v=z, length=jnp.zeros((), jnp.int32))


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K, C = cfg.ssm_conv, cfg.d_inner + 2 * cfg.ssm_state
    Ls = cfg.n_layers
    return SSMState(
        ssm=jnp.zeros((Ls, batch, H, P, N), cfg.cdtype),
        conv=jnp.zeros((Ls, batch, max(K - 1, 1), C), cfg.cdtype))


def init_online_state(cfg: ModelConfig, batch: int, max_cache_len: int,
                      mem_slots: Optional[int] = None) -> OnlineState:
    st: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("ssm", "hybrid"):
        st["ssm"] = init_ssm_state(cfg, batch)
    if cfg.family != "ssm":
        st["cache"] = init_cache(cfg, batch, max_cache_len)
        if cfg.ccm.enabled:
            st["mem"] = init_memory(cfg, batch, mem_slots)
    return OnlineState(**st)


# ---------------------------------------------------------------------------
# attention over [mem | cache | self] for a block of new tokens
# ---------------------------------------------------------------------------

def _attend_online(cfg, q, k_new, v_new, self_info: A.KeyInfo,
                   q_info: A.KeyInfo,
                   mem_kv=None, mem_valid=None,
                   cache_kv=None, cache_len=None, cache_scales=None,
                   cache_layer=None, impl=None):
    """q over [mem?, cache(:length)?, self] KV segments read IN PLACE.

    k_new/v_new are this block's KV.  No concatenated KV or KeyInfo is
    materialized (the segmented attend folds a running softmax across the
    segments); an int8 cache is passed quantized with ``cache_scales``
    and dequantized tile-wise inside the attend.  With ``cache_layer``,
    ``cache_kv`` is the STACKED (L, B, Smax, Hkv, hd) cache and blocks
    are sliced straight out of layer ``cache_layer`` — a scanned layer
    body never copies its layer's cache slice.
    """
    segs = []
    if mem_kv is not None:
        segs.append(A.KVSegment(k=mem_kv[0], v=mem_kv[1], length=mem_valid))
    if cache_kv is not None:
        ks, vs = cache_scales if cache_scales is not None else (None, None)
        segs.append(A.KVSegment(k=cache_kv[0], v=cache_kv[1],
                                length=cache_len, k_scale=ks, v_scale=vs,
                                layer=cache_layer))
    segs.append(A.KVSegment(k=k_new, v=v_new, info=self_info))
    return A.attend_segments(cfg, q, segs, q_info, impl=impl)


def _write_cache(ck, cv, k_new, v_new, at, valid_len=None):
    """Append this block's KV at ``at``.  With ``valid_len`` (ragged lane)
    only the first ``valid_len`` tokens are written — pad positions of the
    cache stay bit-identical to an unpadded run."""
    if valid_len is not None:
        ck = M.ragged_block_write(ck, k_new, at, valid_len, axis=1)
        cv = M.ragged_block_write(cv, v_new, at, valid_len, axis=1)
        return ck, cv
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k_new.astype(ck.dtype), at, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v_new.astype(cv.dtype), at, 1)
    return ck, cv


# ---------------------------------------------------------------------------
# generic attention-stack pass over new tokens (prefill / decode / compress)
# ---------------------------------------------------------------------------

def _attn_stack_pass(params, cfg: ModelConfig, x, positions, *,
                     comp_gate, q_info, self_info, state: OnlineState,
                     write_to_cache: bool, collect_comp: Optional[jnp.ndarray],
                     dist: Optional[DistContext], impl=None, valid_len=None):
    """Runs the layer stack for dense/moe/vlm/encdec families.

    Returns (x, new_cache, comp_kv) where comp_kv is (L, B, m, Hkv, hd)
    pairs when ``collect_comp`` (bool (S,) selector) is given.

    ``valid_len`` (ragged lane): only that many leading tokens of the
    block are real; cache writes past them are frozen and the length
    counter advances by ``valid_len`` instead of the padded block length.
    """
    cache, mem = state.cache, state.mem
    mem_valid = mem.valid_len(cfg.ccm.comp_len) if mem is not None else None
    cross = state.cross
    quant = cache is not None and cache.quantized
    # loop-invariant: the <COMP> gather index is the same every layer —
    # computed once per step, not inside the scanned body
    comp_idx = jnp.nonzero(collect_comp, size=cfg.ccm.comp_len)[0] \
        if collect_comp is not None else None

    # The stacked cache rides the scan CARRY, not xs/ys: the attend
    # slices k-blocks straight out of layer li (KVSegment.layer) and the
    # write touches a block-sized window — no per-layer slice copy in,
    # no per-layer full-cache stack out.
    def body(carry, xs):
        h, cst = carry
        lp, li = xs["lp"], xs["li"]
        hn = L.apply_norm(cfg, lp["ln1"], h)
        q, k_new, v_new = A.qkv_project(
            cfg, lp["attn"], hn, comp_gate,
            positions if cfg.pos_embed == "rope" else None)
        o = _attend_online(
            cfg, q, k_new, v_new, self_info, q_info,
            mem_kv=(xs["mk"], xs["mv"]) if mem is not None else None,
            mem_valid=mem_valid,
            cache_kv=(cst["ck"], cst["cv"]) if cache is not None else None,
            cache_len=cache.length if cache is not None else None,
            cache_scales=(cst["ks"], cst["vs"]) if quant else None,
            cache_layer=li if cache is not None else None, impl=impl)
        h = h + A.out_project(cfg, lp["attn"], o, comp_gate)
        if cross is not None:
            xk, xv = xs["cross"]
            hx = L.apply_norm(cfg, lp["ln_x"], h)
            qx, _, _ = A.qkv_project(cfg, lp["xattn"], hx, None, None)
            ox = A.attend_dense(qx, xk, xv, None, 1.0 / cfg.hd ** 0.5)
            h = h + A.out_project(cfg, lp["xattn"], ox, None)
        hn = L.apply_norm(cfg, lp["ln2"], h)
        if "moe" in lp:
            h = h + MOE.apply_moe(cfg, lp["moe"], hn, dist)
        else:
            h = h + L.apply_mlp(cfg, lp["mlp"], hn)
        if write_to_cache:
            at = cache.length
            if quant:
                qk, sk = quantize_kv(k_new)
                qv, sv = quantize_kv(v_new)
                cst = {"ck": M.layer_window_write(cst["ck"], qk, li, at,
                                                 valid_len),
                       "cv": M.layer_window_write(cst["cv"], qv, li, at,
                                                 valid_len),
                       "ks": M.layer_window_write(cst["ks"], sk, li, at,
                                                 valid_len),
                       "vs": M.layer_window_write(cst["vs"], sv, li, at,
                                                 valid_len)}
            else:
                cst = {"ck": M.layer_window_write(cst["ck"], k_new, li, at,
                                                 valid_len),
                       "cv": M.layer_window_write(cst["cv"], v_new, li, at,
                                                 valid_len)}
        outs = {}
        if collect_comp is not None:
            outs["comp"] = (k_new[:, comp_idx], v_new[:, comp_idx])
        return (h, cst), outs

    Ld = jax.tree.leaves(params["layers"])[0].shape[0]
    xs = {"lp": params["layers"], "li": jnp.arange(Ld, dtype=jnp.int32)}
    if mem is not None:
        xs["mk"], xs["mv"] = mem.k, mem.v
    if cross is not None:
        xs["cross"] = cross
    cst = {}
    if cache is not None:
        cst = {"ck": cache.k, "cv": cache.v}
        if quant:
            cst["ks"], cst["vs"] = cache.k_scale, cache.v_scale
    (x, cst), outs = scan_layers(cfg.unroll_layers, body, (x, cst), xs)

    new_cache = cache
    if write_to_cache and cache is not None:
        adv = x.shape[1] if valid_len is None else valid_len
        new_cache = KVCache(k=cst["ck"], v=cst["cv"],
                            length=cache.length + adv,
                            k_scale=cst.get("ks"), v_scale=cst.get("vs"))
    comp_kv = outs.get("comp") if collect_comp is not None else None
    return x, new_cache, comp_kv


# ---------------------------------------------------------------------------
# SSM / hybrid passes
# ---------------------------------------------------------------------------

def _ssm_stack_pass(params, cfg: ModelConfig, x, state: SSMState,
                    decode: bool):
    def body(h, xs):
        lp, s_ssm, s_conv = xs
        out, ns = T._mamba_block(cfg, lp, h,
                                 {"ssm": s_ssm, "conv": s_conv}, decode)
        return out, (ns["ssm"], ns["conv"])

    x, (n_ssm, n_conv) = scan_layers(
        cfg.unroll_layers, body, x,
        (params["layers"], state.ssm, state.conv))
    return x, SSMState(ssm=n_ssm, conv=n_conv)


def _hybrid_pass(params, cfg: ModelConfig, x, positions, *, comp_gate,
                 q_info, self_info, state: OnlineState, write_to_cache,
                 collect_comp, dist, decode: bool, impl=None):
    """Zamba2: grouped mamba scans + shared attention sites with CCM."""
    n_groups, g, rem = T._hybrid_sites(cfg)
    stacked = params["layers"]
    head = jax.tree.map(lambda a: a[:n_groups * g].reshape(
        (n_groups, g) + a.shape[1:]), stacked)
    tail = jax.tree.map(lambda a: a[n_groups * g:], stacked)
    st_head = jax.tree.map(lambda a: a[:n_groups * g].reshape(
        (n_groups, g) + a.shape[1:]), state.ssm)
    st_tail = jax.tree.map(lambda a: a[n_groups * g:], state.ssm)
    sa = params["shared_attn"]
    cache, mem = state.cache, state.mem
    mem_valid = mem.valid_len(cfg.ccm.comp_len) if mem is not None else None
    comp_idx = jnp.nonzero(collect_comp, size=cfg.ccm.comp_len)[0] \
        if collect_comp is not None else None

    new_states, new_ck, new_cv, comp_ks, comp_vs = [], [], [], [], []
    for gi in range(n_groups):
        grp = jax.tree.map(lambda a: a[gi], head)
        gst = jax.tree.map(lambda a: a[gi], st_head)
        x, nst = _ssm_stack_pass(params={"layers": grp}, cfg=cfg, x=x,
                                 state=SSMState(*gst), decode=decode)
        new_states.append(nst)
        # shared attention site gi
        hn = L.apply_norm(cfg, sa["ln1"], x)
        q, k_new, v_new = A.qkv_project(
            cfg, sa["attn"], hn, comp_gate,
            positions if cfg.pos_embed == "rope" else None)
        o = _attend_online(
            cfg, q, k_new, v_new, self_info, q_info,
            mem_kv=(mem.k[gi], mem.v[gi]) if mem is not None else None,
            mem_valid=mem_valid,
            cache_kv=(cache.k[gi], cache.v[gi]) if cache is not None else None,
            cache_len=cache.length if cache is not None else None, impl=impl)
        x = x + A.out_project(cfg, sa["attn"], o, comp_gate)
        hn = L.apply_norm(cfg, sa["ln2"], x)
        x = x + L.apply_mlp(cfg, sa["mlp"], hn)
        if write_to_cache and cache is not None:
            nk, nv = _write_cache(cache.k[gi], cache.v[gi], k_new, v_new,
                                  cache.length)
            new_ck.append(nk); new_cv.append(nv)
        if collect_comp is not None:
            comp_ks.append(k_new[:, comp_idx])
            comp_vs.append(v_new[:, comp_idx])
    if rem:
        x, nst = _ssm_stack_pass(params={"layers": tail}, cfg=cfg, x=x,
                                 state=SSMState(*st_tail), decode=decode)
    else:
        nst = SSMState(*st_tail)

    # reassemble ssm states (n_groups*g + rem layers)
    grp_ssm = jnp.concatenate([s.ssm for s in new_states]) if new_states \
        else state.ssm[:0]
    grp_conv = jnp.concatenate([s.conv for s in new_states]) if new_states \
        else state.conv[:0]
    new_ssm = SSMState(ssm=jnp.concatenate([grp_ssm, nst.ssm]),
                       conv=jnp.concatenate([grp_conv, nst.conv]))
    new_cache = cache
    if write_to_cache and cache is not None:
        new_cache = KVCache(k=jnp.stack(new_ck), v=jnp.stack(new_cv),
                            length=cache.length + x.shape[1])
    comp_kv = (jnp.stack(comp_ks), jnp.stack(comp_vs)) if comp_ks else None
    return x, new_cache, new_ssm, comp_kv


# ---------------------------------------------------------------------------
# public online ops
# ---------------------------------------------------------------------------

def _embed_block(cfg, params, tokens, positions, comp_mask=None,
                 comp_offset=None):
    x = T.embed_tokens(cfg, params, tokens, comp_mask, comp_offset)
    if cfg.pos_embed == "learned":
        x = T._add_learned_pos(cfg, params["pos_embed"], x, positions)
    return x


def ingest_context(params, cfg: ModelConfig, state: OnlineState,
                   chunk_tokens: jnp.ndarray,
                   dist: Optional[DistContext] = None,
                   valid_len=None) -> OnlineState:
    """Online step for a new context c(t): compress into memory (attention
    archs), update recurrent states (SSM/hybrid). Raw KV is NOT cached.

    ``valid_len`` (ragged lane, attention archs only): the chunk is padded
    up to a token bucket and only the first ``valid_len`` tokens are real.
    Pad tokens are masked out of attention, the <COMP> group keeps the
    RoPE positions of the *unpadded* layout, and the stream-position /
    memory counters advance by ``valid_len`` — the resulting state is
    bit-identical to ingesting the unpadded chunk.
    """
    B, lc = chunk_tokens.shape
    m = cfg.ccm.comp_len
    if cfg.family in ("ssm", "hybrid") and valid_len is not None:
        raise ValueError(
            f"ragged ingest (valid_len) unsupported for {cfg.family!r}: "
            "recurrent state updates cannot skip pad tokens")
    if cfg.family == "ssm":
        x = _embed_block(cfg, params, chunk_tokens,
                         state.pos + jnp.arange(lc))
        x, new_ssm = _ssm_stack_pass(params, cfg, x, state.ssm, decode=False)
        return state._replace(ssm=new_ssm, pos=state.pos + lc)

    S = lc + m
    ar = jnp.arange(S)
    comp_mask = ar >= lc
    comp_off = jnp.maximum(ar - lc, 0)
    tokens = jnp.concatenate(
        [chunk_tokens, jnp.zeros((B, m), chunk_tokens.dtype)], axis=1)
    if valid_len is None:
        positions = state.pos + ar
        k_valid = None
        consumed = S
    else:
        vl = jnp.asarray(valid_len, jnp.int32)
        # <COMP> tokens sit at padded indices [lc, S) but must carry the
        # unpadded stream positions [vl, vl + m) for train-consistent RoPE
        positions = state.pos + jnp.where(comp_mask, vl + (ar - lc), ar)
        k_valid = M.lane_valid(S, vl, tail_start=lc)
        consumed = vl + m
    x = _embed_block(cfg, params, tokens, positions, comp_mask, comp_off)
    comp_gate = jnp.broadcast_to(comp_mask.astype(cfg.cdtype)[None], (B, S))
    self_info = A.KeyInfo(idx=jnp.arange(S, dtype=jnp.int32),
                          seg=jnp.ones((S,), jnp.int32), comp=comp_mask,
                          valid=k_valid)
    q_info = self_info

    if cfg.family == "hybrid":
        x, _, new_ssm, comp_kv = _hybrid_pass(
            params, cfg, x, positions, comp_gate=comp_gate, q_info=q_info,
            self_info=self_info, state=state, write_to_cache=False,
            collect_comp=comp_mask, dist=dist, decode=False)
        h_k, h_v = comp_kv
        new_mem = update_memory(cfg, state.mem, h_k, h_v, S)
        return state._replace(ssm=new_ssm, mem=new_mem, pos=state.pos + S)

    x, _, comp_kv = _attn_stack_pass(
        params, cfg, x, positions, comp_gate=comp_gate, q_info=q_info,
        self_info=self_info, state=state, write_to_cache=False,
        collect_comp=comp_mask, dist=dist)
    h_k, h_v = comp_kv
    new_mem = update_memory(cfg, state.mem, h_k, h_v, consumed)
    return state._replace(mem=new_mem, pos=state.pos + consumed)


def prefill(params, cfg: ModelConfig, state: OnlineState,
            tokens: jnp.ndarray, dist: Optional[DistContext] = None,
            patches: Optional[jnp.ndarray] = None,
            impl: Optional[str] = None, full_logits: bool = False,
            valid_len=None):
    """Process input I(t) attending [Mem(t), self-causal]; KV cached.

    Returns (logits, new_state) — last position only unless full_logits.

    ``valid_len`` (ragged lane, attention archs only): tokens beyond it
    are bucket padding — masked out of attention, frozen out of the KV
    cache, and excluded from the pos/length counters.  Logits at pad
    positions are garbage; callers slice by their valid length.
    """
    B, S = tokens.shape
    if cfg.family in ("ssm", "hybrid") and valid_len is not None:
        raise ValueError(
            f"ragged prefill (valid_len) unsupported for {cfg.family!r}: "
            "recurrent state updates cannot skip pad tokens")
    if valid_len is not None and not full_logits:
        # last-position logits would come from a masked pad token —
        # garbage with no error; ragged callers must slice full logits
        raise ValueError(
            "ragged prefill (valid_len) requires full_logits=True: the "
            "last padded position is masked; slice logits[:, :valid_len]")
    positions = state.pos + jnp.arange(S)
    x = _embed_block(cfg, params, tokens, positions)
    if patches is not None:
        pe = patches.astype(cfg.cdtype) @ params["frontend"]["proj"].astype(cfg.cdtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
    if cfg.family == "ssm":
        x, new_ssm = _ssm_stack_pass(params, cfg, x, state.ssm, decode=False)
        logits = T.lm_logits(params, cfg, x if full_logits else x[:, -1:])
        return logits, state._replace(ssm=new_ssm, pos=state.pos + S)

    if valid_len is None:
        k_valid, adv = None, S
    else:
        adv = jnp.asarray(valid_len, jnp.int32)
        k_valid = M.lane_valid(S, adv)
    self_info = A.KeyInfo(idx=jnp.arange(S, dtype=jnp.int32),
                          seg=jnp.ones((S,), jnp.int32),
                          comp=jnp.zeros((S,), bool),
                          valid=k_valid)
    q_info = self_info
    if cfg.family == "hybrid":
        x, new_cache, new_ssm, _ = _hybrid_pass(
            params, cfg, x, positions, comp_gate=None, q_info=q_info,
            self_info=self_info, state=state, write_to_cache=True,
            collect_comp=None, dist=dist, decode=False, impl=impl)
        logits = T.lm_logits(params, cfg, x if full_logits else x[:, -1:])
        return logits, state._replace(cache=new_cache, ssm=new_ssm,
                                      pos=state.pos + S)
    x, new_cache, _ = _attn_stack_pass(
        params, cfg, x, positions, comp_gate=None, q_info=q_info,
        self_info=self_info, state=state, write_to_cache=True,
        collect_comp=None, dist=dist, impl=impl, valid_len=valid_len)
    logits = T.lm_logits(params, cfg, x if full_logits else x[:, -1:])
    return logits, state._replace(cache=new_cache, pos=state.pos + adv)


def decode_step(params, cfg: ModelConfig, state: OnlineState,
                tokens: jnp.ndarray, dist: Optional[DistContext] = None,
                impl: Optional[str] = None):
    """One-token decode attending [Mem, cache, self]. tokens (B, 1).

    ``impl`` overrides ``cfg.attn_impl`` for the attend (e.g. 'concat'
    to benchmark the materialized-concat baseline)."""
    B, S = tokens.shape
    positions = state.pos + jnp.arange(S)
    x = _embed_block(cfg, params, tokens, positions)
    if cfg.family == "ssm":
        x, new_ssm = _ssm_stack_pass(params, cfg, x, state.ssm, decode=True)
        logits = T.lm_logits(params, cfg, x)
        return logits, state._replace(ssm=new_ssm, pos=state.pos + S)

    big = jnp.full((S,), 2 ** 30, jnp.int32)
    self_info = A.KeyInfo(idx=big + jnp.arange(S, dtype=jnp.int32),
                          seg=jnp.ones((S,), jnp.int32),
                          comp=jnp.zeros((S,), bool))
    q_info = self_info
    if cfg.family == "hybrid":
        x, new_cache, new_ssm, _ = _hybrid_pass(
            params, cfg, x, positions, comp_gate=None, q_info=q_info,
            self_info=self_info, state=state, write_to_cache=True,
            collect_comp=None, dist=dist, decode=True, impl=impl)
        logits = T.lm_logits(params, cfg, x)
        return logits, state._replace(cache=new_cache, ssm=new_ssm,
                                      pos=state.pos + S)
    x, new_cache, _ = _attn_stack_pass(
        params, cfg, x, positions, comp_gate=None, q_info=q_info,
        self_info=self_info, state=state, write_to_cache=True,
        collect_comp=None, dist=dist, impl=impl)
    logits = T.lm_logits(params, cfg, x)
    return logits, state._replace(cache=new_cache, pos=state.pos + S)


def encode_cross(params, cfg: ModelConfig, frames: jnp.ndarray):
    """Whisper: run encoder once, produce per-decoder-layer cross K/V."""
    enc_out = T.encode(params, cfg, frames)

    def kv(lp):
        _, k, v = A.qkv_project(cfg, {"wq": lp["wq"], "wk": lp["wk"],
                                      "wv": lp["wv"], "wo": lp["wo"]},
                                enc_out, None, None)
        return k, v

    xattn = params["layers"]["xattn"]
    ks, vs = jax.vmap(kv)(xattn)
    return ks, vs


def generate(params, cfg: ModelConfig, state: OnlineState,
             prompt: jnp.ndarray, max_new: int,
             dist: Optional[DistContext] = None,
             temperature: float = 0.0, key: Optional[jax.Array] = None,
             impl: Optional[str] = None):
    """Greedy/temperature sampling loop (lax.scan over decode steps)."""
    logits, state = prefill(params, cfg, state, prompt, dist, impl=impl)
    first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    def step(carry, i):
        st, tok, k = carry
        lg, st = decode_step(params, cfg, st, tok[:, None], dist, impl=impl)
        lg = lg[:, -1]
        if temperature > 0:
            k, sub = jax.random.split(k)
            nxt = jax.random.categorical(sub, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return (st, nxt.astype(jnp.int32), k), nxt

    key = key if key is not None else jax.random.PRNGKey(0)
    (_, _, _), toks = jax.lax.scan(step, (state, first, key),
                                   jnp.arange(max_new - 1))
    toks = jnp.concatenate([first[None], toks], axis=0)   # (max_new, B)
    return toks.swapaxes(0, 1)
