"""Streaming inference: sliding window + attention sink + CCM (paper Fig. 9).

StreamingLLM keeps [sink | recent window] and *drops* evicted tokens; CCM
instead *compresses* the evicted block into the compressed memory with a
forward pass of only the m <COMP> tokens attending [Mem, evicted-block KV] —
O(m) compute per eviction, reusing the KV already in the cache. When the
concat memory itself is full, the oldest <COMP> group is emitted
(paper: "emit the oldest compressed key/value pair").

Positions are the monotone virtual-stream ids (train-consistent; see
masks.segment_layout). DESIGN §7 records this deviation from the paper's
per-step position reassignment.

Every op is fixed-shape/functional: the whole streaming step (conditional
compression + window shift + chunk prefill) is one jitted XLA program.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import masks as M
from repro.core.memory import (MemState, evict_oldest, init_memory,
                               recompress_memory, update_memory)
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.models.scan_utils import scan_layers
from repro.models.config import ModelConfig


class StreamState(NamedTuple):
    win_k: jnp.ndarray    # (L, B, W, Hkv, hd)
    win_v: jnp.ndarray
    win_len: jnp.ndarray  # () int32
    mem: MemState
    pos: jnp.ndarray      # () int32 virtual stream position


def init_stream_state(cfg: ModelConfig, batch: int) -> StreamState:
    c = cfg.ccm
    from repro.core.memory import mem_layers
    Lc = max(mem_layers(cfg), 1)
    W = c.stream_window
    z = jnp.zeros((Lc, batch, W, cfg.n_kv_heads, cfg.hd), cfg.cdtype)
    return StreamState(win_k=z, win_v=z,
                       win_len=jnp.zeros((), jnp.int32),
                       mem=init_memory(cfg, batch, c.stream_mem_slots),
                       pos=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# compression from cached KV (no re-embedding of evicted tokens)
# ---------------------------------------------------------------------------

def compress_from_kv(params, cfg: ModelConfig, mem: MemState,
                     blk_k: jnp.ndarray, blk_v: jnp.ndarray,
                     pos0: jnp.ndarray,
                     impl: Optional[str] = None) -> MemState:
    """Run m <COMP> tokens through the stack attending [Mem, block KV].

    blk_k/blk_v: (L, B, cc, Hkv, hd) — the KV of the tokens being evicted.
    Memory and block KV are attended as in-place segments (no per-layer
    concatenation of KV or metadata).
    """
    m = cfg.ccm.comp_len
    B = blk_k.shape[1]
    off = jnp.arange(m, dtype=jnp.int32)
    x = jnp.take(params["comp_embed"].astype(cfg.cdtype), off, axis=0)
    x = jnp.broadcast_to(x[None], (B, m, x.shape[-1]))
    positions = pos0 + off
    gate = jnp.ones((B, m), cfg.cdtype)
    self_info = A.KeyInfo(idx=jnp.arange(m, dtype=jnp.int32),
                          seg=jnp.ones((m,), jnp.int32),
                          comp=jnp.ones((m,), bool))
    mem_valid = mem.valid_len(m)

    def body(h, xs):
        lp, bk, bv, mk, mv = xs
        hn = L.apply_norm(cfg, lp["ln1"], h)
        q, k_new, v_new = A.qkv_project(
            cfg, lp["attn"], hn, gate,
            positions if cfg.pos_embed == "rope" else None)
        segs = [A.KVSegment(k=mk, v=mv, length=mem_valid),
                A.KVSegment(k=bk, v=bv),            # evicted block: fully valid
                A.KVSegment(k=k_new, v=v_new, info=self_info)]
        o = A.attend_segments(cfg, q, segs, self_info, impl=impl)
        h = h + A.out_project(cfg, lp["attn"], o, gate)
        hn = L.apply_norm(cfg, lp["ln2"], h)
        if "moe" in lp:
            h = h + MOE.apply_moe(cfg, lp["moe"], hn, None)
        else:
            h = h + L.apply_mlp(cfg, lp["mlp"], hn)
        return h, (k_new, v_new)

    _, (hk, hv) = scan_layers(
        cfg.unroll_layers, body, x,
        (params["layers"], blk_k, blk_v, mem.k, mem.v))
    full = mem.slots >= mem.max_slots(m)
    mem = jax.lax.cond(full, lambda mm: evict_oldest(mm, m),
                       lambda mm: mm, mem)
    return update_memory(cfg, mem, hk, hv, m)


# ---------------------------------------------------------------------------
# streaming step
# ---------------------------------------------------------------------------

def _evict_once(params, cfg: ModelConfig, s: StreamState, ccm_on: bool,
                impl: Optional[str]) -> StreamState:
    """One eviction: compress the block behind the sink into memory
    (ccm_on) or drop it (StreamingLLM baseline), shift the window left by
    ``stream_chunk`` and advance the counters."""
    cc = cfg.ccm.stream_chunk
    sink = cfg.ccm.stream_sink
    if ccm_on:
        blk_k = jax.lax.dynamic_slice_in_dim(s.win_k, sink, cc, axis=2)
        blk_v = jax.lax.dynamic_slice_in_dim(s.win_v, sink, cc, axis=2)
        new_mem = compress_from_kv(params, cfg, s.mem, blk_k, blk_v,
                                   s.pos, impl=impl)
    else:
        new_mem = s.mem

    # shift [sink+cc, W) left by cc
    def shift(a):
        head = a[:, :, :sink]
        tail = a[:, :, sink + cc:]
        pad = jnp.zeros_like(a[:, :, :cc])
        return jnp.concatenate([head, tail, pad], axis=2)

    return StreamState(win_k=shift(s.win_k), win_v=shift(s.win_v),
                       win_len=s.win_len - cc, mem=new_mem,
                       pos=s.pos + (cfg.ccm.comp_len if ccm_on else 0))


def stream_step(params, cfg: ModelConfig, st: StreamState,
                chunk_tokens: jnp.ndarray,
                ccm_on: bool = True,
                valid_len=None,
                impl: Optional[str] = None,
                evict: bool = True) -> Tuple[jnp.ndarray, StreamState]:
    """Process ``c`` new tokens: maybe compress+evict, then prefill into the
    window attending [Mem, sink+window, self]. Returns per-token logits.

    ccm_on=False reproduces the StreamingLLM baseline (evict = drop), with
    an identical KV budget for fair comparison (paper Fig. 8).

    ``valid_len`` (ragged lane): the chunk is padded up to a token bucket
    and only the first ``valid_len`` tokens are real.  Pad tokens are
    masked out of attention, frozen out of the window write, and excluded
    from the win_len/pos counters *and the eviction trigger* — the padded
    step is bit-identical (incl. eviction boundaries) to the unpadded one.

    ``evict=False`` skips the in-step eviction `cond` entirely: the caller
    has already applied (or gated) the eviction, as `stream_step_lanes`
    does for serve batches where the per-state `cond` would lower to a
    `select` under vmap and run the compression pass on every lane every
    step.
    """
    B, c = chunk_tokens.shape
    cc = cfg.ccm.stream_chunk
    sink = cfg.ccm.stream_sink
    W = cfg.ccm.stream_window
    vl = c if valid_len is None else jnp.asarray(valid_len, jnp.int32)
    # Only ONE eviction (of cc tokens) fires per step, and the
    # dynamic_update_slice window write clamps silently — a chunk larger
    # than the eviction quantum (or an eviction block that doesn't fit
    # behind the sink) would overflow the window and corrupt the newest
    # KV rows.  Reject at trace time.
    if c > cc:
        raise ValueError(
            f"stream_step chunk ({c} tokens) exceeds stream_chunk ({cc}): "
            "one eviction per step cannot keep the window bounded; split "
            "the input into chunks of at most cfg.ccm.stream_chunk")
    if sink + cc > W:
        raise ValueError(
            f"stream_sink ({sink}) + stream_chunk ({cc}) exceeds "
            f"stream_window ({W}): the eviction block does not fit")

    if evict:
        st = jax.lax.cond(st.win_len + vl > W,
                          lambda s: _evict_once(params, cfg, s, ccm_on, impl),
                          lambda s: s, st)

    positions = st.pos + jnp.arange(c)
    x = T.embed_tokens(cfg, params, chunk_tokens)
    if cfg.pos_embed == "learned":
        x = T._add_learned_pos(cfg, params["pos_embed"], x, positions)
    self_info = A.KeyInfo(idx=jnp.arange(c, dtype=jnp.int32),
                          seg=jnp.ones((c,), jnp.int32),
                          comp=jnp.zeros((c,), bool),
                          valid=None if valid_len is None
                          else M.lane_valid(c, vl))
    mem_valid = st.mem.valid_len(cfg.ccm.comp_len)

    # The stacked window rides the scan CARRY: the attend slices k-blocks
    # straight out of layer li (KVSegment.layer) and the write touches a
    # block-sized window — window work scales with win_len rounded to a
    # block, not with W, and no per-layer slice/stack copies remain.
    def body(carry, xs):
        h, wk, wv = carry
        lp, li, mk, mv = xs
        hn = L.apply_norm(cfg, lp["ln1"], h)
        q, k_new, v_new = A.qkv_project(
            cfg, lp["attn"], hn, None,
            positions if cfg.pos_embed == "rope" else None)
        segs = [A.KVSegment(k=mk, v=mv, length=mem_valid),
                A.KVSegment(k=wk, v=wv, length=st.win_len, layer=li),
                A.KVSegment(k=k_new, v=v_new, info=self_info)]
        o = A.attend_segments(cfg, q, segs, self_info, impl=impl)
        h = h + A.out_project(cfg, lp["attn"], o, None)
        hn = L.apply_norm(cfg, lp["ln2"], h)
        if "moe" in lp:
            h = h + MOE.apply_moe(cfg, lp["moe"], hn, None)
        else:
            h = h + L.apply_mlp(cfg, lp["mlp"], hn)
        nwk = M.layer_window_write(wk, k_new, li, st.win_len,
                                  None if valid_len is None else vl)
        nwv = M.layer_window_write(wv, v_new, li, st.win_len,
                                  None if valid_len is None else vl)
        return (h, nwk, nwv), None

    Ld = st.win_k.shape[0]
    (x, nk, nv), _ = scan_layers(
        cfg.unroll_layers, body, (x, st.win_k, st.win_v),
        (params["layers"], jnp.arange(Ld, dtype=jnp.int32),
         st.mem.k, st.mem.v))
    logits = T.lm_logits(params, cfg, x)
    st = StreamState(win_k=nk, win_v=nv, win_len=st.win_len + vl,
                     mem=st.mem, pos=st.pos + vl)
    return logits, st


# ---------------------------------------------------------------------------
# lane-batched streaming step (serve engine)
# ---------------------------------------------------------------------------

def eviction_pending(cfg: ModelConfig, st: StreamState,
                     incoming) -> jnp.ndarray:
    """Per-lane "compression pending" flag: would ingesting ``incoming``
    real tokens overflow the window?  Matches `stream_step`'s in-step
    eviction trigger exactly (incl. ragged lanes, where ``incoming`` is
    the lane's valid length, not the padded bucket width)."""
    return st.win_len + jnp.asarray(incoming, jnp.int32) \
        > cfg.ccm.stream_window


def recompress_memory_lanes(cfg: ModelConfig, mem: MemState, group: int,
                            do) -> MemState:
    """Masked per-lane memory recompression over N stacked lanes (the
    arena-gather layout: every `MemState` leaf carries a leading lane
    axis, inner batch 1).

    ``do`` (N,) bool selects the lanes to recompress
    (`core.memory.recompress_memory` at ratio ``group``); every other
    lane's state is re-selected BIT-exactly (`jnp.where` on all leaves —
    the `stream_step_lanes` eviction-gating pattern), and a batch with
    no selected lane skips the regroup einsum entirely behind one
    scalar `lax.cond`.  Used by the serve engine's pressure-controller
    recompress step (`launch.serve.recompress_arena_slots`)."""
    do = jnp.asarray(do, bool)

    def regroup_masked(m: MemState) -> MemState:
        def one(lane: MemState, p) -> MemState:
            rc = recompress_memory(cfg, lane, group)
            return jax.tree.map(lambda n, o: jnp.where(p, n, o), rc, lane)
        return jax.vmap(one)(m, do)

    return jax.lax.cond(jnp.any(do), regroup_masked, lambda m: m, mem)


def _evict_compact(params, cfg: ModelConfig, st: StreamState, pending,
                   ccm_on: bool, impl: Optional[str]) -> StreamState:
    """Dense-sub-batch eviction: gather the pending lanes to the front
    (stable argsort on the flags), run the compression pass on the
    smallest power-of-2 bucket that covers them, scatter the results
    back.  The masked path (`stream_step_lanes(compact=False)`) pays
    O(N) compressions whenever ANY lane overflows; this pays
    O(round_pow2(k)) for k pending lanes.  Bit-exact with the masked
    path: each lane's eviction is computed from identical per-lane
    state (vmap, no cross-lane reduction) and non-pending rows inside a
    rounded-up bucket are re-selected with `jnp.where` before the
    scatter."""
    n = pending.shape[0]
    buckets = []
    b = 1
    while b < n:
        buckets.append(b)
        b *= 2
    buckets.append(n)
    order = jnp.argsort(~pending)        # stable: pending lanes first
    k = jnp.sum(pending)

    def branch(K):
        def run(s):
            idx = order[:K]              # static bucket width
            rows = jax.tree.map(lambda a: a[idx], s)
            sel = pending[idx]

            def one(lane: StreamState, p) -> StreamState:
                ev = _evict_once(params, cfg, lane, ccm_on, impl)
                return jax.tree.map(lambda nw, o: jnp.where(p, nw, o),
                                    ev, lane)
            rows = jax.vmap(one)(rows, sel)
            return jax.tree.map(lambda f, r: f.at[idx].set(r), s, rows)
        return run

    bidx = jnp.searchsorted(jnp.asarray(buckets, jnp.int32), k)
    return jax.lax.switch(bidx, [branch(K) for K in buckets], st)


def stream_step_lanes(params, cfg: ModelConfig, st: StreamState,
                      chunk_tokens: jnp.ndarray, lengths=None,
                      ccm_on: bool = True,
                      impl: Optional[str] = None,
                      compact: bool = True
                      ) -> Tuple[jnp.ndarray, StreamState]:
    """Serve-batch streaming step over N stacked lanes with PER-LANE
    eviction gating.

    ``st`` holds N independent sessions stacked leaf-wise (leading lane
    axis, inner batch 1 — the arena-gather layout); ``chunk_tokens`` is
    (N, 1, c) and ``lengths`` (N,) carries ragged valid lengths (None =
    every lane's chunk is fully real).

    A plain ``vmap(stream_step)`` turns the eviction `cond` into a
    `select`: every lane runs the O(comp_len) compression pass every
    step.  Here the per-lane "compression pending" flags are reduced to
    ONE scalar predicate — `jax.lax.cond(any(pending), ...)` stays a real
    branch — so steps where no lane overflows skip compression entirely,
    and when some lane does overflow, the eviction runs vmapped but each
    non-pending lane's state is re-selected bit-exactly (`jnp.where` on
    every leaf: window, memory, win_len/pos counters all frozen).  The
    per-token prefill then runs with ``evict=False``.  Cost of the
    compression pass is therefore proportional to how often windows
    actually overflow, not to steps * lanes.

    ``compact=True`` (default) additionally gathers the pending lanes
    into a dense power-of-2 sub-batch before the pass (`_evict_compact`)
    so a 64-lane batch with 3 overflowing lanes compresses 4 lanes, not
    64.  ``compact=False`` keeps the all-lanes masked pass — the
    reference oracle for the bit-exactness test.
    """
    c = chunk_tokens.shape[-1]
    vl = jnp.full((chunk_tokens.shape[0],), c, jnp.int32) \
        if lengths is None else jnp.asarray(lengths, jnp.int32)
    pending = eviction_pending(cfg, st, vl)          # (N,)

    def evict_masked(s: StreamState) -> StreamState:
        def one(lane: StreamState, p) -> StreamState:
            ev = _evict_once(params, cfg, lane, ccm_on, impl)
            return jax.tree.map(lambda n, o: jnp.where(p, n, o), ev, lane)
        return jax.vmap(one)(s, pending)

    evict = (lambda s: _evict_compact(params, cfg, s, pending,
                                      ccm_on, impl)) \
        if compact else evict_masked
    st = jax.lax.cond(jnp.any(pending), evict, lambda s: s, st)

    def one_step(lane: StreamState, tk, v):
        return stream_step(params, cfg, lane, tk, ccm_on=ccm_on,
                           valid_len=None if lengths is None else v,
                           impl=impl, evict=False)

    return jax.vmap(one_step)(st, chunk_tokens, vl)
