"""Conditional LoRA (paper Eq. 4 / Fig. 4).

``x' = W x + m * (DeltaW) x`` with ``m = 1(x is <COMP>)``: the low-rank delta
fires only at <COMP>-token rows, so compression capability lives entirely in
``delta_theta`` and never perturbs normal-token computation.

TPU adaptation: instead of gathering <COMP> rows (layout-hostile), the gate is
fused multiplicatively — dense, branch-free, MXU-friendly. The rank-r
intermediate is tiny (r = 8..16). ``repro.kernels.cond_lora`` provides the
fused Pallas kernel; this module is the reference / CPU implementation and
the parameter plumbing.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def init_lora(key: jax.Array, d_in: int, d_out: int, rank: int,
              dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """A ~ N(0, 1/d_in); B = 0 so the delta starts at zero."""
    a = jax.random.normal(key, (rank, d_in), dtype) / jnp.sqrt(d_in)
    b = jnp.zeros((rank, d_out), dtype)
    return {"a": a, "b": b}


def lora_delta(x: jnp.ndarray, lora: Dict[str, jnp.ndarray],
               scale: float) -> jnp.ndarray:
    """(x @ A^T) @ B * scale, computed in x.dtype."""
    a = lora["a"].astype(x.dtype)
    b = lora["b"].astype(x.dtype)
    return ((x @ a.T) @ b) * jnp.asarray(scale, x.dtype)


def cond_linear(x: jnp.ndarray, w: jnp.ndarray,
                lora: Optional[Dict[str, jnp.ndarray]],
                gate: Optional[jnp.ndarray],
                scale: float = 2.0,
                bias: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """y = x @ W (+bias) + gate * ((x @ A^T) @ B) * scale.

    x: (..., d_in); w: (d_in, d_out); gate: (...,) in {0.,1.} or None for
    unconditional LoRA (the paper's "default LoRA" ablation).
    """
    y = x @ w.astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(x.dtype)
    if lora is not None:
        d = lora_delta(x, lora, scale)
        if gate is not None:
            d = d * gate[..., None].astype(x.dtype)
        y = y + d
    return y


def lora_scale(rank: int, alpha: float) -> float:
    return float(alpha) / float(rank)


def tree_zeros_like_lora(lora_tree: Any) -> Any:
    return jax.tree.map(jnp.zeros_like, lora_tree)
