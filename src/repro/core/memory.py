"""Compressed context memory state + update functions (paper Eq. 1-2).

Fixed-shape functional state so every online step is a single jitted XLA
program:

  concat: k/v (L, B, T*m, Hkv, hd); ``slots`` counts filled <COMP> groups.
  merge : k/v (L, B,   m, Hkv, hd); running (weighted) average; ``steps``
          tracks t for the a_t = 1/t arithmetic-mean coefficient.

Also holds the virtual stream-position counter ``stream_pos`` (total tokens
ever processed, contexts + <COMP> alike) so online RoPE phases match the
parallel-training unroll exactly (see masks.segment_layout docstring).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


class MemState(NamedTuple):
    k: jnp.ndarray            # (L, B, M, Hkv, hd)
    v: jnp.ndarray            # (L, B, M, Hkv, hd)
    slots: jnp.ndarray        # () int32 — filled <COMP> groups (concat)
    steps: jnp.ndarray        # () int32 — online time step t
    stream_pos: jnp.ndarray   # () int32 — virtual stream position

    def max_slots(self, comp_len: int) -> int:
        return self.k.shape[2] // comp_len

    def valid_len(self, comp_len: int) -> jnp.ndarray:
        return self.slots * comp_len


def mem_layers(cfg: ModelConfig) -> int:
    """Number of attention layers that carry CCM memory."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every   # shared-attn sites
    if cfg.family == "encdec":
        return cfg.n_layers                     # decoder self-attn
    if cfg.family == "ssm":
        return 0
    return cfg.n_layers


def init_memory(cfg: ModelConfig, batch: int,
                max_slots: Optional[int] = None,
                dtype=None) -> MemState:
    L = max(mem_layers(cfg), 1)
    m = cfg.ccm.comp_len
    if max_slots is None:
        max_slots = cfg.ccm.mem_slots
    if cfg.ccm.mode == "merge":
        max_slots = 1
    shape = (L, batch, max_slots * m, cfg.n_kv_heads, cfg.hd)
    dt = dtype or cfg.cdtype
    z = jnp.zeros(shape, dt)
    zero = jnp.zeros((), jnp.int32)
    return MemState(k=z, v=z, slots=zero, steps=zero, stream_pos=zero)


def update_memory(cfg: ModelConfig, mem: MemState, h_k: jnp.ndarray,
                  h_v: jnp.ndarray, n_new_tokens: jnp.ndarray) -> MemState:
    """Apply g_update with the new compressed state h(t).

    h_k/h_v: (L, B, m, Hkv, hd) — the <COMP> keys/values from g_comp.
    n_new_tokens: tokens consumed this step (context + m), advances the
    virtual stream position.
    """
    m = cfg.ccm.comp_len
    t_new = mem.steps + 1
    if cfg.ccm.mode == "merge":
        if cfg.ccm.merge_alpha is None:
            a = 1.0 / t_new.astype(jnp.float32)          # arithmetic mean
        else:
            a = jnp.where(t_new == 1, 1.0, cfg.ccm.merge_alpha)
        a = a.astype(mem.k.dtype)
        new_k = mem.k * (1 - a) + h_k.astype(mem.k.dtype) * a
        new_v = mem.v * (1 - a) + h_v.astype(mem.v.dtype) * a
        slots = jnp.ones((), jnp.int32)
    else:
        start = mem.slots * m
        new_k = jax.lax.dynamic_update_slice_in_dim(
            mem.k, h_k.astype(mem.k.dtype), start, axis=2)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            mem.v, h_v.astype(mem.v.dtype), start, axis=2)
        slots = jnp.minimum(mem.slots + 1, mem.max_slots(m))
    return MemState(k=new_k, v=new_v, slots=slots, steps=t_new,
                    stream_pos=mem.stream_pos + n_new_tokens)


def evict_oldest(mem: MemState, comp_len: int) -> MemState:
    """Concat-mode streaming: drop the oldest <COMP> group (paper Fig. 9)."""
    k = jnp.roll(mem.k, -comp_len, axis=2)
    v = jnp.roll(mem.v, -comp_len, axis=2)
    return mem._replace(k=k, v=v, slots=jnp.maximum(mem.slots - 1, 0))
