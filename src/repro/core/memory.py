"""Compressed context memory state + update functions (paper Eq. 1-2).

Fixed-shape functional state so every online step is a single jitted XLA
program:

  concat: k/v (L, B, T*m, Hkv, hd); ``slots`` counts filled <COMP> groups.
  merge : k/v (L, B,   m, Hkv, hd); running (weighted) average; ``steps``
          tracks t for the a_t = 1/t arithmetic-mean coefficient.

Also holds the virtual stream-position counter ``stream_pos`` (total tokens
ever processed, contexts + <COMP> alike) so online RoPE phases match the
parallel-training unroll exactly (see masks.segment_layout docstring).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


class MemState(NamedTuple):
    k: jnp.ndarray            # (L, B, M, Hkv, hd)
    v: jnp.ndarray            # (L, B, M, Hkv, hd)
    slots: jnp.ndarray        # () int32 — filled <COMP> groups (concat)
    steps: jnp.ndarray        # () int32 — online time step t
    stream_pos: jnp.ndarray   # () int32 — virtual stream position

    def max_slots(self, comp_len: int) -> int:
        return self.k.shape[2] // comp_len

    def valid_len(self, comp_len: int) -> jnp.ndarray:
        return self.slots * comp_len


def mem_layers(cfg: ModelConfig) -> int:
    """Number of attention layers that carry CCM memory."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every   # shared-attn sites
    if cfg.family == "encdec":
        return cfg.n_layers                     # decoder self-attn
    if cfg.family == "ssm":
        return 0
    return cfg.n_layers


def init_memory(cfg: ModelConfig, batch: int,
                max_slots: Optional[int] = None,
                dtype=None) -> MemState:
    L = max(mem_layers(cfg), 1)
    m = cfg.ccm.comp_len
    if max_slots is None:
        max_slots = cfg.ccm.mem_slots
    if cfg.ccm.mode == "merge":
        max_slots = 1
    shape = (L, batch, max_slots * m, cfg.n_kv_heads, cfg.hd)
    dt = dtype or cfg.cdtype
    z = jnp.zeros(shape, dt)
    zero = jnp.zeros((), jnp.int32)
    return MemState(k=z, v=z, slots=zero, steps=zero, stream_pos=zero)


def update_memory(cfg: ModelConfig, mem: MemState, h_k: jnp.ndarray,
                  h_v: jnp.ndarray, n_new_tokens: jnp.ndarray) -> MemState:
    """Apply g_update with the new compressed state h(t).

    h_k/h_v: (L, B, m, Hkv, hd) — the <COMP> keys/values from g_comp.
    n_new_tokens: tokens consumed this step (context + m), advances the
    virtual stream position.
    """
    m = cfg.ccm.comp_len
    t_new = mem.steps + 1
    if cfg.ccm.mode == "merge":
        if cfg.ccm.merge_alpha is None:
            a = 1.0 / t_new.astype(jnp.float32)          # arithmetic mean
        else:
            a = jnp.where(t_new == 1, 1.0, cfg.ccm.merge_alpha)
        a = a.astype(mem.k.dtype)
        new_k = mem.k * (1 - a) + h_k.astype(mem.k.dtype) * a
        new_v = mem.v * (1 - a) + h_v.astype(mem.v.dtype) * a
        slots = jnp.ones((), jnp.int32)
    else:
        start = mem.slots * m
        new_k = jax.lax.dynamic_update_slice_in_dim(
            mem.k, h_k.astype(mem.k.dtype), start, axis=2)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            mem.v, h_v.astype(mem.v.dtype), start, axis=2)
        slots = jnp.minimum(mem.slots + 1, mem.max_slots(m))
    return MemState(k=new_k, v=new_v, slots=slots, steps=t_new,
                    stream_pos=mem.stream_pos + n_new_tokens)


def evict_oldest(mem: MemState, comp_len: int) -> MemState:
    """Concat-mode streaming: drop the oldest <COMP> group (paper Fig. 9)."""
    k = jnp.roll(mem.k, -comp_len, axis=2)
    v = jnp.roll(mem.v, -comp_len, axis=2)
    return mem._replace(k=k, v=v, slots=jnp.maximum(mem.slots - 1, 0))


def recompress_memory(cfg: ModelConfig, mem: MemState,
                      group: int) -> MemState:
    """Re-run the merge over EXISTING memory slots at a higher ratio:
    every ``group`` consecutive filled <COMP> groups collapse into one
    (position-aligned arithmetic mean, the same g_update reduction that
    merge mode applies across time steps), shrinking a g-group memory to
    ceil(g / group) groups in place.

    This is the memory-pressure controller's cheapest lever
    (`serve.pressure`): trade reconstruction fidelity for slots without
    touching the host — quality degrades like a coarser ``comp_len``
    would have, but the state stays resident and attendable.

    Fixed-shape and jit-safe under a DYNAMIC ``slots`` scalar: the
    grouped mean is one einsum against a (G, G) one-hot/weight matrix
    built from ``slots``, so the same compiled program serves any fill
    level.  Groups at or past the new count are zeroed (they are
    invalid — ``valid_len`` masks them out of attention).  Merge mode
    (1 slot) and ``group == 1`` return the state unchanged; lanes that
    must stay BIT-exact (e.g. not-selected lanes of a serve batch,
    whose invalid region may hold stale evicted groups) go through
    `streaming.recompress_memory_lanes`, which re-selects them wholesale.
    ``steps`` / ``stream_pos`` are unchanged — recompression rewrites
    the memory's *representation*, not the stream timeline."""
    if group < 1:
        raise ValueError(f"recompress group must be >= 1, got {group}")
    m = cfg.ccm.comp_len
    G = mem.k.shape[2] // m
    if cfg.ccm.mode == "merge" or G <= 1 or group == 1:
        return mem
    g = mem.slots
    new_g = -(-g // group)                        # ceil(g / group)
    gi = jnp.arange(G, dtype=jnp.int32)
    owner = gi // group                           # new group owning old i
    w = ((owner[None, :] == gi[:, None]) & (gi < g)[None, :])
    cnt = w.sum(axis=1, keepdims=True)
    wn = (w / jnp.maximum(cnt, 1)).astype(jnp.float32)

    def regroup(x):
        L, B, _, H, D = x.shape
        xg = x.reshape(L, B, G, m, H, D).astype(jnp.float32)
        out = jnp.einsum("ji,lbimhd->lbjmhd", wn, xg)
        return out.reshape(L, B, G * m, H, D).astype(x.dtype)

    return mem._replace(k=regroup(mem.k), v=regroup(mem.v),
                        slots=new_g.astype(jnp.int32))
