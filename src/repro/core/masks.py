"""CCM segment layout and attention-mask primitives.

Parallel-training layout (paper Fig. 3) for ``t`` online steps, ``m``
<COMP> tokens per step and an input/output tail::

    [ c(1) <COMP>^m | c(2) <COMP>^m | ... | c(t) <COMP>^m | I(t) O(t) ]
      seg=1           seg=2                 seg=t           seg=t+1

Mask rule (CCM-concat), equivalent to "c(j) sees only Mem(j-1); <COMP>_j
compresses c(j) given Mem(j-1); I(t) sees only Mem(t)":

    allow(q, k) = (k <= q) and (seg_k == seg_q or comp_k)

CCM-merge replaces the per-segment <COMP> keys by *virtual memory slots*
holding the running (weighted) average of the compressed states; queries of
segment ``j`` may attend only slot ``j-1``.

All helpers are pure jnp and shape-polymorphic; per-batch layouts are uniform
(a single 1-D ``seg_ids``/``comp_mask`` describes the whole batch), padding
inside chunks is handled with a key-padding mask.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


class SegmentLayout(NamedTuple):
    """Static description of one CCM training sequence."""

    seg_ids: jnp.ndarray    # (S,) int32, 1..t+1
    comp_mask: jnp.ndarray  # (S,) bool, True at <COMP> positions
    positions: jnp.ndarray  # (S,) int32, RoPE position ids (memory-reassigned)
    t_steps: int
    comp_len: int
    chunk_len: int
    tail_len: int

    @property
    def seq_len(self) -> int:
        return int(self.seg_ids.shape[0])


def segment_layout(t_steps: int, chunk_len: int, comp_len: int,
                   tail_len: int, mode: str = "concat") -> SegmentLayout:
    """Build the uniform parallel-training layout.

    ``chunk_len`` counts the raw tokens of each c(j) (excl. <COMP>).

    Positions are the *packed* indices 0..S-1: the parallel training pass is
    then an exact unroll of the recursion where inference maintains a virtual
    stream-position counter covering every token ever processed (contexts and
    <COMP> tokens alike) — identical RoPE phases train vs. online.
    """
    segs, comps = [], []
    m = comp_len
    for j in range(1, t_steps + 1):
        seg_len = chunk_len + m
        segs.append(np.full(seg_len, j, np.int32))
        comps.append(np.concatenate([np.zeros(chunk_len, bool), np.ones(m, bool)]))
    segs.append(np.full(tail_len, t_steps + 1, np.int32))
    comps.append(np.zeros(tail_len, bool))
    total = t_steps * (chunk_len + m) + tail_len
    poss = [np.arange(total, dtype=np.int32)]
    del mode
    return SegmentLayout(
        seg_ids=jnp.asarray(np.concatenate(segs)),
        comp_mask=jnp.asarray(np.concatenate(comps)),
        positions=jnp.asarray(np.concatenate(poss)),
        t_steps=t_steps, comp_len=comp_len,
        chunk_len=chunk_len, tail_len=tail_len)


# ---------------------------------------------------------------------------
# mask builders
# ---------------------------------------------------------------------------

def comp_offset_array(comp_mask: jnp.ndarray) -> jnp.ndarray:
    """(S,) offset of each <COMP> token within its group (0 elsewhere).

    Used to select the per-offset <COMP> embedding (a group of length m has
    m distinct learned embeddings, shared across time steps — paper §B).
    """
    cm = np.asarray(comp_mask)
    out = np.zeros_like(cm, dtype=np.int32)
    run = 0
    for i, c in enumerate(cm):
        run = run + 1 if c else 0
        out[i] = max(run - 1, 0)
    return jnp.asarray(out)


def ccm_mask_concat(seg_ids: jnp.ndarray, comp_mask: jnp.ndarray,
                    k_seg_ids: Optional[jnp.ndarray] = None,
                    k_comp_mask: Optional[jnp.ndarray] = None,
                    q_offset: int = 0) -> jnp.ndarray:
    """Boolean (Q, K) mask: causal AND (same segment OR key-is-<COMP>).

    ``q_offset`` shifts query indices relative to keys (for incremental
    evaluation where queries are a suffix of the key sequence).
    """
    k_seg_ids = seg_ids if k_seg_ids is None else k_seg_ids
    k_comp_mask = comp_mask if k_comp_mask is None else k_comp_mask
    q_idx = jnp.arange(seg_ids.shape[0])[:, None] + q_offset
    k_idx = jnp.arange(k_seg_ids.shape[0])[None, :]
    causal = k_idx <= q_idx
    same_seg = seg_ids[:, None] == k_seg_ids[None, :]
    return causal & (same_seg | k_comp_mask[None, :])


def causal_mask(q_len: int, k_len: int, q_offset: int = 0) -> jnp.ndarray:
    q = jnp.arange(q_len)[:, None] + q_offset
    k = jnp.arange(k_len)[None, :]
    return k <= q


def merge_slot_mask(seg_ids: jnp.ndarray, t_steps: int) -> jnp.ndarray:
    """(Q, T) mask over virtual memory slots: seg j attends slot j-1 only.

    Slot index s (0-based) holds Mem(s+1) = avg(h(1..s+1)); a query in
    segment j uses Mem(j-1) -> slot j-2. The tail segment t+1 uses Mem(t)
    -> slot t-1.
    """
    slot = jnp.arange(1, t_steps + 1)[None, :]  # slot s holds Mem(s)
    want = (seg_ids - 1)[:, None]               # segment j wants Mem(j-1)
    return slot == want


def intra_segment_causal(seg_ids: jnp.ndarray,
                         comp_mask: jnp.ndarray) -> jnp.ndarray:
    """(Q, K) raw-key mask used in merge mode: causal AND same segment."""
    q_idx = jnp.arange(seg_ids.shape[0])[:, None]
    k_idx = jnp.arange(seg_ids.shape[0])[None, :]
    return (k_idx <= q_idx) & (seg_ids[:, None] == seg_ids[None, :])


# ---------------------------------------------------------------------------
# merge-mode virtual slots
# ---------------------------------------------------------------------------

def merge_coefficients(t_steps: int, alpha: Optional[float]) -> jnp.ndarray:
    """(T, T) lower-triangular weights W[j, i] s.t. Mem(j+1)=sum_i W[j,i] h(i+1).

    alpha=None  -> arithmetic mean  W[j, i<=j] = 1/(j+1)
    alpha=a     -> EMA: Mem(t) = (1-a) Mem(t-1) + a h(t), a_1 = 1.
    """
    t = t_steps
    if alpha is None:
        w = np.tril(np.ones((t, t))) / np.arange(1, t + 1)[:, None]
    else:
        w = np.zeros((t, t))
        for j in range(t):
            for i in range(j + 1):
                coef = 1.0 if i == 0 else alpha
                coef *= (1.0 - alpha) ** (j - i)
                w[j, i] = coef
    return jnp.asarray(w, jnp.float32)


def merge_virtual_kv(k: jnp.ndarray, v: jnp.ndarray,
                     comp_mask: jnp.ndarray, t_steps: int, comp_len: int,
                     alpha: Optional[float]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Build virtual memory-slot KV for merge-mode parallel training.

    k, v: (B, S, H, D) per-layer attention keys/values.
    Returns (B, T*comp_len, H, D) slot keys/values where slot j (0-based,
    holding Mem(j+1)) is the weighted average of the <COMP>-group KVs of
    segments 1..j+1.
    """
    B, S, H, D = k.shape
    m = comp_len
    idx = jnp.nonzero(comp_mask, size=t_steps * m)[0]       # static layout
    hk = k[:, idx].reshape(B, t_steps, m, H, D)
    hv = v[:, idx].reshape(B, t_steps, m, H, D)
    w = merge_coefficients(t_steps, alpha).astype(k.dtype)  # (T, T)
    mem_k = jnp.einsum("ji,bimhd->bjmhd", w, hk).reshape(B, t_steps * m, H, D)
    mem_v = jnp.einsum("ji,bimhd->bjmhd", w, hv).reshape(B, t_steps * m, H, D)
    return mem_k, mem_v


def expand_slot_mask(slot_mask: jnp.ndarray, comp_len: int) -> jnp.ndarray:
    """(Q, T) -> (Q, T*comp_len) by repeating each slot column."""
    return jnp.repeat(slot_mask, comp_len, axis=1)


def apply_mask(logits: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Additive -inf masking; mask broadcastable to logits."""
    return jnp.where(mask, logits, NEG_INF)


# ---------------------------------------------------------------------------
# ragged token lanes (serve-engine token-bucket padding)
# ---------------------------------------------------------------------------

def lane_valid(length: int, valid_len: jnp.ndarray,
               tail_start: Optional[int] = None) -> jnp.ndarray:
    """(length,) key-validity mask for one ragged lane.

    True at positions ``< valid_len`` (the real tokens of a request padded
    up to a token bucket) and, when ``tail_start`` is given, at positions
    ``>= tail_start`` (a block that is always real regardless of padding —
    e.g. the <COMP> group appended after a padded context chunk).
    """
    ar = jnp.arange(length)
    v = ar < valid_len
    if tail_start is not None:
        v = v | (ar >= tail_start)
    return v


def ragged_window_write(buf: jnp.ndarray, blk: jnp.ndarray,
                        starts, valid_len: jnp.ndarray,
                        axis: int) -> jnp.ndarray:
    """Write ``blk``'s first ``valid_len`` rows (along ``axis``) into
    ``buf`` at the index tuple ``starts``, touching only a block-sized
    window — O(block) traffic, never O(buf).

    Semantics match :func:`ragged_block_write`: rows past ``valid_len``
    are frozen bit-exactly and a write overhanging the buffer end keeps
    only the rows that fit (no ``dynamic_update_slice`` clamp-shift —
    the window is clamped, then pre-``start`` rows are re-blended from
    the buffer).  ``starts`` addresses every ``buf`` dim, e.g.
    ``(layer, 0, at, 0, 0)`` for a stacked (L, B, S, H, D) cache with
    ``axis=2`` — the scanned-layer write path of the segmented runtime.
    """
    n, s = buf.shape[axis], blk.shape[axis]
    at = jnp.asarray(starts[axis], jnp.int32)
    cl = jnp.clip(at, 0, max(n - s, 0))
    idx = [jnp.asarray(i, jnp.int32) for i in starts]
    idx[axis] = cl
    cur = jax.lax.dynamic_slice(buf, idx, blk.shape)
    pos = cl + jnp.arange(s)                 # global row ids of the window
    src = jnp.clip(pos - at, 0, s - 1)
    moved = jnp.take(blk.astype(buf.dtype), src, axis=axis)
    keep = (pos >= at) & (pos < at + valid_len)
    shape = [1] * buf.ndim
    shape[axis] = s
    blended = jnp.where(keep.reshape(shape), moved, cur)
    return jax.lax.dynamic_update_slice(buf, blended, idx)


def layer_window_write(buf: jnp.ndarray, blk: jnp.ndarray, layer,
                       at, valid_len=None) -> jnp.ndarray:
    """Append ``blk`` (B, s, ...) into layer ``layer`` of a stacked state
    array (L, B, S, ...) at row ``at``, touching only a block-sized
    window — the scanned layer body neither slices nor re-stacks its
    layer's full state.  ``valid_len`` freezes pad rows bit-exactly
    (ragged lanes); without it the write clamps at the buffer end like
    ``dynamic_update_slice``."""
    starts = (layer, 0, at) + (0,) * (buf.ndim - 3)
    blk = blk[None].astype(buf.dtype)
    if valid_len is not None:
        return ragged_window_write(buf, blk, starts, valid_len, axis=2)
    return jax.lax.dynamic_update_slice(
        buf, blk, [jnp.asarray(i, jnp.int32) for i in starts])


def ragged_block_write(buf: jnp.ndarray, blk: jnp.ndarray,
                       start: jnp.ndarray, valid_len: jnp.ndarray,
                       axis: int) -> jnp.ndarray:
    """Write ``blk``'s first ``valid_len`` rows into ``buf`` at ``start``
    along ``axis``; every other position of ``buf`` is frozen bit-exactly.

    The masked-lane analogue of ``dynamic_update_slice_in_dim``: pad rows
    of an over-long block are never written, and (unlike d_u_s) the write
    cannot clamp-shift when ``start + blk_len`` overhangs the buffer —
    so a lane padded into a larger token bucket leaves state bit-identical
    to running the request unpadded.  Touches a block-sized window only
    (see :func:`ragged_window_write`); a block as large as the buffer
    falls back to the full-width blend.
    """
    n, s = buf.shape[axis], blk.shape[axis]
    if s >= n:
        pos = jnp.arange(n)
        src = jnp.clip(pos - start, 0, s - 1)
        moved = jnp.take(blk.astype(buf.dtype), src, axis=axis)
        keep = (pos >= start) & (pos < start + valid_len)
        shape = [1] * buf.ndim
        shape[axis] = n
        return jnp.where(keep.reshape(shape), moved, buf)
    starts = [0] * buf.ndim
    starts[axis] = start
    return ragged_window_write(buf, blk, starts, valid_len, axis)
