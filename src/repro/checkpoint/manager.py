"""Fault-tolerant checkpointing (no orbax): atomic, async, elastic.

 * Atomic: write to ``step_<N>.tmp`` then ``os.replace`` — a crash mid-save
   never corrupts the latest checkpoint; ``latest()`` scans committed dirs.
 * Async: a background thread serializes host copies; the train loop blocks
   only for the device->host transfer of the *changed* leaves (LoRA-only
   training transfers megabytes).
 * Elastic: ``restore(..., mesh, specs)`` device_puts every leaf onto the
   *current* mesh, which may differ from the mesh that saved it — restart
   on fewer/more pods just works (resharding = host round-trip).
 * Integrity: per-leaf CRC + manifest; partial/corrupt dirs are skipped.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_k(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _k(p):
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._async = async_save
        self._err: Optional[BaseException] = None
        if async_save:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Snapshot to host, then commit (async if enabled)."""
        host = _flatten(jax.device_get(tree))
        payload = (step, host, extra or {})
        if self._async:
            self._q.put(payload)
        else:
            self._commit(*payload)

    def wait(self):
        if self._async:
            self._q.join()
        if self._err:
            raise self._err

    def _worker(self):
        while True:
            payload = self._q.get()
            try:
                self._commit(*payload)
            except BaseException as e:  # surfaced on wait()
                self._err = e
            finally:
                self._q.task_done()

    def _commit(self, step: int, host: Dict[str, np.ndarray], extra: Dict):
        tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in host.items():
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return out

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any,
                mesh=None, specs: Any = None,
                verify: bool = True) -> Tuple[Any, Dict]:
        """Load onto the CURRENT mesh (elastic restore).

        target_tree: pytree of arrays or ShapeDtypeStructs (structure
        template). specs: matching PartitionSpec tree (optional)."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        spec_flat = None
        if specs is not None:
            spec_flat = treedef.flatten_up_to(specs)
        leaves = []
        for i, (path, tmpl) in enumerate(flat):
            key = "/".join(_k(p) for p in path)
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(d, meta["file"]))
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc"]:
                    raise IOError(f"checkpoint corruption at leaf {key}")
            if mesh is not None and spec_flat is not None \
                    and spec_flat[i] is not None:
                sh = jax.sharding.NamedSharding(mesh, spec_flat[i])
                leaves.append(jax.device_put(arr.astype(tmpl.dtype), sh))
            else:
                leaves.append(jax.numpy.asarray(arr.astype(tmpl.dtype)))
        return jax.tree_util.tree_unflatten(treedef, leaves), \
            manifest["extra"]
