"""Logical-axis sharding rules -> PartitionSpec trees, per architecture.

TP rule (DESIGN §6): shard a weight dim on the ``model`` axis iff divisible
by its size — heads for attention (gemma-2b's 8 q-heads / 1 kv-head
replicate), d_ff for MLPs, vocab for embedding/head, expert-f for MoE
(ragged_tp) or the expert dim (ep). Mamba blocks replicate weights (DP-only;
DESIGN §6 note). Everything operates on ``jax.eval_shape`` results, so a
400B param tree is never materialized to derive its specs.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.context import DistContext, divisible
from repro.models.config import ModelConfig


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def _shard_last(shape, n, axis_name, at=-1):
    """Spec sharding dim ``at`` iff divisible, else replicated."""
    dims = [None] * len(shape)
    if divisible(shape[at], n):
        dims[at] = axis_name
    return P(*dims)


def _fsdp_spec(shape, nm, ma) -> P:
    """ZeRO-3: shard the largest divisible weight dim over the model axis;
    GSPMD inserts per-layer all-gathers (weights) instead of per-layer
    activation reductions — a win whenever weight bytes << activation
    bytes (small models on big meshes)."""
    best = None
    for i, s in enumerate(shape):
        if divisible(s, nm) and s >= 128:
            if best is None or s >= shape[best]:
                best = i
    dims = [None] * len(shape)
    if best is not None:
        dims[best] = ma
    return P(*dims)


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, dist: DistContext) -> P:
    names = path
    ma, nm = dist.model_axis, dist.n_model
    leaf = names[-1]
    joined = "/".join(names)

    # --- never shard small / norm / scalar things
    if len(shape) <= 1 or any(s in joined for s in
                              ("ln1", "ln2", "ln_x", "final_norm", "norm",
                               "dt_bias", "a_log", "d_skip", "conv",
                               "comp_embed", "frontend")):
        return P()
    if leaf == "router":
        return P()
    if cfg.sharding_strategy == "fsdp" and "lora" not in names:
        return _fsdp_spec(shape, nm, ma)
    if "lora" in names:
        # a: (..., r, d_in) replicate; b: (..., r, d_out) follow target dim
        if leaf == "a":
            return P()
        return _shard_last(shape, nm, ma)
    if leaf in ("embed", "pos_embed"):
        return _shard_last(shape, nm, ma, at=-2)   # vocab/pos rows
    if leaf == "lm_head":
        return _shard_last(shape, nm, ma)
    if "mamba" in names:
        return P()
    if "moe" in names:
        if cfg.moe_impl == "ep":
            at = -3  # expert dim of (..., E, d, f)
            dims = [None] * len(shape)
            if divisible(shape[at], nm):
                dims[at] = ma
            return P(*dims)
        if leaf in ("wi", "wg"):
            return _shard_last(shape, nm, ma)
        if leaf == "wo":
            return _shard_last(shape, nm, ma, at=-2)
        return P()
    if leaf in ("wq", "wk", "wv", "bq", "bk", "bv"):
        return _shard_last(shape, nm, ma)
    if leaf == "wo":
        return _shard_last(shape, nm, ma, at=-2)
    if leaf in ("wi", "wg"):
        return _shard_last(shape, nm, ma)
    return P()


def param_pspecs(cfg: ModelConfig, params_shapes: Any,
                 dist: DistContext) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = [param_spec(_path_names(p), tuple(v.shape), cfg, dist)
             for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspecs(param_specs_tree: Any, opt_state_shapes: Any) -> Any:
    """AdamW moments follow their parameter's spec; step is replicated.

    Frozen leaves (None moments) get no spec (pytree structure match)."""
    from repro.optim.adamw import AdamWState

    def follow(spec, leaf):
        return None if leaf is None else spec

    mu = jax.tree.map(follow, param_specs_tree, opt_state_shapes.mu,
                      is_leaf=lambda x: x is None)
    nu = jax.tree.map(follow, param_specs_tree, opt_state_shapes.nu,
                      is_leaf=lambda x: x is None)
    return AdamWState(step=P(), mu=mu, nu=nu)


# ---------------------------------------------------------------------------
# activation / state specs
# ---------------------------------------------------------------------------

def batch_spec(dist: DistContext, extra_dims: int = 1) -> P:
    return P(dist.batch_axes, *([None] * extra_dims))


def cache_pspecs(cfg: ModelConfig, dist: DistContext,
                 shard_seq: bool = False):
    """KVCache spec: (L, B, S, Hkv, hd). batch on data axes; kv heads on
    model iff divisible; optionally shard the sequence axis (SP) instead of
    batch (long_500k, batch=1)."""
    from repro.core.inference import KVCache
    ma = dist.model_axis if divisible(cfg.n_kv_heads, dist.n_model) else None
    if shard_seq:
        kv = P(None, None, dist.batch_axes, ma, None)
        sc = P(None, None, dist.batch_axes, ma)
    else:
        kv = P(None, dist.batch_axes, None, ma, None)
        sc = P(None, dist.batch_axes, None, ma)
    if cfg.kv_cache_dtype == "int8":
        return KVCache(k=kv, v=kv, length=P(), k_scale=sc, v_scale=sc)
    return KVCache(k=kv, v=kv, length=P())


def mem_pspecs(cfg: ModelConfig, dist: DistContext, batch_sharded=True):
    from repro.core.memory import MemState
    ma = dist.model_axis if divisible(cfg.n_kv_heads, dist.n_model) else None
    b = dist.batch_axes if batch_sharded else None
    kv = P(None, b, None, ma, None)
    return MemState(k=kv, v=kv, slots=P(), steps=P(), stream_pos=P())


def ssm_pspecs(cfg: ModelConfig, dist: DistContext, batch_sharded=True):
    from repro.core.inference import SSMState
    b = dist.batch_axes if batch_sharded else None
    return SSMState(ssm=P(None, b, None, None, None),
                    conv=P(None, b, None, None))


def online_state_pspecs(cfg: ModelConfig, dist: DistContext,
                        batch_sharded: bool = True,
                        shard_cache_seq: bool = False):
    from repro.core.inference import OnlineState
    st = {"pos": P(), "cache": None, "mem": None, "ssm": None, "cross": None}
    if cfg.family in ("ssm", "hybrid"):
        st["ssm"] = ssm_pspecs(cfg, dist, batch_sharded)
    if cfg.family != "ssm":
        cs = cache_pspecs(cfg, dist, shard_seq=shard_cache_seq)
        if not batch_sharded:
            cs = KVCacheReplaceBatch(cs)
        st["cache"] = cs
        if cfg.ccm.enabled:
            st["mem"] = mem_pspecs(cfg, dist, batch_sharded)
    if cfg.family == "encdec":
        ma = dist.model_axis if divisible(cfg.n_kv_heads, dist.n_model) \
            else None
        b = dist.batch_axes if batch_sharded else None
        st["cross"] = (P(None, b, None, ma, None),
                       P(None, b, None, ma, None))
    return OnlineState(**st)


def KVCacheReplaceBatch(cs):
    def unb(p):
        if p is None:
            return None
        dims = list(p)
        dims[1] = None
        return P(*dims)
    return cs._replace(k=unb(cs.k), v=unb(cs.v),
                       k_scale=unb(cs.k_scale), v_scale=unb(cs.v_scale))


def stream_state_pspecs(cfg: ModelConfig, dist: DistContext,
                        batch_sharded: bool = True):
    from repro.core.streaming import StreamState
    ma = dist.model_axis if divisible(cfg.n_kv_heads, dist.n_model) else None
    b = dist.batch_axes if batch_sharded else None
    win = P(None, b, None, ma, None)
    return StreamState(win_k=win, win_v=win, win_len=P(),
                       mem=mem_pspecs(cfg, dist, batch_sharded),
                       pos=P())


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None)


# ---------------------------------------------------------------------------
# serve-arena specs (session-axis sharding)
# ---------------------------------------------------------------------------

def arena_pspecs(template: Any, axis: str = "shards") -> Any:
    """PartitionSpec tree for a serve arena's slabs: every leaf is the
    session template with a leading ROW axis (`serve.arena`), sharded
    over ``axis`` — one contiguous row block (slots + scratch row) per
    device.  All other dims replicate; per-session state is already
    model-replicated on the serve path."""
    return jax.tree.map(lambda _: P(axis), template)


def arena_sharding(mesh, template: Any, axis: str = "shards") -> Any:
    """NamedSharding tree for `arena_pspecs` — pass as the arena's
    ``place`` hook: ``SessionArena(..., place=lambda slabs:
    jax.device_put(slabs, arena_sharding(mesh, template)))`` pins shard
    ``s``'s rows to mesh device ``s``."""
    return named(mesh, arena_pspecs(template, axis))
