"""Distribution context threaded through model apply functions.

Model code is mesh-agnostic; when a ``DistContext`` is provided, modules that
need explicit SPMD control (MoE dispatch, sequence-parallel attention) use
``shard_map`` over the named axes. When ``None`` (unit tests, single device),
pure local computation is used.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: object                     # jax.sharding.Mesh (or AbstractMesh)
    data_axes: Tuple[str, ...] = ("data",)   # batch/token sharding axes
    model_axis: str = "model"                # TP axis
    pod_axis: Optional[str] = None           # cross-pod axis (composes w/ data)

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return ((self.pod_axis,) if self.pod_axis else ()) + tuple(self.data_axes)

    @property
    def n_model(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def n_data(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


def divisible(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def shard_map_compat(f, mesh, in_specs, out_specs, check_vma=None,
                     axis_names=None):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(check_vma=..., axis_names=...)``;
    older releases only have ``jax.experimental.shard_map.shard_map``
    with ``check_rep`` and the complementary ``auto`` axis set."""
    if hasattr(jax, "shard_map"):
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
