"""Elastic scaling & failure recovery.

Recovery contract (DESIGN §6):
 1. every state that matters is in the checkpoint (params/opt/iterator),
 2. sharding specs are *functions of (cfg, mesh)*, never baked into state,
 3. the data iterator is stateless-indexable.

So recovery = build a new mesh from surviving devices -> re-derive specs ->
``CheckpointManager.restore`` with device_put onto the new mesh -> continue
at the checkpointed step. ``simulate_failure_and_recover`` drives that path
end-to-end (used by tests; on a real cluster the coordinator would re-exec
the launcher with the surviving slice).

Straggler mitigation: ``WatchdogStats`` (launch.train) flags slow steps; the
deterministic iterator allows skip-ahead (a lagging host jumps to the
current step index without replaying data) — bounded-skew recovery without
a global barrier.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.distributed import sharding as SH
from repro.distributed.context import DistContext
from repro.models.config import ModelConfig
from repro.optim import partition as PT


def remesh_restore(ckpt: CheckpointManager, step: int,
                   cfg: ModelConfig, new_mesh,
                   state_template: Any,
                   trainable: Any) -> Tuple[Any, dict, DistContext]:
    """Restore a checkpoint onto a (possibly different) mesh."""
    from repro.launch.mesh import make_dist
    dist = make_dist(new_mesh)
    pspecs = SH.param_pspecs(cfg, state_template["tp"], dist)
    tp_specs, _ = PT.partition(pspecs, trainable)
    # opt moments follow param specs
    specs = {"tp": tp_specs,
             "opt": SH.opt_pspecs(tp_specs,
                                  state_template["opt"])}
    state, extra = ckpt.restore(step, state_template, mesh=new_mesh,
                                specs=specs)
    return state, extra, dist


def simulate_failure_and_recover(loop_factory: Callable[[DistContext], Any],
                                 mesh_before, mesh_after,
                                 fail_after_steps: int,
                                 total_steps: int):
    """Run `fail_after_steps` on mesh_before, 'lose' devices, resume on
    mesh_after from the last checkpoint. Returns the recovered loop's
    history. loop_factory(dist) must return a TrainLoop with a ckpt dir."""
    from repro.launch.mesh import make_dist
    loop = loop_factory(make_dist(mesh_before))
    loop.run(fail_after_steps, log_every=0)
    loop.ckpt.wait()
    # --- failure: mesh_before is gone; rebuild on mesh_after
    loop2 = loop_factory(make_dist(mesh_after))
    start = loop2.maybe_restore()
    hist = loop2.run(total_steps, start_step=start, log_every=0)
    return hist, start
