"""Unified model / CCM configuration.

One ``ModelConfig`` covers every assigned architecture family:
dense / GQA / MQA decoder LMs, MoE, Mamba2 (SSD), Zamba2-style hybrids,
Whisper-style encoder-decoder (audio frontend stub) and Pixtral-style
VLM (vision frontend stub).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CCMConfig:
    """Compressed Context Memory (the paper's technique) configuration."""

    enabled: bool = True
    comp_len: int = 2            # tokens per <COMP> group (paper: 1..8)
    mode: str = "concat"         # 'concat' | 'merge'
    method: str = "ccm"          # 'ccm' | 'gisting' | 'compressive'
                                 # (paper baselines, §4.1: Gisting-online
                                 # compresses chunks independently;
                                 # Compressive Transformer mean-pools raw KV)
    merge_alpha: Optional[float] = None  # None -> arithmetic mean a_t=1/t; else EMA
    max_steps: int = 16          # T, max online time steps
    lora_rank: int = 8
    lora_alpha: float = 16.0
    lora_dropout: float = 0.05   # used only in training examples
    # streaming (paper Fig. 9): sliding window w/ attention sink + CCM
    stream_window: int = 4096    # max KV cache (local window) size
    stream_sink: int = 4         # attention-sink tokens kept forever
    stream_chunk: int = 64       # tokens compressed per compression event
    stream_mem_slots: int = 64   # max <COMP> groups kept in concat memory

    @property
    def mem_slots(self) -> int:
        """Number of <COMP>-group slots held in memory at T."""
        return self.max_steps if self.mode == "concat" else 1

    @property
    def mem_len(self) -> int:
        """Length (tokens) of the compressed memory at T."""
        return self.mem_slots * self.comp_len


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    activation: str = "swiglu"   # swiglu | geglu | gelu
    norm: str = "rms"            # rms | ln
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    pos_embed: str = "rope"      # rope | learned | none
    max_pos: int = 0             # learned position table size
    embed_scale: bool = False    # gemma: multiply embeddings by sqrt(d)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_impl: str = "ragged_tp"  # ragged_tp | ep (shard_map all_to_all)
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128         # SSD chunk length
    # --- hybrid (Zamba2): shared attention block every `attn_every` layers ---
    attn_every: int = 0
    # --- encoder-decoder (Whisper) ---
    n_enc_layers: int = 0
    # --- modality frontend stub ---
    frontend: str = "none"       # none | audio | vision
    n_frontend_tokens: int = 0   # e.g. patch tokens prepended (vlm)
    # --- CCM ---
    ccm: CCMConfig = dataclasses.field(default_factory=CCMConfig)
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # --- training mode for the end-to-end step ---
    train_mode: str = "lora"     # lora (paper: only delta-theta trains) | full
    # --- remat policy for scan-over-layers ---
    remat: bool = True
    # --- unroll layer stacks (dry-run cost calibration only) ---
    unroll_layers: bool = False
    # --- sharding strategy: tp (megatron-style) | fsdp (ZeRO-3 via GSPMD:
    #     weights sharded over the model axis, batch over ALL axes) ---
    sharding_strategy: str = "tp"
    # --- KV cache dtype: bfloat16 | int8 (per-(token,head) symmetric) ---
    kv_cache_dtype: str = "bfloat16"
    # --- serving cache bound: 0 = shape-specified full cache; >0 = CCM
    #     compressed serving (bounded window, paper Eq. 3) ---
    serve_cache_len: int = 0
    # --- attention impl: dense | chunked | pallas (TPU only).  The
    #     segmented decode/streaming hot path (attend_segments) also
    #     accepts 'concat' as an explicit baseline: materialize the
    #     [mem|cache|self] concatenation like the pre-segmented runtime ---
    attn_impl: str = "dense"
    attn_chunk: int = 1024       # k-block for the chunked/online-softmax path
    attn_seg_block: int = 512    # k-block for length-bounded KV segments
                                 # (decode work rounds cache.length up to it;
                                 # 512 balances skip granularity vs per-block
                                 # loop overhead on CPU — see decode_bench)
    # --- lane batching: route attend_segments through a custom_vmap rule
    #     so vmapped serve/stream lanes keep the tile-level skip (per-lane
    #     in the Pallas kernel, batch-max-bounded on the jnp path) instead
    #     of lowering the per-block `cond` to a capacity-bound `select`.
    #     False restores the legacy select-lowered vmap (benchmarks).
    #     NOTE: custom_vmap has no JVP rule, so the wrapped (non-concat)
    #     attend_segments paths cannot be differentiated while this is
    #     True — training differentiates models.attention.attend, never
    #     attend_segments; set False to grad through the inference paths ---
    attn_lane_batched: bool = True

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # rough parameter counts (used for roofline MODEL_FLOPS = 6*N*D) -----
    def param_count(self, active_only: bool = False) -> int:
        d, f, hd = self.d_model, self.d_ff, self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.activation in ("swiglu", "geglu"):
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        if self.n_experts:
            e = self.top_k if active_only else self.n_experts
            ffn = ffn * max(e, 1)
        per_layer = attn + ffn
        if self.family == "ssm":
            di, ds = self.d_inner, self.ssm_state
            per_layer = d * (2 * di + 2 * ds + self.ssm_heads) + di * d \
                + self.ssm_conv * (di + 2 * ds)
        if self.family == "hybrid":
            di, ds = self.d_inner, self.ssm_state
            mamba = d * (2 * di + 2 * ds + self.ssm_heads) + di * d \
                + self.ssm_conv * (di + 2 * ds)
            per_layer = mamba  # shared attn counted once below
        total = emb + self.n_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            total += attn + 3 * d * f  # one shared block
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + ffn) + self.n_layers * attn  # cross-attn
        return int(total)
