"""Mixture-of-Experts FFN: token-choice top-k routing, two SPMD strategies.

  ragged_tp — default. Token sort + ``jax.lax.ragged_dot`` grouped matmuls;
              expert weights are *tensor-parallel* (d_ff sharded on the model
              axis), tokens stay on their data shard, a single psum over the
              model axis combines partial outputs. No all_to_all; robust for
              any expert count (llama4's 128e and phi3.5's 16e).
  ep        — true expert parallelism. Experts are partitioned across the
              model axis; tokens are routed to expert owners with a
              capacity-bounded all_to_all inside shard_map (and back).
              Exercised in tests on a small mesh; selectable per config.

Router: softmax over expert logits (fp32), top-k, renormalized combine
weights (Mixtral convention). Dropless in ragged_tp; capacity-dropped in ep.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.context import DistContext, shard_map_compat
from repro.models.config import ModelConfig
from repro.models import layers as L


def init_moe(key, cfg: ModelConfig, d: int, f: int) -> Dict:
    E = cfg.n_experts
    ks = jax.random.split(key, 4)
    def ei(k, a, b):
        return (jax.random.normal(k, (E, a, b), jnp.float32) / jnp.sqrt(a)
                ).astype(cfg.pdtype)
    return {"router": L.dense_init(ks[0], d, E, jnp.float32),
            "wi": ei(ks[1], d, f), "wg": ei(ks[2], d, f), "wo": ei(ks[3], f, d)}


def _route(cfg: ModelConfig, router_w, xf):
    """xf (N, d) -> combine weights (N, k) fp32, expert ids (N, k) i32."""
    logits = (xf.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, topi.astype(jnp.int32)


def _expert_ffn(cfg: ModelConfig, p, xs, group_sizes):
    """Grouped (ragged) expert MLP. xs (M, d) sorted by expert."""
    wi = p["wi"].astype(xs.dtype)
    wg = p["wg"].astype(xs.dtype)
    wo = p["wo"].astype(xs.dtype)
    hg = jax.lax.ragged_dot(xs, wg, group_sizes)
    hi = jax.lax.ragged_dot(xs, wi, group_sizes)
    h = jax.nn.silu(hg) * hi
    return jax.lax.ragged_dot(h, wo, group_sizes)


def _moe_local(cfg: ModelConfig, p, xf):
    """Dropless sort-based MoE on one shard. xf (N, d) -> (N, d)."""
    N, d = xf.shape
    k, E = cfg.top_k, cfg.n_experts
    topw, topi = _route(cfg, p["router"], xf)
    eids = topi.reshape(-1)                                  # (N*k,)
    order = jnp.argsort(eids)                                # stable enough
    xr = jnp.repeat(xf, k, axis=0)[order]                    # (N*k, d)
    group_sizes = jnp.bincount(eids, length=E).astype(jnp.int32)
    y_sorted = _expert_ffn(cfg, p, xr, group_sizes)
    y = jnp.zeros_like(y_sorted).at[order].set(y_sorted)
    y = y.reshape(N, k, d) * topw[..., None].astype(y_sorted.dtype)
    return y.sum(axis=1)


# ---------------------------------------------------------------------------
# strategy: ragged_tp (shard_map over data x model; psum(model) combine)
# ---------------------------------------------------------------------------

def _moe_tp_shard(cfg: ModelConfig, p, xf, model_axis):
    """Per-shard body: experts' f-dim is local slice; combine via psum."""
    N, d = xf.shape
    k, E = cfg.top_k, cfg.n_experts
    topw, topi = _route(cfg, p["router"], xf)    # router fp32, replicated
    eids = topi.reshape(-1)
    order = jnp.argsort(eids)
    xr = jnp.repeat(xf, k, axis=0)[order]
    group_sizes = jnp.bincount(eids, length=E).astype(jnp.int32)
    wi = p["wi"].astype(xf.dtype)
    wg = p["wg"].astype(xf.dtype)
    wo = p["wo"].astype(xf.dtype)
    hg = jax.lax.ragged_dot(xr, wg, group_sizes)
    hi = jax.lax.ragged_dot(xr, wi, group_sizes)
    h = jax.nn.silu(hg) * hi                                  # local f-slice
    y_sorted = jax.lax.ragged_dot(h, wo, group_sizes)         # partial sum
    y_sorted = jax.lax.psum(y_sorted, model_axis)
    y = jnp.zeros_like(y_sorted).at[order].set(y_sorted)
    y = y.reshape(N, k, d) * topw[..., None].astype(y_sorted.dtype)
    return y.sum(axis=1)


# ---------------------------------------------------------------------------
# strategy: ep (expert parallel, capacity-bounded all_to_all)
# ---------------------------------------------------------------------------

def _moe_ep_shard(cfg: ModelConfig, p, xf, model_axis, ep: int,
                  capacity_factor: float = 1.25):
    """Per-shard body under shard_map: p['wi'] etc are (E/ep, d, f) local.

    Each shard routes its N local tokens, packs per-destination-shard
    buffers of fixed capacity C, all_to_all's them to expert owners,
    runs the local experts, and sends results back.
    """
    N, d = xf.shape
    k, E = cfg.top_k, cfg.n_experts
    e_local = E // ep
    C = int((N * k / ep) * capacity_factor) + 1
    topw, topi = _route(cfg, p["router"], xf)
    eids = topi.reshape(-1)                       # (N*k,)
    dest = eids // e_local                        # owner shard per assignment
    # position of each assignment within its destination buffer
    onehot = jax.nn.one_hot(dest, ep, dtype=jnp.int32)         # (N*k, ep)
    prior = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_dest = jnp.take_along_axis(prior, dest[:, None], axis=1)[:, 0]
    pos = jnp.where(pos_in_dest < C, pos_in_dest, C)           # drop overflow
    xr = jnp.repeat(xf, k, axis=0)
    buf = jnp.zeros((ep, C + 1, d), xr.dtype)
    buf = buf.at[dest, pos].set(xr)                            # (ep, C+1, d)
    ebuf = jnp.full((ep, C + 1), e_local, jnp.int32)           # pad -> no-op id
    ebuf = ebuf.at[dest, pos].set(eids % e_local)
    buf = buf[:, :C]
    ebuf = ebuf[:, :C]
    # exchange: rows -> expert owners
    rbuf = jax.lax.all_to_all(buf, model_axis, 0, 0, tiled=False)   # (ep,C,d)
    rebuf = jax.lax.all_to_all(ebuf, model_axis, 0, 0, tiled=False)
    rx = rbuf.reshape(ep * C, d)
    re = rebuf.reshape(ep * C)
    order = jnp.argsort(re)
    gs = jnp.bincount(re, length=e_local + 1).astype(jnp.int32)
    pe = {kk: jnp.concatenate([p[kk], jnp.zeros_like(p[kk][:1])])
          for kk in ("wi", "wg", "wo")}                        # no-op expert
    ys = _expert_ffn(cfg, pe, rx[order], gs)
    y = jnp.zeros_like(ys).at[order].set(ys).reshape(ep, C, d)
    y = jax.lax.all_to_all(y, model_axis, 0, 0, tiled=False)   # back home
    out = y[dest, pos] * (pos_in_dest < C)[:, None].astype(y.dtype)
    out = out.reshape(N, k, d) * topw[..., None].astype(y.dtype)
    return out.sum(axis=1)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def apply_moe(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
              dist: Optional[DistContext] = None) -> jnp.ndarray:
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    if dist is None:
        return _moe_local(cfg, p, xf).reshape(B, S, d)

    ba, ma = dist.batch_axes, dist.model_axis
    if cfg.moe_impl == "ep":
        ep = dist.n_model
        body = functools.partial(_moe_ep_shard, cfg, model_axis=ma, ep=ep)
        y = shard_map_compat(
            lambda pp, xx: body(pp, xf=xx),
            mesh=dist.mesh,
            in_specs=({"router": P(), "wi": P(ma), "wg": P(ma), "wo": P(ma)},
                      P(ba)),
            out_specs=P(ba),
            check_vma=False,   # every model shard reproduces the combine
        )(p, xf)
    else:
        body = functools.partial(_moe_tp_shard, cfg, model_axis=ma)
        y = shard_map_compat(
            lambda pp, xx: body(pp, xx),
            mesh=dist.mesh,
            in_specs=({"router": P(), "wi": P(None, None, ma),
                       "wg": P(None, None, ma), "wo": P(None, ma, None)},
                      P(ba)),
            out_specs=P(ba),
        )(p, xf)
    return y.reshape(B, S, d)
