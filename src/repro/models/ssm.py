"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD algorithm: within-chunk attention-like diagonal blocks on the
MXU plus an inter-chunk linear recurrence over per-chunk states — the
TPU-friendly formulation (contiguous (chunk x chunk) and (P x N) matmuls,
one short ``lax.scan`` across chunks instead of a length-S scan).

Single-group variant (n_groups = 1): B/C shared across heads.

State for decoding: s (B, H, P, N) with
    s_t = exp(dt*A) * s_{t-1} + dt * B_t (outer) x_t ;  y_t = C_t . s_t + D*x_t
— the arch's native "compressed context memory" (cf. DESIGN §5: CCM is
inapplicable to attention-free layers; this state plays the same role).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L


def init_mamba(key, cfg: ModelConfig, d: int) -> Dict:
    di, N, H, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * di + 2 * N + H   # z, x, B, C, dt
    conv_dim = di + 2 * N
    return {
        "in_proj": L.dense_init(ks[0], d, d_in_proj, cfg.pdtype),
        "conv_w": (jax.random.normal(ks[1], (K, conv_dim), jnp.float32)
                   / jnp.sqrt(K)).astype(cfg.pdtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": {"scale": jnp.zeros((di,), cfg.pdtype)},
        "out_proj": L.dense_init(ks[2], di, d, cfg.pdtype),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x (..., Q) -> (..., Q, Q) with out[..., i, j] = sum_{j<k<=i} x[..., k],
    -inf above the diagonal (strictly causal cumulative log-decay)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x (B,S,C), w (K,C). Returns y and the last
    K-1 inputs (decode conv state)."""
    K = w.shape[0]
    pad = state if state is not None else jnp.zeros(
        (x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None].astype(x.dtype)
            for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return y + b.astype(x.dtype), new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int,
                init_state: Optional[jnp.ndarray] = None):
    """SSD scan. x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,N).

    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    B_, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = chunk
    nc = S // Q
    assert S % Q == 0, "seq must be divisible by ssm_chunk"
    xc = x.reshape(B_, nc, Q, H, Pd)
    dtc = dt.reshape(B_, nc, Q, H)
    Bc = Bm.reshape(B_, nc, Q, N)
    Cc = Cm.reshape(B_, nc, Q, N)
    dA = dtc * A[None, None, None, :]                 # (B,nc,Q,H) log-decay
    dA = dA.astype(jnp.float32)

    # --- diagonal (within-chunk) term: attention-like on the MXU
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))        # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc).astype(jnp.float32)
    M = scores[:, :, None] * Lmat                            # (B,nc,H,Q,Q)
    xdt = xc * dtc[..., None]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", M.astype(x.dtype),
                        xdt.astype(x.dtype))

    # --- per-chunk states: S_c = sum_k exp(sum_{j>k} dA_j) * dt_k B_k x_k^T
    dA_cum = jnp.cumsum(dA, axis=2)                          # (B,nc,Q,H)
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)    # (B,nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn",
                        Bc.astype(jnp.float32), (dtc * decay_states),
                        xc.astype(jnp.float32))              # (B,nc,H,P,N)

    # --- inter-chunk recurrence (short scan over nc chunks)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # (B,nc,H)
    s0 = init_state.astype(jnp.float32) if init_state is not None else \
        jnp.zeros((B_, H, Pd, N), jnp.float32)

    def step(s, xs):
        dec, st = xs                                         # (B,H), (B,H,P,N)
        s_new = s * dec[..., None, None] + st
        return s_new, s                                      # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, s0, (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                 # (B,nc,H,P,N)

    # --- off-diagonal: y_off[q] = C_q . (exp(dA_cum_q) * S_prev)
    out_decay = jnp.exp(dA_cum)                              # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                       Cc.astype(jnp.float32), out_decay,
                       prev_states)
    y = (y_diag.astype(jnp.float32) + y_off).reshape(B_, S, H, Pd)
    return y.astype(x.dtype), final.astype(x.dtype)


def apply_mamba(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                state: Optional[Dict] = None,
                decode: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """Mamba2 block. x (B,S,d). state = {'ssm': (B,H,P,N), 'conv': (B,K-1,C)}.

    decode=True uses the O(1) recurrence (S small, typically 1).
    """
    B_, S, d = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    Pd = cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xr, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state)
    conv_out = jax.nn.silu(conv_out)
    xr, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])          # (B,S,H)
    A = -jnp.exp(p["a_log"])                                  # (H,) < 0
    xh = xr.reshape(B_, S, H, Pd)
    ssm_state = state["ssm"] if state is not None else None

    if decode:
        s = ssm_state.astype(jnp.float32) if ssm_state is not None else \
            jnp.zeros((B_, H, Pd, N), jnp.float32)

        def one(s, t):
            dec = jnp.exp(dt[:, t] * A[None])                # (B,H)
            upd = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, t].astype(jnp.float32),
                             dt[:, t], xh[:, t].astype(jnp.float32))
            s = s * dec[..., None, None] + upd
            y = jnp.einsum("bn,bhpn->bhp", Cm[:, t].astype(jnp.float32), s)
            return s, y

        s, ys = jax.lax.scan(one, s, jnp.arange(S))
        y = ys.swapaxes(0, 1)                                 # (B,S,H,P)
        final = s
    else:
        y, final = ssd_chunked(xh, dt, A, Bm, Cm,
                               min(cfg.ssm_chunk, S), ssm_state)

    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = L.rms_norm(y, p["norm"]["scale"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = {"ssm": final.astype(x.dtype) if not decode else
                 final.astype(x.dtype), "conv": new_conv}
    return out, new_state
