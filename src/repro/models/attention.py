"""Attention: GQA/MQA/MHA with CCM-aware masking, three implementations.

  dense   — einsum logits + additive mask; short sequences / merge-mode
            training with virtual memory slots.
  chunked — double-blocked online-softmax (flash-style) in pure jnp; the
            CPU/compile-analysis analogue of the Pallas kernel. Mask is
            evaluated per (q-block, k-block) from per-token metadata
            (index, segment id, is-<COMP>), never materialized at S×S.
  pallas  — repro.kernels.ccm_attention (TPU target; interpret-validated).

Conventions: q (B, Sq, Hq, D); k/v (B, Sk, Hkv, D); GQA grouping is done
here (no materialized head repetition). Softmax statistics in fp32.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import custom_batching

from repro.core import lora as lora_lib
from repro.core.masks import NEG_INF
from repro.models.config import ModelConfig
from repro.models import layers as L


class KeyInfo(NamedTuple):
    """Per-token metadata driving the CCM mask, all shape (Sk,) or (Sq,).

    idx  : global position index used for causality (mem keys get -1).
    seg  : CCM segment id (mem keys 0; plain causal = all zeros + comp 1s).
    comp : True where the key is a <COMP> token / memory slot.
    valid: False at padding (keys only).
    """
    idx: jnp.ndarray
    seg: jnp.ndarray
    comp: jnp.ndarray
    valid: Optional[jnp.ndarray] = None


def plain_causal_info(length: int, offset: int = 0) -> KeyInfo:
    idx = jnp.arange(length, dtype=jnp.int32) + offset
    z = jnp.zeros((length,), jnp.int32)
    return KeyInfo(idx=idx, seg=z, comp=jnp.ones((length,), bool))


def mem_key_info(length: int, valid: Optional[jnp.ndarray] = None) -> KeyInfo:
    """Memory keys: always visible (idx=-1, comp=True)."""
    return KeyInfo(idx=jnp.full((length,), -1, jnp.int32),
                   seg=jnp.zeros((length,), jnp.int32),
                   comp=jnp.ones((length,), bool),
                   valid=valid)


def concat_info(a: KeyInfo, b: KeyInfo) -> KeyInfo:
    def cat(x, y, fill_x, fill_y):
        if x is None and y is None:
            return None
        if x is None:
            x = fill_x
        if y is None:
            y = fill_y
        return jnp.concatenate([x, y])
    va = jnp.ones(a.idx.shape, bool)
    vb = jnp.ones(b.idx.shape, bool)
    return KeyInfo(idx=jnp.concatenate([a.idx, b.idx]),
                   seg=jnp.concatenate([a.seg, b.seg]),
                   comp=jnp.concatenate([a.comp, b.comp]),
                   valid=cat(a.valid, b.valid, va, vb))


def mask_from_info(q: KeyInfo, k: KeyInfo) -> jnp.ndarray:
    """(Q, K) CCM mask: causal AND (same-segment OR k-is-comp) AND k-valid."""
    causal = k.idx[None, :] <= q.idx[:, None]
    allow = (k.seg[None, :] == q.seg[:, None]) | k.comp[None, :]
    m = causal & allow
    if k.valid is not None:
        m = m & k.valid[None, :]
    return m


# ---------------------------------------------------------------------------
# core attends
# ---------------------------------------------------------------------------

def _group(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    B, S, Hq, D = q.shape
    return q.reshape(B, S, n_kv, Hq // n_kv, D)


def attend_dense(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 mask: Optional[jnp.ndarray], scale: float) -> jnp.ndarray:
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D), mask (Sq,Sk) or (B,Sq,Sk) or None."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    qg = _group(q, Hkv)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        else:
            mask = mask[:, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, Sq, Hq, D)


def _pad_to(x: jnp.ndarray, mult: int, axis: int, fill=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill), n


def attend_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   q_info: KeyInfo, k_info: KeyInfo, scale: float,
                   q_chunk: int = 512, k_chunk: int = 1024) -> jnp.ndarray:
    """Double-blocked online-softmax attention with CCM mask.

    Memory high-watermark per step: O(B * Hq * q_chunk * k_chunk) — the CPU
    analogue of the Pallas flash kernel's VMEM tiling.
    """
    B, Sq0, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    valid = k_info.valid if k_info.valid is not None \
        else jnp.ones((k.shape[1],), bool)

    q, _ = _pad_to(q, q_chunk, axis=1)
    qi_idx, _ = _pad_to(q_info.idx, q_chunk, axis=0, fill=-(10 ** 9))
    qi_seg, _ = _pad_to(q_info.seg, q_chunk, axis=0, fill=-1)
    k, _ = _pad_to(k, k_chunk, axis=1)
    v, _ = _pad_to(v, k_chunk, axis=1)
    ki_idx, _ = _pad_to(k_info.idx, k_chunk, axis=0, fill=10 ** 9)
    ki_seg, _ = _pad_to(k_info.seg, k_chunk, axis=0, fill=-2)
    ki_comp, _ = _pad_to(k_info.comp, k_chunk, axis=0, fill=False)
    ki_valid, _ = _pad_to(valid, k_chunk, axis=0, fill=False)

    Sq, Sk = q.shape[1], k.shape[1]
    nq, nk = Sq // q_chunk, Sk // k_chunk
    qg = _group(q, Hkv).reshape(B, nq, q_chunk, Hkv, G, D)
    kb = k.reshape(B, nk, k_chunk, Hkv, D)
    vb = v.reshape(B, nk, k_chunk, Hkv, D)

    def q_block(carrys, xs):
        qblk, qidx, qseg = xs  # (B,qc,Hkv,G,D), (qc,), (qc,)

        def k_step(state, kxs):
            m_i, l_i, acc = state
            kblk, vblk, kidx, kseg, kcomp, kval = kxs
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk)
            logits = logits.astype(jnp.float32) * scale
            msk = (kidx[None, :] <= qidx[:, None]) \
                & ((kseg[None, :] == qseg[:, None]) | kcomp[None, :]) \
                & kval[None, :]
            logits = jnp.where(msk[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_i, logits.max(axis=-1))
            alpha = jnp.exp(m_i - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_i * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] \
                + jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(qblk.dtype), vblk)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
             ki_idx.reshape(nk, k_chunk), ki_seg.reshape(nk, k_chunk),
             ki_comp.reshape(nk, k_chunk), ki_valid.reshape(nk, k_chunk)))
        out = acc / jnp.maximum(l_f[..., None], 1e-37)
        return carrys, out.astype(qblk.dtype)

    _, outs = jax.lax.scan(
        q_block, (),
        (qg.swapaxes(0, 1), qi_idx.reshape(nq, q_chunk),
         qi_seg.reshape(nq, q_chunk)))
    # outs: (nq, B, Hkv, G, qc, D) -> (B, Sq, Hq, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hq, D)
    return out[:, :Sq0]


def attend(cfg: ModelConfig, q, k, v, q_info: KeyInfo, k_info: KeyInfo,
           impl: Optional[str] = None) -> jnp.ndarray:
    scale = 1.0 / (cfg.hd ** 0.5)
    impl = impl or cfg.attn_impl
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.ccm_attention(q, k, v, q_info, k_info, scale)
    if impl == "chunked":
        return attend_chunked(q, k, v, q_info, k_info, scale,
                              q_chunk=min(cfg.attn_chunk, 512),
                              k_chunk=cfg.attn_chunk)
    mask = mask_from_info(q_info, k_info)
    return attend_dense(q, k, v, mask, scale)


# ---------------------------------------------------------------------------
# segmented attention — the decode / streaming hot path
#
# A (usually small) q block attends an ordered list of KV segments —
# [mem | cache(:length) | self] — each read IN PLACE from its own array.
# No concatenated KV and no concatenated KeyInfo metadata is ever
# materialized; a running softmax (m, l, acc) is folded across segments
# and, inside a segment, across k-blocks.  Work on a length-bounded
# segment scales with `length` rounded up to `cfg.attn_seg_block`
# (blocks past the valid prefix are skipped via `lax.cond`), not with the
# segment's allocated capacity, and int8 segments are dequantized one
# tile at a time (never as a full-cache fp copy).
# ---------------------------------------------------------------------------


class KVSegment(NamedTuple):
    """One in-place KV region consumed by :func:`attend_segments`.

    k/v      : (B, S, Hkv, hd) — compute dtype, or int8 with scales.
               With ``layer`` set, the STACKED per-layer state
               (L, B, S, Hkv, hd): blocks are sliced straight out of it,
               so a scanned layer body never materializes its layer's
               cache slice (the per-layer `xs` copy of the concat era).
    info     : per-token ``KeyInfo``; None marks a *memory-like* segment
               whose keys are always visible (idx=-1, seg=0, comp=True).
    length   : () int32 valid-prefix length (None = fully valid).  Blocked
               paths skip whole k-blocks past it.
    k_scale/v_scale : (B, S, Hkv) fp32 when k/v are int8-quantized
               ((L, B, S, Hkv) when ``layer`` is set).
    layer    : () int32 index into the leading layer axis, or None.

    Under `jax.vmap` (serve session lanes) each field may carry a mapped
    lane axis — per-lane lengths, metadata and stacked caches; the
    `custom_vmap` rule in :func:`attend_segments` rewrites the batch
    into the lane schema of `kernels.decode_attention` so the per-block
    tile skip stays per-lane instead of lowering to `select`.
    """
    k: jnp.ndarray
    v: jnp.ndarray
    info: Optional[KeyInfo] = None
    length: Optional[jnp.ndarray] = None
    k_scale: Optional[jnp.ndarray] = None
    v_scale: Optional[jnp.ndarray] = None
    layer: Optional[jnp.ndarray] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def n_tokens(self) -> int:
        return self.k.shape[2 if self.layer is not None else 1]


def _dequant(x: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return x.astype(dtype) * scale[..., None].astype(dtype)


def _slice_rows(arr, layer, start, width, token_axis: int = 1):
    """(B, width, ...) window of ``arr`` at ``start`` along the token
    axis; with ``layer``, ``arr`` carries a leading layer axis and only
    the window of that layer is ever read (no layer-slice copy)."""
    if layer is None:
        return jax.lax.dynamic_slice_in_dim(arr, start, width, token_axis)
    starts = [jnp.asarray(layer, jnp.int32)] \
        + [jnp.zeros((), jnp.int32)] * (arr.ndim - 1)
    starts[token_axis + 1] = jnp.asarray(start, jnp.int32)
    sizes = list(arr.shape)
    sizes[0], sizes[token_axis + 1] = 1, width
    return jax.lax.dynamic_slice(arr, starts, sizes)[0]


def _seg_layer_kv(seg: KVSegment):
    """Materialize the segment's (B, S, ...) layer view (concat baseline /
    oracle paths only — the segmented paths slice windows instead)."""
    if seg.layer is None:
        return seg.k, seg.v, seg.k_scale, seg.v_scale
    ix = functools.partial(jax.lax.dynamic_index_in_dim, index=seg.layer,
                          axis=0, keepdims=False)
    return (ix(seg.k), ix(seg.v),
            None if seg.k_scale is None else ix(seg.k_scale),
            None if seg.v_scale is None else ix(seg.v_scale))


def segment_key_info(seg: KVSegment) -> KeyInfo:
    """Explicit KeyInfo for one segment (concat baseline / oracles only —
    the segmented paths never materialize this)."""
    S = seg.n_tokens
    if seg.info is not None:
        info = seg.info
    else:
        info = KeyInfo(idx=jnp.full((S,), -1, jnp.int32),
                       seg=jnp.zeros((S,), jnp.int32),
                       comp=jnp.ones((S,), bool))
    if seg.length is not None:
        lv = jnp.arange(S) < seg.length
        info = info._replace(
            valid=lv if info.valid is None else info.valid & lv)
    return info


def _fold_block(state, qg, kb, vb, mask, scale):
    """Online-softmax update of (m, l, acc) with one k-block.

    qg (B,Sq,Hkv,G,D); kb/vb (B,bk,Hkv,D); mask (Sq,bk)/(1,bk) shared
    across the batch, (B,Sq,bk)/(B,1,bk) per-lane, or None.  Masked
    columns contribute exactly 0 to l/acc, so padding a segment (or a
    lane) leaves the statistics bit-identical.
    """
    m_i, l_i, acc = state
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb).astype(jnp.float32) * scale
    if mask is not None:
        mask = mask[None, None, None] if mask.ndim == 2 \
            else mask[:, None, None]
        s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m_i, s.max(axis=-1))
    alpha = jnp.exp(m_i - m_new)
    p = jnp.exp(s - m_new[..., None])
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    l_new = l_i * alpha + p.sum(axis=-1)
    acc = acc * alpha[..., None] \
        + jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(qg.dtype), vb
                     ).astype(jnp.float32)
    return (m_new, l_new, acc)


def _fold_segment(state, qg, qidx, qseg, seg: KVSegment, scale: float,
                  block: int):
    """Fold one KV segment into the running softmax, k-block by k-block,
    skipping blocks entirely past the segment's valid prefix."""
    S = seg.n_tokens
    info, L = seg.info, seg.length
    dt = qg.dtype

    def slice_kv(start, width, dyn):
        kb = _slice_rows(seg.k, seg.layer, start, width)
        vb = _slice_rows(seg.v, seg.layer, start, width)
        if seg.quantized:           # tile-wise dequant — no full-cache copy
            kb = _dequant(kb, _slice_rows(seg.k_scale, seg.layer, start,
                                          width), dt)
            vb = _dequant(vb, _slice_rows(seg.v_scale, seg.layer, start,
                                          width), dt)
        return kb.astype(dt), vb.astype(dt)

    def block_mask(start, width, dyn):
        def sl(a):
            return jax.lax.dynamic_slice_in_dim(a, start, width, 0) \
                if dyn else a[start:start + width]
        mask = None
        if info is not None:
            mask = (sl(info.idx)[None, :] <= qidx[:, None]) \
                & ((sl(info.seg)[None, :] == qseg[:, None])
                   | sl(info.comp)[None, :])
            if info.valid is not None:
                mask = mask & sl(info.valid)[None, :]
        if L is not None:
            lv = ((start + jnp.arange(width)) < L)[None, :]
            mask = lv if mask is None else mask & lv
        return mask

    def do_block(st, start, width, dyn):
        kb, vb = slice_kv(start, width, dyn)
        return _fold_block(st, qg, kb, vb, block_mask(start, width, dyn),
                           scale)

    bs = min(S, block)
    nfull, tail = divmod(S, bs)
    if nfull == 1 and tail == 0:
        return do_block(state, 0, bs, dyn=False)
    if nfull:
        starts = jnp.arange(nfull, dtype=jnp.int32) * bs

        def body(carry, start):
            if L is None:
                return do_block(carry, start, bs, dyn=True), None
            return jax.lax.cond(start < L,
                                lambda c: do_block(c, start, bs, dyn=True),
                                lambda c: c, carry), None

        state, _ = jax.lax.scan(body, state, starts)
    if tail:
        t0 = nfull * bs
        if L is None:
            state = do_block(state, t0, tail, dyn=False)
        else:
            state = jax.lax.cond(
                t0 < L, lambda c: do_block(c, t0, tail, dyn=False),
                lambda c: c, state)
    return state


def _attend_segments_online(cfg: ModelConfig, q, segments, q_info: KeyInfo,
                            scale: float) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    Hkv = segments[0].k.shape[-2]
    G = Hq // Hkv

    def one_q_block(qblk, qidx, qseg):
        qc = qblk.shape[1]
        qg = qblk.reshape(B, qc, Hkv, G, D)
        state = (jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32),
                 jnp.zeros((B, Hkv, G, qc), jnp.float32),
                 jnp.zeros((B, Hkv, G, qc, D), jnp.float32))
        for seg in segments:
            blk = cfg.attn_seg_block if seg.length is not None \
                else cfg.attn_chunk
            state = _fold_segment(state, qg, qidx, qseg, seg, scale, blk)
        m_f, l_f, acc = state
        out = acc / jnp.maximum(l_f, 1e-37)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(B, qc, Hq, D
                                                    ).astype(qblk.dtype)

    q_chunk = min(cfg.attn_chunk, 512)
    if Sq <= q_chunk:
        return one_q_block(q, q_info.idx, q_info.seg)
    # large-q (prefill) path: fold per q-block so the peak per-step
    # buffer stays O(q_chunk * k_block), mirroring attend_chunked
    qp, _ = _pad_to(q, q_chunk, axis=1)
    qi, _ = _pad_to(q_info.idx, q_chunk, axis=0, fill=-(10 ** 9))
    qs, _ = _pad_to(q_info.seg, q_chunk, axis=0, fill=-3)
    nq = qp.shape[1] // q_chunk

    def body(carry, xs):
        qblk, qidx, qseg = xs
        return carry, one_q_block(qblk, qidx, qseg)

    _, outs = jax.lax.scan(
        body, (),
        (qp.reshape(B, nq, q_chunk, Hq, D).swapaxes(0, 1),
         qi.reshape(nq, q_chunk), qs.reshape(nq, q_chunk)))
    return outs.swapaxes(0, 1).reshape(B, nq * q_chunk, Hq, D)[:, :Sq]


# ---------------------------------------------------------------------------
# lane-batched segmented attention — the serve-vmap route
#
# Under `launch.serve.session_vmap` every serve lane is an independent
# session, so `cache.length` (and the ragged valid masks) are *batched*
# and the per-block `lax.cond` skip of `_fold_segment` would lower to a
# `select`: every lane computes capacity-bounded attention.  Instead,
# `attend_segments` wraps its dispatch in `jax.custom_batching.custom_vmap`
# whose rule re-expresses the batch in the *lane schema* (lane axis folded
# into the batch axis, per-lane lengths/metadata as arrays, per-lane
# stacked caches lane-major) and calls a lane-aware implementation:
#
#   pallas — the kernel's lane grid axis + 2-D scalar prefetch skips each
#            lane's k-blocks past its OWN valid prefix;
#   jnp    — `_fold_segment_lanes` keeps a REAL `cond` by predicating on
#            the batch max length (a scalar), so work scales with the
#            largest lane occupancy in the batch, not with capacity, and
#            per-lane masks keep lanes numerically independent.
#
# Masked-out columns contribute exactly 0 to the running softmax, so the
# lane route is bit-identical to running each lane unbatched.  Layouts
# the rule cannot express (per-lane layer ids, inner batch > 1, a shared
# stacked cache) fall back to plain `jax.vmap` of the unbatched dispatch —
# the legacy select-lowered semantics.
# ---------------------------------------------------------------------------


class _LaneFallback(Exception):
    """Batched layout with no lane-schema equivalent; use plain vmap."""


def _lane_normalize(axis_size, in_batched, q, segments, q_info: KeyInfo):
    """(vmap-batched args) -> (q (N,Sq,Hq,D), lane seg dicts, qidx, qseg).

    Batched leaves arrive with the mapped lane axis at dim 0; shared
    leaves are broadcast.  Raises `_LaneFallback` for layouts outside
    the lane schema."""
    N = axis_size
    qB, segsB, qiB = in_batched
    if not qB or q.shape[1] != 1:
        raise _LaneFallback   # lanes must be single-session (inner B=1)
    ql = q[:, 0]

    def meta(x, batched, dtype):
        if x is None:
            return None
        x = jnp.asarray(x).astype(dtype)
        return x if batched else jnp.broadcast_to(x, (N,) + x.shape)

    qidx = meta(q_info.idx, qiB.idx, jnp.int32)
    qseg = meta(q_info.seg, qiB.seg, jnp.int32)
    dicts = []
    for s, sb in zip(segments, segsB):
        layered = s.layer is not None
        if layered and (sb.layer or not sb.k):
            # per-lane layer ids, or a stacked cache shared across lanes:
            # neither has a lane-major layout without a full-cache copy
            raise _LaneFallback
        d = {"lane_major": layered}
        for key in ("k", "v", "k_scale", "v_scale"):
            a = getattr(s, key)
            if a is None:
                d[key] = None
                continue
            if getattr(sb, key):
                if a.shape[2 if layered else 1] != 1:
                    raise _LaneFallback
                d[key] = a[:, :, 0] if layered else a[:, 0]
            else:
                if layered or a.shape[0] != 1:
                    raise _LaneFallback
                d[key] = jnp.broadcast_to(a[0], (N,) + a.shape[1:])
        d["layer"] = None if s.layer is None \
            else jnp.asarray(s.layer, jnp.int32)
        d["length"] = None if s.length is None else jnp.broadcast_to(
            jnp.asarray(s.length, jnp.int32), (N,))
        if s.info is None:
            d.update(idx=None, seg=None, comp=None, valid=None)
        else:
            ib = sb.info
            d["idx"] = meta(s.info.idx, ib.idx, jnp.int32)
            d["seg"] = meta(s.info.seg, ib.seg, jnp.int32)
            d["comp"] = meta(s.info.comp, ib.comp, bool)
            d["valid"] = None if s.info.valid is None \
                else meta(s.info.valid, ib.valid, bool)
        dicts.append(d)
    return ql, dicts, qidx, qseg


def _fold_segment_lanes(state, qg, qidx, qseg, seg: Dict, scale: float,
                        block: int):
    """`_fold_segment` over the lane schema: seg a dict with per-lane
    length (N,), metadata (N, S) and (for layered segments) a lane-major
    stacked cache (N, L, S, Hkv, D) at a lane-shared ``layer``.  Blocks
    past the BATCH max length are skipped by a real `cond` (the predicate
    is a scalar); per-lane validity inside a block is a mask column that
    contributes exactly zero."""
    layered = seg.get("layer") is not None
    S = seg["k"].shape[2 if layered else 1]
    N = qg.shape[0]
    L = seg.get("length")
    idx = seg.get("idx")
    dt = qg.dtype
    layer = seg.get("layer")

    def slice_kv(start, width):
        def sl(a):
            if layered:
                starts = [jnp.zeros((), jnp.int32),
                          jnp.asarray(layer, jnp.int32),
                          jnp.asarray(start, jnp.int32)] \
                    + [jnp.zeros((), jnp.int32)] * (a.ndim - 3)
                return jax.lax.dynamic_slice(
                    a, starts, (N, 1, width) + a.shape[3:])[:, 0]
            return jax.lax.dynamic_slice_in_dim(a, start, width, 1)
        kb, vb = sl(seg["k"]), sl(seg["v"])
        if seg.get("k_scale") is not None:
            kb = _dequant(kb, sl(seg["k_scale"]), dt)
            vb = _dequant(vb, sl(seg["v_scale"]), dt)
        return kb.astype(dt), vb.astype(dt)

    def block_mask(start, width):
        def msl(a):
            return jax.lax.dynamic_slice(
                a, (jnp.zeros((), jnp.int32), jnp.asarray(start, jnp.int32)),
                (N, width))
        mask = None
        if idx is not None:
            mask = (msl(idx)[:, None, :] <= qidx[:, :, None]) \
                & ((msl(seg["seg"])[:, None, :] == qseg[:, :, None])
                   | msl(seg["comp"])[:, None, :])
            if seg.get("valid") is not None:
                mask = mask & msl(seg["valid"])[:, None, :]
        if L is not None:
            lv = ((start + jnp.arange(width))[None, :] < L[:, None])
            lv = lv[:, None, :]
            mask = lv if mask is None else mask & lv
        return mask

    def do_block(st, start, width):
        kb, vb = slice_kv(start, width)
        return _fold_block(st, qg, kb, vb, block_mask(start, width), scale)

    Lmax = None if L is None else jnp.max(L)
    bs = min(S, block)
    nfull, tail = divmod(S, bs)
    if nfull == 1 and tail == 0:
        return do_block(state, jnp.zeros((), jnp.int32), bs)
    if nfull:
        starts = jnp.arange(nfull, dtype=jnp.int32) * bs

        def body(carry, start):
            if Lmax is None:
                return do_block(carry, start, bs), None
            return jax.lax.cond(start < Lmax,
                                lambda c: do_block(c, start, bs),
                                lambda c: c, carry), None

        state, _ = jax.lax.scan(body, state, starts)
    if tail:
        t0 = jnp.asarray(nfull * bs, jnp.int32)
        if Lmax is None:
            state = do_block(state, t0, tail)
        else:
            state = jax.lax.cond(
                t0 < Lmax, lambda c: do_block(c, t0, tail),
                lambda c: c, state)
    return state


def _attend_segments_lanes_online(cfg: ModelConfig, q, segs, qidx, qseg,
                                  scale: float) -> jnp.ndarray:
    """Lane-schema analogue of `_attend_segments_online`: q (N,Sq,Hq,D)
    with N independent lanes, per-lane metadata (N, Sq)/(N, S)."""
    N, Sq, Hq, D = q.shape
    Hkv = segs[0]["k"].shape[-2]
    G = Hq // Hkv

    def one_q_block(qblk, qi, qs):
        qc = qblk.shape[1]
        qg = qblk.reshape(N, qc, Hkv, G, D)
        state = (jnp.full((N, Hkv, G, qc), NEG_INF, jnp.float32),
                 jnp.zeros((N, Hkv, G, qc), jnp.float32),
                 jnp.zeros((N, Hkv, G, qc, D), jnp.float32))
        for s in segs:
            blk = cfg.attn_seg_block if s.get("length") is not None \
                else cfg.attn_chunk
            state = _fold_segment_lanes(state, qg, qi, qs, s, scale, blk)
        m_f, l_f, acc = state
        out = acc / jnp.maximum(l_f, 1e-37)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(N, qc, Hq, D
                                                    ).astype(qblk.dtype)

    q_chunk = min(cfg.attn_chunk, 512)
    if Sq <= q_chunk:
        return one_q_block(q, qidx, qseg)
    qp, _ = _pad_to(q, q_chunk, axis=1)
    qi, _ = _pad_to(qidx, q_chunk, axis=1, fill=-(10 ** 9))
    qs, _ = _pad_to(qseg, q_chunk, axis=1, fill=-3)
    nq = qp.shape[1] // q_chunk

    def body(carry, xs):
        return carry, one_q_block(*xs)

    _, outs = jax.lax.scan(
        body, (),
        (qp.reshape(N, nq, q_chunk, Hq, D).swapaxes(0, 1),
         qi.reshape(N, nq, q_chunk).swapaxes(0, 1),
         qs.reshape(N, nq, q_chunk).swapaxes(0, 1)))
    return outs.swapaxes(0, 1).reshape(N, nq * q_chunk, Hq, D)[:, :Sq]


def attend_segments(cfg: ModelConfig, q, segments, q_info: KeyInfo,
                    impl: Optional[str] = None) -> jnp.ndarray:
    """q (B, Sq, Hq, D) over ordered KV ``segments`` read in place.

    Shape/layout contract: every segment's k/v are (B, S, Hkv, hd) — or
    the stacked (L, B, S, Hkv, hd) state when ``KVSegment.layer`` is set
    — consumed where they live; nothing is concatenated.  ``q_info``
    carries the query rows' (Sq,) idx/seg metadata; each segment brings
    its own k-side metadata (or none, for always-visible memory keys)
    and a valid-prefix ``length`` that bounds the work to occupancy.
    Returns (B, Sq, Hq, D) in ``q.dtype``.

    impl: None -> ``cfg.attn_impl``.  'pallas' -> fused segmented kernel
    (repro.kernels.decode_attention); 'concat' -> materialize the full
    [seg|...|seg] concatenation and run :func:`attend` (the pre-segmented
    baseline, kept for benchmarks/oracles); 'dense'/'chunked' -> the
    pure-jnp blocked online-softmax above.

    Under `jax.vmap` (the serve engine's session lanes) the non-concat
    paths reroute through a `custom_vmap` rule to a lane-batched
    implementation so the per-block tile skip survives batching —
    see the lane-batched section above.  `cfg.attn_lane_batched=False`
    restores the legacy select-lowered vmap; it is also required to
    differentiate through these paths (`jax.custom_batching.custom_vmap`
    defines no JVP rule, so `jax.grad` through the wrapped dispatch
    fails — the training step differentiates :func:`attend`, never this).
    """
    scale = 1.0 / (cfg.hd ** 0.5)
    segments = [s for s in segments if s.n_tokens]
    impl = impl or cfg.attn_impl
    if impl == "concat":
        ks, vs, infos = [], [], []
        for s in segments:
            k, v, ksc, vsc = _seg_layer_kv(s)
            if ksc is not None:
                k = _dequant(k, ksc, q.dtype)
                v = _dequant(v, vsc, q.dtype)
            ks.append(k)
            vs.append(v)
            infos.append(segment_key_info(s))
        info = functools.reduce(concat_info, infos)
        # impl=None -> cfg.attn_impl, exactly what the pre-segmented
        # runtime did after materializing the concatenation (attend()
        # treats an unknown impl like 'concat' itself as dense)
        return attend(cfg, q, jnp.concatenate(ks, axis=1),
                      jnp.concatenate(vs, axis=1), q_info, info, impl=None)

    if impl == "pallas":
        def base(q, segments, q_info):
            from repro.kernels import ops as kops
            return kops.segmented_attention(
                q, [_raw_segment(s) for s in segments], q_info.idx,
                q_info.seg, scale)
    else:
        def base(q, segments, q_info):
            return _attend_segments_online(cfg, q, segments, q_info, scale)

    if not cfg.attn_lane_batched:
        return base(q, segments, q_info)
    fn = custom_batching.custom_vmap(base)

    @fn.def_vmap
    def _lane_rule(axis_size, in_batched, qb, segsb, qib):
        try:
            ql, dicts, qidx, qseg = _lane_normalize(
                axis_size, in_batched, qb, segsb, qib)
        except _LaneFallback:
            in_axes = jax.tree.map(lambda b: 0 if b else None, in_batched)
            return jax.vmap(base, in_axes=in_axes)(qb, segsb, qib), True
        if impl == "pallas":
            from repro.kernels import ops as kops
            out = kops.segmented_attention(ql, dicts, qidx, qseg, scale)
        else:
            out = _attend_segments_lanes_online(cfg, ql, dicts, qidx, qseg,
                                                scale)
        return out[:, None], True

    return fn(q, segments, q_info)


def _raw_segment(seg: KVSegment) -> Dict:
    """KVSegment -> plain-array dict (the kernels/ref layer is model-free)."""
    return {"k": seg.k, "v": seg.v,
            "k_scale": seg.k_scale, "v_scale": seg.v_scale,
            "length": seg.length, "layer": seg.layer,
            "idx": seg.info.idx if seg.info is not None else None,
            "seg": seg.info.seg if seg.info is not None else None,
            "comp": seg.info.comp if seg.info is not None else None,
            "valid": seg.info.valid if seg.info is not None else None}


# ---------------------------------------------------------------------------
# attention block parameters & projections (with conditional LoRA)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, with_lora: bool = True,
                   d_model: Optional[int] = None) -> Dict:
    d = d_model or cfg.d_model
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 9)
    p = {"wq": L.dense_init(ks[0], d, Hq * hd, cfg.pdtype),
         "wk": L.dense_init(ks[1], d, Hkv * hd, cfg.pdtype),
         "wv": L.dense_init(ks[2], d, Hkv * hd, cfg.pdtype),
         "wo": L.dense_init(ks[3], Hq * hd, d, cfg.pdtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((Hkv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((Hkv * hd,), cfg.pdtype)
    if with_lora and cfg.ccm.enabled:
        r = cfg.ccm.lora_rank
        p["lora"] = {
            "q": lora_lib.init_lora(ks[4], d, Hq * hd, r),
            "k": lora_lib.init_lora(ks[5], d, Hkv * hd, r),
            "v": lora_lib.init_lora(ks[6], d, Hkv * hd, r),
            "o": lora_lib.init_lora(ks[7], Hq * hd, d, r),
        }
    return p


def qkv_project(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                comp_gate: Optional[jnp.ndarray],
                positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd) with RoPE at `positions`.

    comp_gate: (B,S) {0,1} — conditional-LoRA gate (1 at <COMP> tokens); None
    disables the delta entirely (pure pretrained weights).
    """
    B, S, _ = x.shape
    lora = p.get("lora")
    sc = lora_lib.lora_scale(cfg.ccm.lora_rank, cfg.ccm.lora_alpha)

    def proj(name, bias_name):
        lw = lora.get(name) if (lora is not None and comp_gate is not None) else None
        return lora_lib.cond_linear(x, p["w" + name], lw, comp_gate, sc,
                                    bias=p.get(bias_name))

    q = proj("q", "bq").reshape(B, S, cfg.n_heads, cfg.hd)
    k = proj("k", "bk").reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = proj("v", "bv").reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if positions is not None:
        cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    return q, k, v


def out_project(cfg: ModelConfig, p: Dict, o: jnp.ndarray,
                comp_gate: Optional[jnp.ndarray]) -> jnp.ndarray:
    B, S = o.shape[:2]
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    lora = p.get("lora")
    lw = lora.get("o") if (lora is not None and comp_gate is not None) else None
    sc = lora_lib.lora_scale(cfg.ccm.lora_rank, cfg.ccm.lora_alpha)
    return lora_lib.cond_linear(o, p["wo"], lw, comp_gate, sc)
