"""Attention: GQA/MQA/MHA with CCM-aware masking, three implementations.

  dense   — einsum logits + additive mask; short sequences / merge-mode
            training with virtual memory slots.
  chunked — double-blocked online-softmax (flash-style) in pure jnp; the
            CPU/compile-analysis analogue of the Pallas kernel. Mask is
            evaluated per (q-block, k-block) from per-token metadata
            (index, segment id, is-<COMP>), never materialized at S×S.
  pallas  — repro.kernels.ccm_attention (TPU target; interpret-validated).

Conventions: q (B, Sq, Hq, D); k/v (B, Sk, Hkv, D); GQA grouping is done
here (no materialized head repetition). Softmax statistics in fp32.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lora as lora_lib
from repro.core.masks import NEG_INF
from repro.models.config import ModelConfig
from repro.models import layers as L


class KeyInfo(NamedTuple):
    """Per-token metadata driving the CCM mask, all shape (Sk,) or (Sq,).

    idx  : global position index used for causality (mem keys get -1).
    seg  : CCM segment id (mem keys 0; plain causal = all zeros + comp 1s).
    comp : True where the key is a <COMP> token / memory slot.
    valid: False at padding (keys only).
    """
    idx: jnp.ndarray
    seg: jnp.ndarray
    comp: jnp.ndarray
    valid: Optional[jnp.ndarray] = None


def plain_causal_info(length: int, offset: int = 0) -> KeyInfo:
    idx = jnp.arange(length, dtype=jnp.int32) + offset
    z = jnp.zeros((length,), jnp.int32)
    return KeyInfo(idx=idx, seg=z, comp=jnp.ones((length,), bool))


def mem_key_info(length: int, valid: Optional[jnp.ndarray] = None) -> KeyInfo:
    """Memory keys: always visible (idx=-1, comp=True)."""
    return KeyInfo(idx=jnp.full((length,), -1, jnp.int32),
                   seg=jnp.zeros((length,), jnp.int32),
                   comp=jnp.ones((length,), bool),
                   valid=valid)


def concat_info(a: KeyInfo, b: KeyInfo) -> KeyInfo:
    def cat(x, y, fill_x, fill_y):
        if x is None and y is None:
            return None
        if x is None:
            x = fill_x
        if y is None:
            y = fill_y
        return jnp.concatenate([x, y])
    va = jnp.ones(a.idx.shape, bool)
    vb = jnp.ones(b.idx.shape, bool)
    return KeyInfo(idx=jnp.concatenate([a.idx, b.idx]),
                   seg=jnp.concatenate([a.seg, b.seg]),
                   comp=jnp.concatenate([a.comp, b.comp]),
                   valid=cat(a.valid, b.valid, va, vb))


def mask_from_info(q: KeyInfo, k: KeyInfo) -> jnp.ndarray:
    """(Q, K) CCM mask: causal AND (same-segment OR k-is-comp) AND k-valid."""
    causal = k.idx[None, :] <= q.idx[:, None]
    allow = (k.seg[None, :] == q.seg[:, None]) | k.comp[None, :]
    m = causal & allow
    if k.valid is not None:
        m = m & k.valid[None, :]
    return m


# ---------------------------------------------------------------------------
# core attends
# ---------------------------------------------------------------------------

def _group(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    B, S, Hq, D = q.shape
    return q.reshape(B, S, n_kv, Hq // n_kv, D)


def attend_dense(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 mask: Optional[jnp.ndarray], scale: float) -> jnp.ndarray:
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D), mask (Sq,Sk) or (B,Sq,Sk) or None."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    qg = _group(q, Hkv)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        else:
            mask = mask[:, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, Sq, Hq, D)


def _pad_to(x: jnp.ndarray, mult: int, axis: int, fill=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill), n


def attend_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   q_info: KeyInfo, k_info: KeyInfo, scale: float,
                   q_chunk: int = 512, k_chunk: int = 1024) -> jnp.ndarray:
    """Double-blocked online-softmax attention with CCM mask.

    Memory high-watermark per step: O(B * Hq * q_chunk * k_chunk) — the CPU
    analogue of the Pallas flash kernel's VMEM tiling.
    """
    B, Sq0, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    valid = k_info.valid if k_info.valid is not None \
        else jnp.ones((k.shape[1],), bool)

    q, _ = _pad_to(q, q_chunk, axis=1)
    qi_idx, _ = _pad_to(q_info.idx, q_chunk, axis=0, fill=-(10 ** 9))
    qi_seg, _ = _pad_to(q_info.seg, q_chunk, axis=0, fill=-1)
    k, _ = _pad_to(k, k_chunk, axis=1)
    v, _ = _pad_to(v, k_chunk, axis=1)
    ki_idx, _ = _pad_to(k_info.idx, k_chunk, axis=0, fill=10 ** 9)
    ki_seg, _ = _pad_to(k_info.seg, k_chunk, axis=0, fill=-2)
    ki_comp, _ = _pad_to(k_info.comp, k_chunk, axis=0, fill=False)
    ki_valid, _ = _pad_to(valid, k_chunk, axis=0, fill=False)

    Sq, Sk = q.shape[1], k.shape[1]
    nq, nk = Sq // q_chunk, Sk // k_chunk
    qg = _group(q, Hkv).reshape(B, nq, q_chunk, Hkv, G, D)
    kb = k.reshape(B, nk, k_chunk, Hkv, D)
    vb = v.reshape(B, nk, k_chunk, Hkv, D)

    def q_block(carrys, xs):
        qblk, qidx, qseg = xs  # (B,qc,Hkv,G,D), (qc,), (qc,)

        def k_step(state, kxs):
            m_i, l_i, acc = state
            kblk, vblk, kidx, kseg, kcomp, kval = kxs
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk)
            logits = logits.astype(jnp.float32) * scale
            msk = (kidx[None, :] <= qidx[:, None]) \
                & ((kseg[None, :] == qseg[:, None]) | kcomp[None, :]) \
                & kval[None, :]
            logits = jnp.where(msk[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_i, logits.max(axis=-1))
            alpha = jnp.exp(m_i - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_i * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] \
                + jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(qblk.dtype), vblk)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
             ki_idx.reshape(nk, k_chunk), ki_seg.reshape(nk, k_chunk),
             ki_comp.reshape(nk, k_chunk), ki_valid.reshape(nk, k_chunk)))
        out = acc / jnp.maximum(l_f[..., None], 1e-37)
        return carrys, out.astype(qblk.dtype)

    _, outs = jax.lax.scan(
        q_block, (),
        (qg.swapaxes(0, 1), qi_idx.reshape(nq, q_chunk),
         qi_seg.reshape(nq, q_chunk)))
    # outs: (nq, B, Hkv, G, qc, D) -> (B, Sq, Hq, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hq, D)
    return out[:, :Sq0]


def attend(cfg: ModelConfig, q, k, v, q_info: KeyInfo, k_info: KeyInfo,
           impl: Optional[str] = None) -> jnp.ndarray:
    scale = 1.0 / (cfg.hd ** 0.5)
    impl = impl or cfg.attn_impl
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.ccm_attention(q, k, v, q_info, k_info, scale)
    if impl == "chunked":
        return attend_chunked(q, k, v, q_info, k_info, scale,
                              q_chunk=min(cfg.attn_chunk, 512),
                              k_chunk=cfg.attn_chunk)
    mask = mask_from_info(q_info, k_info)
    return attend_dense(q, k, v, mask, scale)


# ---------------------------------------------------------------------------
# attention block parameters & projections (with conditional LoRA)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, with_lora: bool = True,
                   d_model: Optional[int] = None) -> Dict:
    d = d_model or cfg.d_model
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 9)
    p = {"wq": L.dense_init(ks[0], d, Hq * hd, cfg.pdtype),
         "wk": L.dense_init(ks[1], d, Hkv * hd, cfg.pdtype),
         "wv": L.dense_init(ks[2], d, Hkv * hd, cfg.pdtype),
         "wo": L.dense_init(ks[3], Hq * hd, d, cfg.pdtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((Hkv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((Hkv * hd,), cfg.pdtype)
    if with_lora and cfg.ccm.enabled:
        r = cfg.ccm.lora_rank
        p["lora"] = {
            "q": lora_lib.init_lora(ks[4], d, Hq * hd, r),
            "k": lora_lib.init_lora(ks[5], d, Hkv * hd, r),
            "v": lora_lib.init_lora(ks[6], d, Hkv * hd, r),
            "o": lora_lib.init_lora(ks[7], Hq * hd, d, r),
        }
    return p


def qkv_project(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                comp_gate: Optional[jnp.ndarray],
                positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd) with RoPE at `positions`.

    comp_gate: (B,S) {0,1} — conditional-LoRA gate (1 at <COMP> tokens); None
    disables the delta entirely (pure pretrained weights).
    """
    B, S, _ = x.shape
    lora = p.get("lora")
    sc = lora_lib.lora_scale(cfg.ccm.lora_rank, cfg.ccm.lora_alpha)

    def proj(name, bias_name):
        lw = lora.get(name) if (lora is not None and comp_gate is not None) else None
        return lora_lib.cond_linear(x, p["w" + name], lw, comp_gate, sc,
                                    bias=p.get(bias_name))

    q = proj("q", "bq").reshape(B, S, cfg.n_heads, cfg.hd)
    k = proj("k", "bk").reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = proj("v", "bv").reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if positions is not None:
        cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    return q, k, v


def out_project(cfg: ModelConfig, p: Dict, o: jnp.ndarray,
                comp_gate: Optional[jnp.ndarray]) -> jnp.ndarray:
    B, S = o.shape[:2]
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    lora = p.get("lora")
    lw = lora.get("o") if (lora is not None and comp_gate is not None) else None
    sc = lora_lib.lora_scale(cfg.ccm.lora_rank, cfg.ccm.lora_alpha)
    return lora_lib.cond_linear(o, p["wo"], lw, comp_gate, sc)
