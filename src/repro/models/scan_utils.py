"""scan-or-unroll over stacked layer parameters.

Production path: ``lax.scan`` (O(1) HLO in depth). Calibration path
(``cfg.unroll_layers``): Python loop, used by the dry-run to recover
per-layer HLO FLOPs/bytes that XLA's cost_analysis cannot see inside a
while-loop body (it counts loop bodies once, and not at all under remat).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def scan_layers(unroll: bool, body: Callable, carry: Any, xs: Any,
                remat: bool = False) -> Tuple[Any, Any]:
    """Semantics of ``jax.lax.scan(body, carry, xs)`` with optional unroll."""
    if remat and not unroll:
        body = jax.checkpoint(body)
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked
