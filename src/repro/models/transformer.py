"""Model assembly: init + train-forward + prefill/decode/compress for every
assigned architecture family.

Layer parameters are stacked along a leading layer axis and traversed with
``jax.lax.scan`` so compile time / HLO size is O(1) in depth (48-layer
llama4 compiles as fast as 4-layer smoke configs). Hybrid (Zamba2) uses
grouped scans with a *shared* attention block between groups.

CCM integration (paper): the training forward is the parallelized unroll
(masks from ``repro.core.masks``); ``compress_chunk`` / ``decode_step`` are
the online g_comp / inference of Eq. (1)-(3).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import masks as M
from repro.core.memory import MemState, init_memory, update_memory
from repro.distributed.context import DistContext
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ===========================================================================
# init
# ===========================================================================

def _init_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        return {"ln1": L.init_norm(cfg, cfg.d_model),
                "mamba": SSM.init_mamba(ks[0], cfg, cfg.d_model)}
    if cfg.family == "hybrid":
        return {"ln1": L.init_norm(cfg, cfg.d_model),
                "mamba": SSM.init_mamba(ks[0], cfg, cfg.d_model)}
    p = {"ln1": L.init_norm(cfg, cfg.d_model),
         "attn": A.init_attention(ks[0], cfg),
         "ln2": L.init_norm(cfg, cfg.d_model)}
    if cfg.family == "moe":
        p["moe"] = MOE.init_moe(ks[1], cfg, cfg.d_model, cfg.d_ff)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff)
    return p


def _init_enc_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln1": L.init_norm(cfg, cfg.d_model),
            "attn": A.init_attention(ks[0], cfg, with_lora=False),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff)}


def _init_cross_block(key, cfg: ModelConfig) -> Params:
    """Decoder block with cross attention (whisper-style)."""
    p = _init_block(key, cfg)
    ks = jax.random.split(jax.random.fold_in(key, 7), 2)
    p["ln_x"] = L.init_norm(cfg, cfg.d_model)
    p["xattn"] = A.init_attention(ks[0], cfg, with_lora=False)
    return p


def init_lm(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 10)
    p: Params = {"embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                       cfg.pdtype),
                 "final_norm": L.init_norm(cfg, cfg.d_model)}
    if cfg.ccm.enabled:
        p["comp_embed"] = (jax.random.normal(
            ks[1], (cfg.ccm.comp_len, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.pdtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab_size,
                                    cfg.pdtype)
    if cfg.pos_embed == "learned":
        p["pos_embed"] = L.embed_init(ks[3], max(cfg.max_pos, 2048),
                                      cfg.d_model, cfg.pdtype)
    # stacked decoder layers
    layer_keys = jax.random.split(ks[4], cfg.n_layers)
    init_fn = _init_cross_block if cfg.family == "encdec" else _init_block
    p["layers"] = jax.vmap(lambda k: init_fn(k, cfg))(layer_keys)
    if cfg.family == "hybrid":
        p["shared_attn"] = {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "attn": A.init_attention(ks[5], cfg),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(ks[6], cfg, cfg.d_model, cfg.d_ff)}
    if cfg.family == "encdec":
        enc_keys = jax.random.split(ks[7], cfg.n_enc_layers)
        p["encoder"] = {
            "layers": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
            "final_norm": L.init_norm(cfg, cfg.d_model),
            "pos_embed": L.embed_init(ks[8], max(cfg.max_pos, 2048),
                                      cfg.d_model, cfg.pdtype)}
    if cfg.family == "vlm":
        p["frontend"] = {"proj": L.dense_init(ks[9], 1024, cfg.d_model,
                                              cfg.pdtype)}
    return p


# ===========================================================================
# embeddings
# ===========================================================================

def embed_tokens(cfg: ModelConfig, p: Params, tokens: jnp.ndarray,
                 comp_mask: Optional[jnp.ndarray] = None,
                 comp_offset: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = jnp.take(p["embed"], tokens, axis=0).astype(cfg.cdtype)
    if comp_mask is not None and "comp_embed" in p:
        ce = p["comp_embed"].astype(cfg.cdtype)
        off = comp_offset if comp_offset is not None else \
            jnp.zeros(tokens.shape[-1], jnp.int32)
        comp_vec = jnp.take(ce, off, axis=0)          # (S, d)
        cm = comp_mask[..., None].astype(cfg.cdtype)
        x = x * (1 - cm) + comp_vec * cm
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    return x


def _add_learned_pos(cfg, table, x, positions):
    pe = jnp.take(table, jnp.clip(positions, 0, table.shape[0] - 1), axis=0)
    return x + pe.astype(x.dtype)


def _rope_positions(cfg, positions):
    return positions if cfg.pos_embed == "rope" else None


# ===========================================================================
# block applications (training / full-sequence)
# ===========================================================================

def _attn_mlp_block(cfg: ModelConfig, lp: Params, x, *, q_info, k_info,
                    comp_gate, positions, merge_ctx, dist,
                    cross: Optional[Tuple] = None):
    h = L.apply_norm(cfg, lp["ln1"], x)
    q, k, v = A.qkv_project(cfg, lp["attn"], h, comp_gate,
                            _rope_positions(cfg, positions))
    if merge_ctx is not None:
        slots_fn = merge_ctx.get("slots_fn")
        if slots_fn is not None:
            mem_k, mem_v = slots_fn(k, v)
            k = jnp.concatenate([mem_k, k], axis=1)
            v = jnp.concatenate([mem_v, v], axis=1)
        o = A.attend_dense(q, k, v, merge_ctx["mask"], 1.0 / cfg.hd ** 0.5)
    else:
        o = A.attend(cfg, q, k, v, q_info, k_info)
    x = x + A.out_project(cfg, lp["attn"], o, comp_gate)
    if cross is not None:
        # cross is either the encoder output (B,Se,d) -> project per layer,
        # or a precomputed per-layer (xk, xv) tuple (decode path).
        h = L.apply_norm(cfg, lp["ln_x"], x)
        qx, _, _ = A.qkv_project(cfg, lp["xattn"], h, None, None)
        if isinstance(cross, tuple):
            xk, xv = cross
        else:
            _, xk, xv = A.qkv_project(cfg, lp["xattn"], cross, None, None)
        ox = A.attend_dense(qx, xk, xv, None, 1.0 / cfg.hd ** 0.5)
        x = x + A.out_project(cfg, lp["xattn"], ox, None)
    h = L.apply_norm(cfg, lp["ln2"], x)
    if "moe" in lp:
        return x + MOE.apply_moe(cfg, lp["moe"], h, dist)
    return x + L.apply_mlp(cfg, lp["mlp"], h)


def _mamba_block(cfg, lp, x, state=None, decode=False):
    h = L.apply_norm(cfg, lp["ln1"], x)
    out, new_state = SSM.apply_mamba(cfg, lp["mamba"], h, state, decode)
    return x + out, new_state


def _hybrid_sites(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, group_size, remainder) for zamba2-style layouts."""
    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    return n_groups, g, cfg.n_layers - n_groups * g


def _scan_blocks(cfg, stacked, x, body):
    """scan ``body(x, layer_params) -> x`` over stacked layer params."""
    from repro.models.scan_utils import scan_layers

    def step(carry, lp):
        return body(carry, lp), None

    x, _ = scan_layers(cfg.unroll_layers, step, x, stacked, remat=cfg.remat)
    return x


def forward_hidden(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                   q_info=None, k_info=None, comp_gate=None, positions=None,
                   merge_ctx=None, dist=None, cross=None) -> jnp.ndarray:
    """Run the full decoder stack on embedded inputs x (B,S,d)."""
    if cfg.family in ("ssm", "hybrid"):
        def mbody(h, lp):
            out, _ = _mamba_block(cfg, lp, h)
            return out

        if cfg.family == "ssm":
            return _scan_blocks(cfg, params["layers"], x, mbody)
        # hybrid: groups of mamba layers + shared attention block
        n_groups, g, rem = _hybrid_sites(cfg)
        stacked = params["layers"]
        head = jax.tree.map(lambda a: a[:n_groups * g].reshape(
            (n_groups, g) + a.shape[1:]), stacked)
        tail = jax.tree.map(lambda a: a[n_groups * g:], stacked)
        sa = params["shared_attn"]
        for gi in range(n_groups):
            grp = jax.tree.map(lambda a: a[gi], head)
            x = _scan_blocks(cfg, grp, x, mbody)
            x = _attn_mlp_block(cfg, sa, x, q_info=q_info, k_info=k_info,
                                comp_gate=comp_gate, positions=positions,
                                merge_ctx=merge_ctx, dist=dist)
        if rem:
            x = _scan_blocks(cfg, tail, x, mbody)
        return x

    body = functools.partial(
        lambda h, lp: _attn_mlp_block(cfg, lp, h, q_info=q_info,
                                      k_info=k_info, comp_gate=comp_gate,
                                      positions=positions,
                                      merge_ctx=merge_ctx, dist=dist,
                                      cross=cross))
    return _scan_blocks(cfg, params["layers"], x, body)


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend: conv feature extraction happens upstream)."""
    enc = params["encoder"]
    S = frames.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    x = _add_learned_pos(cfg, enc["pos_embed"], frames.astype(cfg.cdtype), pos)
    info = A.KeyInfo(idx=jnp.zeros((S,), jnp.int32),
                     seg=jnp.zeros((S,), jnp.int32),
                     comp=jnp.ones((S,), bool))   # bidirectional

    def body(h, lp):
        return _attn_mlp_block(cfg, lp, h, q_info=info, k_info=info,
                               comp_gate=None, positions=None,
                               merge_ctx=None, dist=None)

    x = _scan_blocks(cfg, enc["layers"], x, body)
    return L.apply_norm(cfg, enc["final_norm"], x)


def lm_logits(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = L.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head.astype(x.dtype)


# ===========================================================================
# CCM parallel training forward (paper Fig. 3 / Alg. 1)
# ===========================================================================

def train_forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                  layout: M.SegmentLayout, dist: Optional[DistContext] = None,
                  frames: Optional[jnp.ndarray] = None,
                  patches: Optional[jnp.ndarray] = None,
                  logits_slice: Optional[Tuple[int, int]] = None,
                  unconditional_lora: bool = False) -> jnp.ndarray:
    """One parallelized CCM forward. tokens (B,S) following ``layout``.

    Returns logits over ``logits_slice`` (start, length) — by default the
    tail (input/output) region only, so the vocab projection is computed at
    O(tail) not O(S) positions.
    """
    S = layout.seq_len
    seg, comp, pos = layout.seg_ids, layout.comp_mask, layout.positions
    comp_off = M.comp_offset_array(comp)
    use_ccm = cfg.ccm.enabled and not cfg.is_attention_free

    x = embed_tokens(cfg, params, tokens, comp if use_ccm else None, comp_off)
    if cfg.pos_embed == "learned":
        x = _add_learned_pos(cfg, params["pos_embed"], x, pos)
    if patches is not None:
        # patches are context tokens with precomputed embeddings; <COMP>
        # positions inside the patch span keep their comp embedding.
        pe = patches.astype(cfg.cdtype) @ params["frontend"]["proj"].astype(cfg.cdtype)
        xp = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
        x = jnp.where(comp[None, :, None], x, xp) if cfg.ccm.enabled else xp

    comp_gate = None
    if use_ccm:
        comp_gate = jnp.broadcast_to(comp.astype(cfg.cdtype)[None],
                                     tokens.shape)
        if unconditional_lora:
            comp_gate = jnp.ones_like(comp_gate)

    merge_ctx = None
    q_info = k_info = None
    if use_ccm and cfg.ccm.method == "gisting":
        from repro.core.baselines import gisting_online_mask
        merge_ctx = {"mask": gisting_online_mask(seg, comp, layout.t_steps),
                     "slots_fn": None}
    elif use_ccm and cfg.ccm.method == "compressive":
        from repro.core.baselines import (compressive_slot_mask,
                                          compressive_virtual_kv)
        raw_mask = M.intra_segment_causal(seg, comp)
        slot_mask = compressive_slot_mask(seg, layout.t_steps,
                                          layout.comp_len)
        merge_ctx = {
            "mask": jnp.concatenate([slot_mask, raw_mask], axis=1),
            "slots_fn": functools.partial(
                compressive_virtual_kv, seg_ids=seg, comp_mask=comp,
                t_steps=layout.t_steps, comp_len=layout.comp_len)}
    elif use_ccm and cfg.ccm.mode == "merge":
        raw_mask = M.intra_segment_causal(seg, comp)
        slot_mask = M.expand_slot_mask(
            M.merge_slot_mask(seg, layout.t_steps), layout.comp_len)
        merge_ctx = {
            "mask": jnp.concatenate([slot_mask, raw_mask], axis=1),
            "slots_fn": functools.partial(
                M.merge_virtual_kv, comp_mask=comp,
                t_steps=layout.t_steps, comp_len=layout.comp_len,
                alpha=cfg.ccm.merge_alpha)}
    elif use_ccm:
        q_info = A.KeyInfo(idx=jnp.arange(S, dtype=jnp.int32), seg=seg,
                           comp=comp)
        k_info = q_info
    else:
        q_info = k_info = A.plain_causal_info(S)

    cross = None
    if cfg.family == "encdec":
        cross = encode(params, cfg, frames)   # per-layer K/V inside blocks

    x = forward_hidden(params, cfg, x, q_info=q_info, k_info=k_info,
                       comp_gate=comp_gate, positions=pos,
                       merge_ctx=merge_ctx, dist=dist, cross=cross)
    if logits_slice is None:
        logits_slice = (S - layout.tail_len, layout.tail_len)
    start, length = logits_slice
    return lm_logits(params, cfg, x[:, start:start + length])
