"""Shared neural building blocks: norms, RoPE, MLPs, embeddings, init."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms (fp32 statistics, cast back)
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(cfg: ModelConfig, d: int) -> Dict[str, jnp.ndarray]:
    if cfg.norm == "ln":
        return {"scale": jnp.ones((d,), cfg.pdtype),
                "bias": jnp.zeros((d,), cfg.pdtype)}
    return {"scale": jnp.zeros((d,), cfg.pdtype)}  # rms: stored as (1+scale)


def apply_norm(cfg: ModelConfig, p: Dict[str, jnp.ndarray],
               x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "ln":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE with explicit per-token positions (CCM reassigns positions)
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float,
                 dtype=jnp.float32):
    """positions: (..., S) int -> cos/sin (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2). Rotate-half pairing
    (x1, x2) = split(x, 2, -1) — llama convention."""
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos, sin = cos.astype(x.dtype), sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# MLP: SwiGLU / GeGLU / GELU
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d: int, f: int) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {"wi": dense_init(ks[0], d, f, cfg.pdtype),
                "wg": dense_init(ks[1], d, f, cfg.pdtype),
                "wo": dense_init(ks[2], f, d, cfg.pdtype)}
    return {"wi": dense_init(ks[0], d, f, cfg.pdtype),
            "wo": dense_init(ks[2], f, d, cfg.pdtype)}


def apply_mlp(cfg: ModelConfig, p: Dict[str, jnp.ndarray],
              x: jnp.ndarray) -> jnp.ndarray:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(x @ p["wg"].astype(x.dtype), approximate=True) \
            * (x @ p["wi"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(x.dtype), approximate=True)
    return h @ p["wo"].astype(x.dtype)
