"""Injectable time sources for the observability layer.

Everything in ``src/repro`` that needs a wall/monotonic clock goes
through this module — never ``time.*`` directly (enforced by
``scripts/check_no_stray_timers.py``).  Centralizing the clock is what
makes timing *injectable*: the serve engine takes a `Clock` and the
deterministic simulation harness (`tests/simulation.py`) swaps in a
`ManualClock`, so request-lifecycle traces carry exact, reproducible
timestamps instead of host-noise wall times.

All timestamps are monotonic seconds with an arbitrary epoch — only
differences are meaningful.  No timing here (or anywhere in obs) runs
inside jit: device work is timed around dispatch boundaries with
``block_until_ready``, never traced into a compiled program.
"""
from __future__ import annotations

import time as _time


class MonotonicClock:
    """Real monotonic clock (``time.perf_counter``)."""

    def now(self) -> float:
        return _time.perf_counter()


class ManualClock:
    """Deterministic clock the caller advances explicitly.

    ``now()`` returns the last set value — repeated reads between
    ``advance`` calls are identical, so traces driven by a `ManualClock`
    are exactly reproducible across runs and platforms."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float = 1.0) -> float:
        if dt < 0:
            raise ValueError("ManualClock cannot run backwards")
        self._t += dt
        return self._t


_DEFAULT = MonotonicClock()


def perf_counter() -> float:
    """Module-level monotonic timestamp for call sites without an
    injected clock (launch.train step timing, launch.dryrun
    lower/compile timing).  Same contract as ``time.perf_counter``."""
    return _DEFAULT.now()
