"""Host-side observability for the serve stack (and any other module
that needs a clock or a counter).

Three pieces, one bundle:

  metrics.py — typed `MetricsRegistry` (counters / gauges / fixed-bucket
               histograms with p50/p95/p99 extraction, no per-sample
               storage), JSON + Prometheus export
  trace.py   — per-request lifecycle spans (submit -> verdict -> queue
               wait -> execute -> terminal), bounded `FlightRecorder`
               ring the engine dumps on error; `NullRecorder` keeps the
               disabled path allocation-free
  clock.py   — injectable time source: `MonotonicClock` in production,
               `ManualClock` under the deterministic simulation harness
               (`scripts/check_no_stray_timers.py` lints that raw
               ``time.*`` calls exist nowhere else in ``src/``)

`Observability` wires the three together; `ServeEngine(obs=...)`
threads the bundle through scheduler, admission, session manager and
arena instrumentation.  Everything here is host-side Python — no
metric, span, or clock read ever runs inside jit, so compiled programs
are untouched whether tracing is on or off.
"""
from repro.obs.clock import ManualClock, MonotonicClock, perf_counter
from repro.obs.metrics import (DEFAULT_TIME_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry,
                               render_prometheus)
from repro.obs.trace import (FlightRecorder, NullRecorder, RequestTrace,
                             SpanEvent, TraceRecorder)


class Observability:
    """Bundle of (registry, clock, recorder) one engine threads through
    its serve stack.

    The registry and clock are ALWAYS live (counters are cheap dict
    bumps; the clock only ticks outside jit) — that is what lets the
    engine's legacy ``stats`` dicts become thin views over registry
    counters with zero behavior change.  Only the *recorder* is
    optional: the default `NullRecorder` makes every trace/flight hook
    a no-op, and `Observability.tracing()` swaps in a `TraceRecorder`
    (bound to the same clock + registry) for per-request spans, latency
    histograms and the crash flight buffer."""

    def __init__(self, registry: MetricsRegistry = None, clock=None,
                 recorder=None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.clock = clock if clock is not None else MonotonicClock()
        self.recorder = recorder if recorder is not None \
            else NullRecorder()
        self.recorder.bind(self.clock, self.registry)

    @classmethod
    def tracing(cls, clock=None, flight_capacity: int = 256,
                keep_completed: int = 4096) -> "Observability":
        """Fully-enabled bundle: traces + flight recorder + histograms."""
        return cls(clock=clock,
                   recorder=TraceRecorder(flight_capacity=flight_capacity,
                                          keep_completed=keep_completed))


__all__ = ["Counter", "DEFAULT_TIME_BUCKETS", "FlightRecorder", "Gauge",
           "Histogram", "ManualClock", "MetricsRegistry",
           "MonotonicClock", "NullRecorder", "Observability",
           "RequestTrace", "SpanEvent", "TraceRecorder", "perf_counter",
           "render_prometheus"]
