"""Request-lifecycle tracing + bounded flight recorder.

A *trace* is the ordered list of span events one request passes through:

  submit -> verdict (admitted | queued | shed) -> [pumped] ->
  popped (queue wait ends) -> executed (batch dispatch) ->
  terminal (finished | shed | cancelled)

exactly one terminal event per submitted request — the trace-conservation
property the simulation suite asserts.  Timestamps come from the
recorder's injected `Clock` (`obs.clock`), so the deterministic
simulation harness produces byte-identical traces run to run.

Two recorder implementations share one call surface:

  NullRecorder  — the default: every hook is a no-op ``pass`` (no
                  allocation, no clock reads), so an engine without
                  tracing behaves bit-exactly like one that never heard
                  of this module.
  TraceRecorder — keeps per-request `RequestTrace`s (bounded completed
                  ring), feeds queue-wait / end-to-end latency
                  histograms into the bound `MetricsRegistry`, and logs
                  every event into a bounded ring-buffer
                  `FlightRecorder` the engine dumps on error.

The recorder observes; it never steers.  Engine/session code calls the
hooks with live `Request` objects (duck-typed: ``.sid``/``.kind``/
``.tenant`` — obs does not import the serve package).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.obs.clock import MonotonicClock
from repro.obs.metrics import MetricsRegistry

TERMINALS = ("finished", "shed", "cancelled")


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    name: str
    ts: float
    detail: str = ""


@dataclasses.dataclass
class RequestTrace:
    sid: str
    kind: str
    tenant: str
    events: List[SpanEvent] = dataclasses.field(default_factory=list)

    @property
    def terminal(self) -> Optional[str]:
        for ev in reversed(self.events):
            if ev.name in TERMINALS:
                return ev.name
        return None

    def ts_of(self, name: str) -> Optional[float]:
        """Timestamp of the FIRST event with this name (None if absent)."""
        for ev in self.events:
            if ev.name == name:
                return ev.ts
        return None

    def span(self, start: str, end: str) -> Optional[float]:
        """Seconds between the first ``start`` and first ``end`` event;
        None when either is absent (e.g. queue wait of a shed request)."""
        t0, t1 = self.ts_of(start), self.ts_of(end)
        return None if t0 is None or t1 is None else t1 - t0


class FlightRecorder:
    """Bounded ring buffer of recent (ts, event, detail) triples.  Old
    events fall off the back — memory stays O(capacity) forever; the
    engine dumps the buffer to stderr when an exception escapes a
    drain, so the last moments before a crash are always available."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("flight recorder needs capacity >= 1")
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)

    def __len__(self) -> int:
        return len(self._ring)

    def record(self, ts: float, event: str, detail: str = "") -> None:
        self._ring.append((ts, event, detail))

    def events(self) -> List[Tuple[float, str, str]]:
        return list(self._ring)

    def lines(self) -> List[str]:
        return [f"[t={ts:.6f}] {event}" + (f" {detail}" if detail else "")
                for ts, event, detail in self._ring]


class NullRecorder:
    """Do-nothing recorder: the engine's default.  Every hook is a bare
    ``pass`` — no clock reads, no allocation — so the disabled path is
    bit-exact with (and as fast as) a never-instrumented engine."""
    enabled = False

    def bind(self, clock, registry) -> None:
        pass

    # -- request lifecycle --------------------------------------------
    def submit(self, req) -> None:
        pass

    def admitted(self, req) -> None:
        pass

    def backlogged(self, req, reason: str = "") -> None:
        pass

    def pumped(self, req) -> None:
        pass

    def popped(self, req) -> None:
        pass

    def executed(self, req, detail: str = "") -> None:
        pass

    def finished(self, req) -> None:
        pass

    def shed(self, req, reason: str = "") -> None:
        pass

    def cancelled(self, req) -> None:
        pass

    # -- batch / session events (flight recorder only) ----------------
    def note(self, event: str, detail: str = "") -> None:
        pass

    # -- introspection -------------------------------------------------
    def flight_lines(self) -> List[str]:
        return []

    def trace_of(self, req) -> Optional[RequestTrace]:
        return None


class TraceRecorder(NullRecorder):
    """Real tracing: per-request span events, latency histograms,
    flight-recorder feed.

    ``keep_completed`` bounds the retained finished traces (ring — the
    histograms keep the aggregate view forever; traces are for
    debugging and tests).  Active traces are keyed by request object
    identity: callers hold their `Request`s for the request's lifetime
    (the scheduler queue, the engine ledger, and test drivers all do),
    so identity is stable from submit to terminal."""
    enabled = True

    def __init__(self, clock=None, registry: Optional[MetricsRegistry] = None,
                 flight_capacity: int = 256, keep_completed: int = 4096):
        self.clock = clock or MonotonicClock()
        self.flight = FlightRecorder(flight_capacity)
        self._active: Dict[int, Tuple[object, RequestTrace]] = {}
        self._completed: deque = deque(maxlen=keep_completed)
        self._completed_by_key: Dict[int, RequestTrace] = {}
        self._registry: Optional[MetricsRegistry] = None
        self._h_wait = self._h_e2e = None
        if registry is not None:
            self.bind(self.clock, registry)

    def bind(self, clock, registry: MetricsRegistry) -> None:
        """Attach the owning engine's clock + registry (idempotent)."""
        if clock is not None:
            self.clock = clock
        self._registry = registry
        self._h_wait = registry.histogram(
            "serve_queue_wait_seconds",
            "seconds between admission into the scheduler queue and the "
            "batch pop that served the request", labels=("kind",))
        self._h_e2e = registry.histogram(
            "serve_e2e_latency_seconds",
            "seconds between submit and delivery (finished requests "
            "only)", labels=("kind",))

    # -- internals -----------------------------------------------------
    def _event(self, req, name: str, detail: str = "") -> None:
        ts = self.clock.now()
        key = id(req)
        entry = self._active.get(key)
        if entry is None:
            trace = RequestTrace(sid=req.sid, kind=req.kind,
                                 tenant=req.tenant)
            self._active[key] = (req, trace)
        else:
            trace = entry[1]
        trace.events.append(SpanEvent(name, ts, detail))
        self.flight.record(
            ts, name, f"sid={req.sid} kind={req.kind}"
            + (f" {detail}" if detail else ""))
        if name in TERMINALS:
            self._active.pop(key, None)
            self._completed.append(trace)
            self._completed_by_key[key] = trace
            if len(self._completed_by_key) > 2 * self._completed.maxlen:
                live = set(id(t) for t in self._completed)
                self._completed_by_key = {
                    k: t for k, t in self._completed_by_key.items()
                    if id(t) in live}

    # -- request lifecycle --------------------------------------------
    def submit(self, req) -> None:
        self._event(req, "submit", f"len={req.token_len}")

    def admitted(self, req) -> None:
        self._event(req, "admitted")

    def backlogged(self, req, reason: str = "") -> None:
        self._event(req, "queued", reason)

    def pumped(self, req) -> None:
        self._event(req, "pumped")

    def popped(self, req) -> None:
        self._event(req, "popped")
        trace = self.trace_of(req)
        if trace is not None and self._h_wait is not None:
            # queue wait starts at the LAST entry into the queue — a
            # pumped request waited in the backlog first; its scheduler
            # wait is pop - pump, its total wait is pop - submit (both
            # recoverable from the trace; the histogram takes the
            # scheduler wait)
            t_pop = trace.events[-1].ts
            t_in = trace.ts_of("pumped")
            if t_in is None:
                t_in = trace.ts_of("admitted")
            if t_in is not None:
                self._h_wait.labels(kind=req.kind).observe(t_pop - t_in)

    def executed(self, req, detail: str = "") -> None:
        self._event(req, "executed", detail)

    def finished(self, req) -> None:
        self._event(req, "finished")
        trace = self.trace_of(req)
        if trace is not None and self._h_e2e is not None:
            dt = trace.span("submit", "finished")
            if dt is not None:
                self._h_e2e.labels(kind=req.kind).observe(dt)

    def shed(self, req, reason: str = "") -> None:
        self._event(req, "shed", reason)

    def cancelled(self, req) -> None:
        self._event(req, "cancelled")

    # -- batch / session events ---------------------------------------
    def note(self, event: str, detail: str = "") -> None:
        self.flight.record(self.clock.now(), event, detail)

    # -- introspection -------------------------------------------------
    def flight_lines(self) -> List[str]:
        return self.flight.lines()

    def trace_of(self, req) -> Optional[RequestTrace]:
        entry = self._active.get(id(req))
        if entry is not None:
            return entry[1]
        return self._completed_by_key.get(id(req))

    @property
    def active(self) -> List[RequestTrace]:
        return [t for _, t in self._active.values()]

    @property
    def completed(self) -> List[RequestTrace]:
        return list(self._completed)
