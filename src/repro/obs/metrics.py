"""Typed host-side metrics: counters, gauges, fixed-bucket histograms.

Prometheus-shaped but dependency-free.  Metrics are *families* keyed by
a name plus declared label names; ``family.labels(kind="query")``
returns (creating on demand) the child holding the actual value.  A
family declared with no labels proxies the single default child, so
``reg.counter("x").inc()`` just works.

Histograms are fixed-bucket with NO per-sample storage: ``observe``
lands each sample in the first bucket whose upper bound is >= the
sample (plus an overflow bucket), keeping O(len(buckets)) memory at any
traffic volume.  Quantiles are extracted from the cumulative bucket
counts and always return a bucket UPPER BOUND — a conservative estimate
that is *exact* whenever the samples sit on bucket boundaries (which is
what the deterministic simulation clock produces, and what the property
suite asserts: merge associativity, monotone quantiles,
bucket-boundary exactness).

Snapshots export two ways (same data):

  registry.snapshot()       — plain-JSON dict (committed benchmark files,
                              CI artifacts)
  registry.to_prometheus()  — Prometheus text exposition format
                              (``render_prometheus`` also re-renders a
                              saved snapshot dict, used by
                              scripts/serve_metrics.py --from-json)
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Sequence, Tuple

_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# default latency ladder (seconds): wide enough for micro-dispatches up
# to multi-minute drains; sub-ms resolution where serve batches live
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = (
    1e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0)


class Counter:
    """Monotonically non-decreasing value (int or float increments)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    """Point-in-time value (set to anything, any direction)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n


class Histogram:
    """Fixed-bucket histogram; no per-sample storage.

    ``bounds`` are strictly-increasing finite upper bounds; an implicit
    +Inf overflow bucket is always appended.  ``counts[i]`` is the
    number of samples with ``value <= bounds[i]`` that did not fit an
    earlier bucket (i.e. per-bucket, not cumulative)."""
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Sequence[float] = DEFAULT_TIME_BUCKETS):
        bounds = tuple(float(b) for b in bounds)
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        if any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(
                f"bucket bounds must be strictly increasing: {bounds}")
        if not all(math.isfinite(b) for b in bounds):
            raise ValueError("bucket bounds must be finite "
                             "(+Inf overflow is implicit)")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)     # +1 = overflow (+Inf)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        i = 0
        n = len(self.bounds)
        while i < n and value > self.bounds[i]:
            i += 1
        self.counts[i] += 1
        self.sum += value
        self.count += 1

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-quantile sample.

        Exact when samples sit on bucket boundaries; otherwise a
        conservative (>= true value) estimate.  Returns 0.0 for an
        empty histogram and ``inf`` when the quantile falls in the
        overflow bucket (samples beyond the largest finite bound —
        widen the ladder rather than trusting that number)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.bounds[i] if i < len(self.bounds) \
                    else math.inf
        return math.inf                            # unreachable

    def percentiles(self) -> Dict[str, float]:
        return {"p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def merge(self, other: "Histogram") -> "Histogram":
        """Combine two histograms over the SAME bucket ladder.  With
        integer-valued sums the operation is exact and associative —
        merging per-shard histograms loses nothing vs one global one."""
        if self.bounds != other.bounds:
            raise ValueError("cannot merge histograms with different "
                             f"bucket ladders: {self.bounds} vs "
                             f"{other.bounds}")
        out = Histogram(self.bounds)
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.sum = self.sum + other.sum
        out.count = self.count + other.count
        return out


class _Family:
    """One named metric with declared label names and per-label-values
    children."""

    def __init__(self, name: str, help: str, label_names: Tuple[str, ...],
                 make_child):
        self.name = name
        self.help = help
        self.label_names = label_names
        self._make = make_child
        self._children: Dict[Tuple[str, ...], object] = {}
        if not label_names:
            self._children[()] = make_child()

    def labels(self, **kv):
        if tuple(sorted(kv)) != tuple(sorted(self.label_names)):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(kv)}")
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = self._make()
        return child

    def children(self) -> List[Tuple[Tuple[str, ...], object]]:
        return sorted(self._children.items())

    # no-label convenience: proxy the default child
    def _default(self):
        if self.label_names:
            raise ValueError(
                f"metric {self.name!r} is labelled {self.label_names}; "
                "use .labels(...)")
        return self._children[()]

    def inc(self, n=1):
        self._default().inc(n)

    def set(self, v):
        self._default().set(v)

    def observe(self, v):
        self._default().observe(v)

    @property
    def value(self):
        return self._default().value

    def aggregate(self) -> Histogram:
        """Merge all children of a histogram family into one histogram
        (e.g. per-kind latency children -> overall percentiles)."""
        hists = [c for _, c in self.children()]
        if not hists or not isinstance(hists[0], Histogram):
            raise ValueError(f"{self.name!r} is not a histogram family")
        out = Histogram(hists[0].bounds)
        for h in hists:
            out = out.merge(h)
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Holds metric families; declaration is idempotent (re-declaring
    the same name with the same type/labels returns the existing
    family; a conflicting re-declaration raises)."""

    def __init__(self):
        self._families: Dict[str, Tuple[str, _Family]] = {}

    def _declare(self, kind: str, name: str, help: str,
                 labels: Sequence[str], make_child) -> _Family:
        if not _NAME.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        labels = tuple(labels)
        for l in labels:
            if not _LABEL.match(l):
                raise ValueError(f"invalid label name {l!r}")
        existing = self._families.get(name)
        if existing is not None:
            ekind, fam = existing
            if ekind != kind or fam.label_names != labels:
                raise ValueError(
                    f"metric {name!r} already declared as {ekind} with "
                    f"labels {fam.label_names}")
            return fam
        fam = _Family(name, help, labels, make_child)
        self._families[name] = (kind, fam)
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> _Family:
        return self._declare("counter", name, help, labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> _Family:
        return self._declare("gauge", name, help, labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS
                  ) -> _Family:
        bounds = tuple(float(b) for b in buckets)
        fam = self._declare("histogram", name, help, labels,
                            lambda: Histogram(bounds))
        return fam

    def get(self, name: str) -> Optional[_Family]:
        entry = self._families.get(name)
        return entry[1] if entry else None

    # -- export --------------------------------------------------------
    def snapshot(self) -> Dict[str, dict]:
        """Plain-JSON dict of every family (stable key order)."""
        out: Dict[str, dict] = {}
        for name in sorted(self._families):
            kind, fam = self._families[name]
            values = []
            for key, child in fam.children():
                labels = dict(zip(fam.label_names, key))
                if kind == "histogram":
                    values.append({
                        "labels": labels,
                        "buckets": list(child.bounds),
                        "counts": list(child.counts),
                        "sum": child.sum, "count": child.count,
                        **{k: _json_num(v)
                           for k, v in child.percentiles().items()}})
                else:
                    values.append({"labels": labels,
                                   "value": _json_num(child.value)})
            out[name] = {"type": kind, "help": fam.help, "values": values}
        return out

    def to_prometheus(self) -> str:
        return render_prometheus(self.snapshot())


def _json_num(v):
    """inf/nan are not JSON — encode as strings (rare: overflow-bucket
    quantiles only)."""
    if isinstance(v, float) and not math.isfinite(v):
        return repr(v)
    return v


def _fmt_labels(labels: Dict[str, str], extra: str = "") -> str:
    parts = [f'{k}="{_esc(v)}"' for k, v in labels.items()]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _esc(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


def _fmt_value(v) -> str:
    if isinstance(v, str):          # _json_num-encoded inf/nan
        return {"inf": "+Inf", "-inf": "-Inf"}.get(v, "NaN")
    if isinstance(v, float):
        return repr(v)
    return str(v)


def render_prometheus(snapshot: Dict[str, dict]) -> str:
    """Prometheus text exposition of a ``MetricsRegistry.snapshot()``
    dict (shared by live registries and saved-snapshot re-rendering)."""
    lines: List[str] = []
    for name in sorted(snapshot):
        meta = snapshot[name]
        if meta["help"]:
            lines.append(f"# HELP {name} {meta['help']}")
        lines.append(f"# TYPE {name} {meta['type']}")
        for val in meta["values"]:
            labels = val["labels"]
            if meta["type"] == "histogram":
                cum = 0
                bounds = list(val["buckets"]) + [math.inf]
                for le, c in zip(bounds, val["counts"]):
                    cum += c
                    le_s = "+Inf" if math.isinf(le) else repr(float(le))
                    le_label = 'le="' + le_s + '"'
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(labels, le_label)} {cum}")
                lines.append(f"{name}_sum{_fmt_labels(labels)} "
                             f"{_fmt_value(val['sum'])}")
                lines.append(f"{name}_count{_fmt_labels(labels)} "
                             f"{val['count']}")
            else:
                lines.append(f"{name}{_fmt_labels(labels)} "
                             f"{_fmt_value(val['value'])}")
    return "\n".join(lines) + "\n"
