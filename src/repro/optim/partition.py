"""Parameter partitioning for partial (LoRA-only) training.

Differentiate only the trainable subtree: the loss closure merges the two
trees, so frozen parameters are constants to AD — no cotangents, no
optimizer state, no fp32 copies for the 400B frozen base.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax


def _is_none(x):
    return x is None


def trainable_mask(params: Any, predicate: Callable[[Tuple], bool]) -> Any:
    """Build a boolean mask pytree from a path predicate.

    predicate receives a tuple of str path keys, e.g.
    ``('layers', 'attn', 'lora', 'q', 'a')``.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    vals = [bool(predicate(tuple(_key_str(k) for k in path)))
            for path, _ in flat]
    return jax.tree.unflatten(treedef, vals)


def _key_str(k):
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def lora_predicate(path: Tuple[str, ...]) -> bool:
    """The paper's trainable set: conditional-LoRA deltas + <COMP> embed."""
    return "lora" in path or "comp_embed" in path


def partition(params: Any, mask: Any) -> Tuple[Any, Any]:
    train = jax.tree.map(lambda p, m: p if m else None, params, mask)
    frozen = jax.tree.map(lambda p, m: None if m else p, params, mask)
    return train, frozen


def merge(train: Any, frozen: Any) -> Any:
    return jax.tree.map(
        lambda t, f: f if t is None else t, train, frozen,
        is_leaf=_is_none)
