"""AdamW + clipping + schedules, from scratch (no optax).

Supports *partial* training (the paper's LoRA-only mode): a boolean
``trainable`` pytree mask restricts both updates and optimizer-state
allocation — frozen leaves carry no moments (llama4-400B trains its
conditional-LoRA deltas with megabytes, not terabytes, of optimizer state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    schedule: str = "cosine"       # cosine | constant
    warmup_steps: int = 20
    total_steps: int = 1000


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "cosine":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def _mask_tree(tree, mask, fill=None):
    return jax.tree.map(
        lambda x, m: x if m else (fill if fill is not None else None),
        tree, mask, is_leaf=lambda x: x is None)


def init_adamw(params: Any, trainable: Optional[Any] = None) -> AdamWState:
    if trainable is None:
        trainable = jax.tree.map(lambda _: True, params)
    zeros = jax.tree.map(
        lambda p, m: jnp.zeros_like(p, jnp.float32) if m else None,
        params, trainable)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(lambda z: None if z is None
                                      else jnp.zeros_like(z), zeros,
                                      is_leaf=lambda x: x is None))


def global_norm(grads: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads) if g is not None]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: AdamWState,
                 trainable: Optional[Any] = None):
    """Returns (new_params, new_state, metrics)."""
    if trainable is None:
        trainable = jax.tree.map(lambda _: True, params)
    gnorm = global_norm(_mask_tree(grads, trainable))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu, tr):
        if not tr or mu is None:
            return p, mu, nu
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mh, nh = mu / bc1, nu / bc2
        delta = mh / (jnp.sqrt(nh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = tdef.flatten_up_to(state.mu)
    flat_nu = tdef.flatten_up_to(state.nu)
    flat_tr = jax.tree.leaves(trainable)
    out = [upd(p, g, mu, nu, tr) for p, g, mu, nu, tr
           in zip(flat_p, flat_g, flat_mu, flat_nu, flat_tr)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu), \
        {"grad_norm": gnorm, "lr": lr}
