"""Gradient compression for the data-parallel reduce, with error feedback.

Two codecs:
  int8  — per-tensor symmetric quantization; 4x wire reduction. The DP
          all-reduce becomes reduce-scatter(int8->fp32 accumulate) semantics
          by dequantizing before psum (XLA reduces fp32; wire bytes of the
          *gather* side drop 4x when combined with the reduce-scatter +
          quantized all-gather pattern below).
  topk  — magnitude top-k% sparsification with error feedback (Lin et al.,
          Deep Gradient Compression): residuals accumulate locally so the
          update stays unbiased over time.

Used by ``repro.launch.train`` through ``compressed_psum`` inside shard_map;
unit-tested for codec round-trip + error-feedback convergence invariants.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any   # same structure as grads


def init_ef(grads_like: Any) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


# --- int8 codec ------------------------------------------------------------

def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# --- top-k codec -----------------------------------------------------------

def topk_sparsify(x: jnp.ndarray, frac: float) -> jnp.ndarray:
    """Keep the top ``frac`` fraction of entries by magnitude (dense mask —
    the wire format would transmit (indices, values); we model the value
    selection and the error it leaves behind)."""
    flat = x.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(x) >= thresh
    return x * mask


# --- error-feedback compress step -------------------------------------------

def compress_with_ef(grads: Any, ef: EFState, codec: str = "int8",
                     topk_frac: float = 0.01):
    """Returns (compressed_grads, new_ef). compressed + residual == grads + old residual."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if codec == "int8":
            q, s = quantize_int8(gf)
            out = dequantize_int8(q, s)
        elif codec == "topk":
            out = topk_sparsify(gf, topk_frac)
        else:
            out = gf
        return out, gf - out

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = tdef.unflatten([o[0] for o in outs])
    resid = tdef.unflatten([o[1] for o in outs])
    return comp, EFState(residual=resid)


def compressed_psum(grads: Any, axis_name, ef: Optional[EFState] = None,
                    codec: str = "none", topk_frac: float = 0.01):
    """psum over the DP axis with optional codec + error feedback.

    Call inside shard_map; returns (reduced_grads, new_ef).
    """
    if codec == "none" or ef is None:
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), grads), ef
    comp, new_ef = compress_with_ef(grads, ef, codec, topk_frac)
    red = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), comp)
    return red, new_ef
