"""Losses: numerically-stable masked next-token cross entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_loss(logits: jnp.ndarray, tokens: jnp.ndarray,
                    loss_mask: jnp.ndarray) -> jnp.ndarray:
    """logits (B, T, V) for positions p..p+T; tokens (B, T+1) = the tokens at
    those positions plus one (targets are tokens[:, 1:]); loss_mask (B, T)."""
    targets = tokens[:, 1:]
    lg = logits[:, :targets.shape[1]].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    mask = loss_mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
