"""Training: jitted CCM train step (pjit/GSPMD) + fault-tolerant loop.

``make_train_step`` builds one XLA program containing: CCM parallelized
forward (paper Alg. 1), masked tail loss, backprop restricted to the
trainable partition (LoRA-only by default — the paper's regime), optional
gradient compression on the DP reduce (shard_map over the data/pod axes,
model axis left to GSPMD), AdamW update.

``TrainLoop`` adds production concerns: checkpoint/restart (atomic + async),
elastic restore onto a different mesh, step-time watchdog (straggler
detection), deterministic restartable data order.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.core import masks as M
from repro.data.synthetic import ShardableIndexIterator, sample_kv_batch
from repro.distributed import sharding as SH
from repro.distributed.context import DistContext, shard_map_compat
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.obs import perf_counter
from repro.optim import partition as PT
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update, init_adamw
from repro.optim.grad_compress import EFState, compressed_psum, init_ef
from repro.optim.losses import next_token_loss


def trainable_mask_for(cfg: ModelConfig, params_shapes) -> Any:
    if cfg.train_mode == "lora":
        return PT.trainable_mask(params_shapes, PT.lora_predicate)
    return jax.tree.map(lambda _: True, params_shapes)


def _loss_fn(tp, fp, cfg: ModelConfig, layout: M.SegmentLayout, batch,
             dist: Optional[DistContext]):
    params = PT.merge(tp, fp)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = batch["frames"]
    if cfg.family == "vlm":
        kw["patches"] = batch["patches"]
    logits = T.train_forward(params, cfg, batch["tokens"], layout,
                             dist=dist, **kw)
    tail = batch["tokens"][:, layout.seq_len - layout.tail_len:]
    return next_token_loss(logits, tail, batch["loss_mask"])


def make_train_step(cfg: ModelConfig, layout: M.SegmentLayout,
                    opt_cfg: AdamWConfig,
                    dist: Optional[DistContext] = None,
                    grad_codec: str = "none",
                    topk_frac: float = 0.01) -> Callable:
    """Returns step(train_params, frozen_params, opt_state, batch, ef)
    -> (train_params, opt_state, metrics, ef)."""

    def step(tp, fp, opt: AdamWState, batch, ef: Optional[EFState]):
        if grad_codec != "none" and dist is not None:
            # grads per data shard -> codec + psum over data/pod (wire),
            # model axis left automatic (TP sharding preserved). EF residual
            # is PER-SHARD state: leading device axis, sharded on data.
            def shard_grads(tpp, fpp, bb, eff):
                loss, grads = jax.value_and_grad(_loss_fn)(
                    tpp, fpp, cfg, layout, bb, dist)
                eff_local = jax.tree.map(lambda r: r[0], eff.residual)
                grads, new_ef = compressed_psum(
                    grads, dist.batch_axes, EFState(eff_local),
                    grad_codec, topk_frac)
                loss = jax.lax.pmean(loss, dist.batch_axes)
                new_ef = EFState(jax.tree.map(lambda r: r[None],
                                              new_ef.residual))
                return loss, grads, new_ef

            nb = dist.n_data
            ef_spec = EFState(jax.tree.map(
                lambda _: P(dist.batch_axes), ef.residual))
            loss, grads, ef = shard_map_compat(
                shard_grads, mesh=dist.mesh,
                in_specs=(P(), P(), SH.batch_spec(dist), ef_spec),
                out_specs=(P(), P(), ef_spec),
                axis_names=set(dist.batch_axes),
                check_vma=False)(tp, fp, batch, ef)
            grads = jax.tree.map(lambda g: g / nb, grads)
        else:
            loss, grads = jax.value_and_grad(_loss_fn)(
                tp, fp, cfg, layout, batch, dist)
        mask = jax.tree.map(lambda _: True, tp)
        new_tp, new_opt, metrics = adamw_update(opt_cfg, tp, grads, opt, mask)
        metrics["loss"] = loss
        return new_tp, new_opt, metrics, ef

    return step


def jit_train_step(step_fn, cfg: ModelConfig, dist: DistContext,
                   params_shapes, opt_shapes, batch_shapes,
                   trainable) -> Callable:
    """pjit with explicit in/out shardings derived from the rules."""
    pspecs = SH.param_pspecs(cfg, params_shapes, dist)
    tp_specs, fp_specs = PT.partition(pspecs, trainable)
    opt_specs = SH.opt_pspecs(tp_specs, opt_shapes)
    bspecs = {k: SH.batch_spec(dist, extra_dims=len(v.shape) - 1)
              for k, v in batch_shapes.items()}
    mesh = dist.mesh
    in_sh = (SH.named(mesh, tp_specs), SH.named(mesh, fp_specs),
             SH.named(mesh, opt_specs), SH.named(mesh, bspecs), None)
    out_sh = (SH.named(mesh, tp_specs), SH.named(mesh, opt_specs),
              None, None)
    return jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 2))


# ===========================================================================
# fault-tolerant loop
# ===========================================================================

@dataclasses.dataclass
class WatchdogStats:
    """Step-time watchdog: flags straggling steps (>k x median)."""
    times: list = dataclasses.field(default_factory=list)
    threshold: float = 3.0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < 5:
            return False
        med = sorted(self.times[-50:])[len(self.times[-50:]) // 2]
        return dt > self.threshold * med


class TrainLoop:
    """Checkpointed, restartable training driver (single-host harness for
    the multi-host pattern; data order and checkpoint layout are host-count
    independent)."""

    def __init__(self, cfg: ModelConfig, layout: M.SegmentLayout,
                 opt_cfg: AdamWConfig, batch_size: int,
                 ckpt_dir: Optional[str] = None, seed: int = 0,
                 dist: Optional[DistContext] = None,
                 ckpt_every: int = 50, grad_codec: str = "none"):
        self.cfg, self.layout, self.opt_cfg = cfg, layout, opt_cfg
        self.batch_size = batch_size
        self.dist = dist
        params = T.init_lm(jax.random.PRNGKey(seed), cfg)
        self.trainable = trainable_mask_for(cfg, params)
        self.tp, self.fp = PT.partition(params, self.trainable)
        self.opt = init_adamw(self.tp)
        if grad_codec == "none":
            self.ef = None
        elif dist is not None:
            self.ef = EFState(jax.tree.map(
                lambda p: jnp.zeros((dist.n_data,) + p.shape, jnp.float32),
                self.tp))
        else:
            self.ef = init_ef(self.tp)
        self.it = ShardableIndexIterator(seed, batch_size)
        step_fn = make_train_step(cfg, layout, opt_cfg, dist, grad_codec)
        self.step_fn = jax.jit(step_fn) if dist is None else step_fn
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.watchdog = WatchdogStats()
        self.history: list = []

    # ------------------------------------------------------------------
    def maybe_restore(self):
        if self.ckpt is None:
            return 0
        latest = self.ckpt.latest()
        if latest is None:
            return 0
        state_tmpl = {"tp": self.tp, "opt": self.opt}
        restored, extra = self.ckpt.restore(latest, state_tmpl)
        self.tp, self.opt = restored["tp"], restored["opt"]
        self.it.load_state_dict(extra["iterator"])
        return int(extra["step"])

    def run(self, n_steps: int, start_step: int = 0,
            log_every: int = 10) -> list:
        for s in range(start_step, n_steps):
            key = self.it.next_key()
            batch = sample_kv_batch(key, self.layout, self.batch_size)
            t0 = perf_counter()
            self.tp, self.opt, metrics, self.ef = self.step_fn(
                self.tp, self.fp, self.opt, batch, self.ef)
            loss = float(metrics["loss"])
            dt = perf_counter() - t0
            straggle = self.watchdog.record(dt)
            self.history.append({"step": s, "loss": loss, "dt": dt,
                                 "straggler": straggle})
            if log_every and s % log_every == 0:
                print(f"step {s:5d} loss {loss:.4f} "
                      f"dt {dt*1e3:7.1f}ms{'  STRAGGLER' if straggle else ''}")
            if self.ckpt and (s + 1) % self.ckpt_every == 0:
                self.ckpt.save(s + 1, {"tp": self.tp, "opt": self.opt},
                               extra={"step": s + 1,
                                      "iterator": self.it.state_dict()})
        if self.ckpt:
            self.ckpt.wait()
        return self.history
