"""Serving: jitted online-inference step builders (pjit/GSPMD).

Three production step programs per architecture (these are what the
dry-run lowers per shape):

  prefill_step — input I(t) over [Mem, self] (prefill_32k)
  decode_step  — one token over [Mem, cache(S)] (decode_32k)
  stream_step  — CCM streaming decode: bounded window + compressed memory
                 (long_500k for attention archs; the paper's unbounded-
                 stream answer, Fig. 8/9)
  ingest_step  — g_comp for a new context chunk (the online compression op)

SSM/hybrid archs decode in O(1) state — long_500k lowers their native
decode_step.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import inference as I
from repro.core import streaming as STR
from repro.distributed import sharding as SH
from repro.distributed.context import DistContext, divisible
from repro.models.config import ModelConfig


def serve_specs(cfg: ModelConfig, dist: DistContext, *,
                batch_sharded: bool = True, shard_cache_seq: bool = False):
    state_specs = SH.online_state_pspecs(
        cfg, dist, batch_sharded=batch_sharded,
        shard_cache_seq=shard_cache_seq)
    tok_spec = P(dist.batch_axes if batch_sharded else None, None)
    return state_specs, tok_spec


def make_prefill_step(cfg: ModelConfig, dist: Optional[DistContext] = None,
                      impl: Optional[str] = None, **spec_kw) -> Callable:
    def fn(params, state, tokens, patches=None):
        return I.prefill(params, cfg, state, tokens, dist, patches=patches,
                         impl=impl)

    if dist is None:
        return jax.jit(fn)
    return _jit_with_specs(fn, cfg, dist, **spec_kw)


def make_decode_step(cfg: ModelConfig, dist: Optional[DistContext] = None,
                     **spec_kw) -> Callable:
    def fn(params, state, tokens):
        return I.decode_step(params, cfg, state, tokens, dist)

    if dist is None:
        return jax.jit(fn)
    return _jit_with_specs(fn, cfg, dist, **spec_kw)


def make_ingest_step(cfg: ModelConfig, dist: Optional[DistContext] = None,
                     **spec_kw) -> Callable:
    def fn(params, state, tokens):
        return I.ingest_context(params, cfg, state, tokens, dist)

    if dist is None:
        return jax.jit(fn)
    return _jit_with_specs(fn, cfg, dist, ingest=True, **spec_kw)


def make_stream_step(cfg: ModelConfig, params_shapes,
                     dist: Optional[DistContext] = None,
                     batch_sharded: bool = True) -> Callable:
    def fn(params, st, tokens):
        return STR.stream_step(params, cfg, st, tokens)

    if dist is None:
        return jax.jit(fn)
    pspecs = SH.param_pspecs(cfg, params_shapes, dist)
    sspecs = SH.stream_state_pspecs(cfg, dist, batch_sharded)
    tok = P(dist.batch_axes if batch_sharded else None, None)
    mesh = dist.mesh
    vspec = P(dist.batch_axes if batch_sharded else None, None, None)
    return jax.jit(
        fn,
        in_shardings=(SH.named(mesh, pspecs), SH.named(mesh, sspecs),
                      SH.named(mesh, tok)),
        out_shardings=(SH.named(mesh, vspec), SH.named(mesh, sspecs)),
        donate_argnums=(1,))


# ---------------------------------------------------------------------------
# multi-tenant session steps (repro.serve engine)
#
# The batched steps above share one scalar counter (pos/steps/length) per
# batch — fine when one batch IS one user stream, wrong for a batch packed
# from many independent sessions at different timeline points.  The
# session steps vmap the single-session op instead: every state leaf gains
# a leading session axis (arena pack layout) and each lane carries its own
# counters.  `make_arena_step` fuses arena gather -> vmapped op -> scatter
# into one jit per op kind; distinct (B, token_len) shapes each compile
# once, so `fn._cache_size()` is the recompile-churn metric the serve
# engine reports.  `make_sharded_arena_step` is the multi-device variant:
# the arena's session axis is partitioned one row block per device
# (serve.arena) and the same fused step runs under shard_map on every
# shard's local rows — per-session state is independent, so the program
# has NO cross-device collectives on the steady path.
# ---------------------------------------------------------------------------

def ragged_family(cfg: ModelConfig) -> bool:
    """Whether masked token lanes are supported: attention archs only —
    SSM/hybrid recurrent scans cannot skip pad tokens, so their batches
    keep exact token-length grouping."""
    return cfg.family not in ("ssm", "hybrid")


def session_vmap(cfg: ModelConfig, op: str, ragged: bool = False) -> Callable:
    """Unjitted vmapped session op:
    (params, state(B,...), tokens (B,1,l), lengths (B,)).

    'ingest' -> state; 'query'/'stream' -> (logits (B,1,l,V), state).
    Query = prefill of I(t) over [Mem, self] with full per-token logits.

    Per-lane cost stays occupancy-proportional under the vmap: the
    segmented attends reroute through `models.attention`'s lane-batched
    `custom_vmap` rule (per-lane tile skip instead of a capacity-bound
    `select`), and 'stream' dispatches to `streaming.stream_step_lanes`,
    which gates the eviction/compression pass on a batch-level
    "any lane pending" `cond` and re-selects non-overflowing lanes'
    state bit-exactly instead of compressing every lane every step.

    ``ragged``: each lane's tokens are padded up to a shared token bucket
    and ``lengths`` carries the per-request valid length — pad tokens are
    masked out of attention and frozen out of every state write, so a
    padded lane is bit-identical to running the request unpadded.  With
    ``ragged=False`` lengths are accepted but ignored (exact-length
    batches; the only mode for SSM/hybrid)."""
    if ragged and not ragged_family(cfg):
        raise ValueError(
            f"ragged session batching unsupported for family {cfg.family!r}")
    if op == "stream":
        def fn(params, state, tokens, lengths):
            return STR.stream_step_lanes(
                params, cfg, state, tokens,
                lengths=lengths if ragged else None)
        return fn
    if ragged:
        core = {
            "ingest": lambda p, st, tk, vl: I.ingest_context(
                p, cfg, st, tk, valid_len=vl),
            "query": lambda p, st, tk, vl: I.prefill(
                p, cfg, st, tk, full_logits=True, valid_len=vl),
        }[op]
    else:
        core = {
            "ingest": lambda p, st, tk, vl: I.ingest_context(p, cfg, st, tk),
            "query": lambda p, st, tk, vl: I.prefill(p, cfg, st, tk,
                                                     full_logits=True),
        }[op]

    def fn(params, state, tokens, lengths):
        return jax.vmap(lambda st, tk, vl: core(params, st, tk, vl))(
            state, tokens, lengths)
    return fn


def make_arena_step(cfg: ModelConfig, op: str,
                    ragged: bool = False) -> Callable:
    """Fused arena step:
    (params, slabs, ids (B,), tokens (B,1,l), lengths (B,)) ->
    (logits-or-None, slabs).

    Shape contract: ``slabs`` is the arena's state pytree — every leaf
    of the single-session template (inner batch 1) with a leading
    ``(n_slots + 1,)`` slot axis; ``ids`` selects the batch's B slot
    rows (``pad_slot`` for pad lanes); ``tokens`` are (B, 1, token_len)
    bucket-padded token lanes and ``lengths`` the per-lane valid lengths
    (== token_len everywhere when ``ragged=False``).  'query'/'stream'
    return logits (B, 1, token_len, V) — rows past a lane's valid length
    are masked-lane garbage the engine slices off.

    Gather of the batch's slot rows, the vmapped op, and the scatter of
    updated rows run as ONE jitted program over the donated slabs — the
    serve engine's hot path (no intermediate batch materialization, no
    extra dispatch boundaries).  Inside the vmapped op, decode/stream
    attention takes the lane-batched route (per-lane tile skip; see
    `session_vmap`), so the fused program's cost follows per-lane cache
    occupancy rather than arena capacity."""
    from repro.kernels import ops as KOPS
    vf = session_vmap(cfg, op, ragged)

    def fn(params, slabs, ids, tokens, lengths):
        state = jax.tree.map(lambda s: KOPS.session_gather(s, ids), slabs)
        # barrier: without it the remat'd layer scan recomputes the
        # gather every layer (measured ~2x step time on CPU)
        state = jax.lax.optimization_barrier(state)
        if op == "ingest":
            out, new = None, vf(params, state, tokens, lengths)
        else:
            out, new = vf(params, state, tokens, lengths)
        # leaves the op left untouched come back as the SAME tracer
        # (ingest never writes the KV cache, query never writes the
        # memory) — skip their scatter entirely
        slabs = jax.tree.map(
            lambda s, old, r: s if r is old
            else KOPS.session_scatter(s, ids, r),
            slabs, state, new)
        return out, slabs
    return jax.jit(fn, donate_argnums=(1,))


def make_sharded_arena_step(cfg: ModelConfig, op: str, mesh,
                            ragged: bool = False) -> Callable:
    """`make_arena_step` partitioned over the SESSION axis: one arena
    shard (contiguous row block, `serve.arena`) per device of the 1-D
    ``mesh`` (axis ``"shards"``, `launch.mesh.make_session_mesh`).

    Call contract:
    (params, slabs, ids (S, B), tokens (S, B, 1, l), lengths (S, B)) ->
    (logits (S, B, 1, l, V) or None for ingest, slabs).

    ``slabs`` leaves carry the arena's full ``(n_rows, ...)`` row axis
    sharded ``P("shards")`` (each device holds its shard's
    ``slots_per_shard + 1`` rows); ``ids`` row ``s`` holds shard ``s``'s
    LOCAL row indices (``SessionArena.local_row`` — every shard's
    scratch row is ``slots_per_shard`` — NOT global slot ids); params
    are replicated.  Inside `shard_map` each device runs the exact fused
    gather -> vmapped-op -> scatter of `make_arena_step` on its own row
    block: per-session CCM state is independent, so the program contains
    NO cross-device collectives — session state never crosses a device
    boundary on the steady path (the serve engine's
    ``serve_cross_shard_moves_total`` counter stays 0).  Slabs are
    donated, so each shard's rows update in place on their own device.

    One jit per (op, ragged) like the single-shard builder; distinct
    (S, B, token_len) shapes each compile once."""
    from repro.distributed.context import shard_map_compat
    from repro.kernels import ops as KOPS
    vf = session_vmap(cfg, op, ragged)

    def body(params, slabs, ids, tokens, lengths):
        # per-device view: slabs leaves hold this shard's row block;
        # ids/tokens/lengths arrive (1, ...) — drop the shard dim
        ids, tokens, lengths = ids[0], tokens[0], lengths[0]
        state = jax.tree.map(lambda s: KOPS.session_gather(s, ids), slabs)
        state = jax.lax.optimization_barrier(state)
        if op == "ingest":
            new = vf(params, state, tokens, lengths)
        else:
            out, new = vf(params, state, tokens, lengths)
        slabs = jax.tree.map(
            lambda s, old, r: s if r is old
            else KOPS.session_scatter(s, ids, r),
            slabs, state, new)
        if op == "ingest":
            # shard_map outputs must be arrays; logits=None stays outside
            return slabs
        return out[None], slabs       # re-attach the shard dim

    shard = P("shards")
    out_specs = shard if op == "ingest" else (shard, shard)
    sharded = shard_map_compat(
        body, mesh,
        in_specs=(P(), shard, shard, shard, shard),
        out_specs=out_specs,
        # per-lane counters make leaves device-varying in ways the
        # static replication checker cannot prove; correctness is pinned
        # by the single-shard bit-exactness tests instead
        check_vma=False)

    def fn(params, slabs, ids, tokens, lengths):
        if op == "ingest":
            return None, sharded(params, slabs, ids, tokens, lengths)
        return sharded(params, slabs, ids, tokens, lengths)
    return jax.jit(fn, donate_argnums=(1,))


@functools.partial(jax.jit, static_argnames=("cfg", "group"),
                   donate_argnums=(0,))
def recompress_arena_slots(mem_slabs, ids, cfg: ModelConfig, group: int):
    """Arena-resident memory recompression: gather the ``ids`` rows of
    the slabs' `MemState` subtree, collapse every ``group`` consecutive
    filled <COMP> groups per lane (`core.memory.recompress_memory`,
    masked per lane via `streaming.recompress_memory_lanes`), and
    scatter the shrunk memories back — one jitted program over the
    donated mem slabs, no model params touched (it runs unchanged under
    the null-step simulation harness).

    Lanes whose memory would not shrink (fewer than two filled groups,
    or pad lanes gathering the scratch row) are re-selected bit-exactly.
    Module-level jit: `ModelConfig` is hashable, so every engine —
    and every fuzzed simulation trace — shares one compile per
    (shape, cfg, group)."""
    from repro.kernels import ops as KOPS
    mem = jax.tree.map(lambda s: KOPS.session_gather(s, ids), mem_slabs)
    # shrink only when it frees at least one group: ceil(g/r) < g
    do = -(-mem.slots // group) < mem.slots
    new = STR.recompress_memory_lanes(cfg, mem, group, do)
    return jax.tree.map(
        lambda s, r: KOPS.session_scatter(s, ids, r), mem_slabs, new)


@functools.partial(jax.jit, donate_argnums=(0,))
def cow_clone_slots(slabs, src_ids, dst_ids):
    """Copy-on-write break: clone the ``src_ids`` rows of every slab
    leaf into the freshly-allocated ``dst_ids`` rows — one jitted
    gather/scatter over the donated slabs, batched over all of a shard's
    COW breaks in an activation plan.  Pad lanes pass
    ``src == dst == pad_slot`` (scratch-row self-copy, no effect), so
    the program compiles once per batch bucket.

    This is the only sanctioned way to make a shared arena row writable:
    the caller allocates a fresh slot, clones the shared row here, drops
    its reference on the shared slot, and repoints the session — the
    siblings' view of the original row is never touched.  Module-level
    jit like `recompress_arena_slots`: every arena (engines, fuzzed
    simulation traces) shares one compile per shape."""
    from repro.kernels import ops as KOPS
    rows = jax.tree.map(lambda s: KOPS.session_gather(s, src_ids), slabs)
    return jax.tree.map(
        lambda s, r: KOPS.session_scatter(s, dst_ids, r), slabs, rows)


def make_null_step(cfg: ModelConfig, op: str, ragged: bool = False
                   ) -> Callable:
    """Control-plane-only arena step with `make_arena_step`'s exact
    call contract but NO model compute: returns zero logits of the
    contract shape and the slabs untouched.

    The serve-simulation harness (`tests/simulation.py`) injects this
    as the engine's ``step_factory`` so thousands of fuzzed
    admit->schedule->offload->restore->cancel traces exercise the REAL
    scheduler/arena/session/admission objects — free-list moves, host
    offload transfers, verdicts — without paying model FLOPs or jit
    compiles per trace."""
    del ragged

    def fn(params, slabs, ids, tokens, lengths):
        del params, ids, lengths
        if op == "ingest":
            return None, slabs
        B, _, L = tokens.shape
        return np.zeros((B, 1, L, cfg.vocab_size), np.float32), slabs
    return fn


def _jit_with_specs(fn, cfg: ModelConfig, dist: DistContext,
                    ingest: bool = False, batch_sharded: bool = True,
                    shard_cache_seq: bool = False,
                    params_shapes=None) -> Callable:
    state_specs, tok_spec = serve_specs(
        cfg, dist, batch_sharded=batch_sharded,
        shard_cache_seq=shard_cache_seq)
    mesh = dist.mesh
    pspecs = SH.param_pspecs(cfg, params_shapes, dist) \
        if params_shapes is not None else None
    p_in = SH.named(mesh, pspecs) if pspecs is not None else None
    st_in = SH.named(mesh, state_specs)
    vocab_sharded = dist.model_axis \
        if divisible(cfg.vocab_size, dist.n_model) else None
    logit_spec = P(dist.batch_axes if batch_sharded else None, None,
                   vocab_sharded)
    if ingest:
        out_sh = st_in
    else:
        out_sh = (SH.named(mesh, logit_spec), st_in)
    return jax.jit(fn,
                   in_shardings=(p_in, st_in, SH.named(mesh, tok_spec)),
                   out_shardings=out_sh,
                   donate_argnums=(1,))
