"""(architecture x input-shape) cell builders for the multi-pod dry-run.

For each of the 40 cells this produces a jitted step function with explicit
in/out shardings plus a ShapeDtypeStruct argument tree (the ``input_specs()``
pattern: weak-type-correct, shardable, zero allocation). ``.lower(*args)``
then ``.compile()`` proves the distribution config end-to-end.

Shape semantics (assignment):
  train_4k    seq 4,096  batch 256 — CCM parallel train_step
  prefill_32k seq 32,768 batch 32  — serve prefill (I(t) over Mem)
  decode_32k  seq 32,768 batch 128 — one-token decode, KV cache = seq
  long_500k   seq 524,288 batch 1  — long-context decode:
      dense/moe/vlm/encdec -> CCM streaming step (bounded window +
      compressed memory — the paper's sub-quadratic mechanism; the dense
      500k-KV variant is skipped per DESIGN §5);
      ssm    -> native O(1) state decode;
      hybrid -> O(1) SSM states + CCM-bounded attention sites.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import inference as I
from repro.core import masks as M
from repro.core import streaming as STR
from repro.distributed import sharding as SH
from repro.distributed.context import DistContext, divisible
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import partition as PT
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.launch.train import (jit_train_step, make_train_step,
                                trainable_mask_for)

I32 = jnp.int32
F32 = jnp.float32
BF16 = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    kind: str
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode", 32768, 128),
    "long_500k": ShapeSpec("long", 524288, 1),
}

# Serve-engine batch-size buckets: a short continuous-batching batch is
# padded up to the next bucket so only these batch dims ever compile.
SERVE_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)

# Serve-engine token-length buckets: a request shorter than its batch
# head's bucket is padded up to it (pad tokens masked out of attention,
# state writes frozen — see core.inference valid_len), so mixed-length
# traffic shares batches and only these token dims ever compile.
SERVE_TOKEN_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def batch_bucket(n: int, buckets=SERVE_BATCH_BUCKETS) -> int:
    """Smallest bucket >= n (largest bucket if n exceeds them all)."""
    for b in sorted(buckets):
        if n <= b:
            return b
    return max(buckets)


def token_bucket(n: int, buckets=SERVE_TOKEN_BUCKETS) -> int:
    """Smallest token bucket >= n; ``n`` itself beyond the largest bucket
    (a too-long request runs at its exact length rather than truncating)."""
    for b in sorted(buckets):
        if n <= b:
            return b
    return n


def pad_waste(lengths, buckets) -> int:
    """Total pad tokens a ladder spends on a trace of request lengths:
    ``sum(token_bucket(l) - l)``.  Adding buckets to a ladder can only
    shrink this (every length maps to a bucket at least as tight), which
    is what makes `derive_token_buckets`'s no-regression clamp sound."""
    return sum(token_bucket(n, buckets) - n for n in lengths)


def derive_token_buckets(lengths, *, max_buckets: int = 8,
                         compile_cost_tokens: float = 128.0,
                         compiled_lens=(),
                         baseline=SERVE_TOKEN_BUCKETS):
    """Fit a token-bucket ladder to OBSERVED request lengths by exact
    dynamic programming over a pad-waste-vs-compile-churn cost model:

        cost(ladder) = pad_waste(lengths, ladder)
                     + compile_cost_tokens * #{new shapes in ladder}

    ``compile_cost_tokens`` prices one extra compiled program in pad-
    token units (calibrate it from the serve engine's
    ``serve_compiled_programs_total`` / ``serve_pad_tokens_total``
    counters: how many pad tokens one compile is worth amortizing).
    ``compiled_lens`` are padded lengths the engine has ALREADY compiled
    (`ServeEngine.compile_stats`) — a bucket placed on one of those
    costs no churn, so refits gravitate to warm shapes.

    Every optimal ladder puts buckets only at observed lengths (moving a
    bucket down to the largest length it serves never increases pad),
    so the DP is exact in O(U^2 * max_buckets) over U = distinct
    lengths.  The result is clamped to never regress on the very trace
    it was fit to: if the fitted ladder pads worse than ``baseline``
    (possible when churn pricing buys fewer buckets), the baseline's
    hit buckets are unioned in — a strict pad improvement by the
    monotonicity fact above.  Deterministic for a fixed history; with
    an empty history the baseline is returned unchanged."""
    if max_buckets < 1:
        raise ValueError("max_buckets must be >= 1")
    if compile_cost_tokens < 0:
        raise ValueError("compile_cost_tokens must be >= 0")
    lengths = [int(n) for n in lengths]
    if any(n < 1 for n in lengths):
        raise ValueError("request lengths must be >= 1")
    if not lengths:
        return tuple(sorted(baseline))
    compiled = set(int(n) for n in compiled_lens)
    uniq = sorted(set(lengths))
    cnt = {u: 0 for u in uniq}
    for n in lengths:
        cnt[n] += 1
    U = len(uniq)
    K = min(max_buckets, U)
    # prefix sums: pad cost of serving uniq[i..j] from one bucket at
    # uniq[j] is uniq[j] * (count of i..j) - (token sum of i..j)
    pc = [0] * (U + 1)       # prefix counts
    ps = [0] * (U + 1)       # prefix token sums
    for i, u in enumerate(uniq):
        pc[i + 1] = pc[i] + cnt[u]
        ps[i + 1] = ps[i] + cnt[u] * u

    def seg(i, j):           # pad cost, bucket at uniq[j] serving i..j
        return uniq[j] * (pc[j + 1] - pc[i]) - (ps[j + 1] - ps[i])

    def churn(j):            # compile price of a bucket at uniq[j]
        return 0.0 if uniq[j] in compiled else compile_cost_tokens

    INF = float("inf")
    # best[k][j]: min cost covering uniq[0..j] with exactly k buckets,
    # the last at uniq[j] (a ladder must cover its largest length)
    best = [[INF] * U for _ in range(K + 1)]
    back = [[-1] * U for _ in range(K + 1)]
    for j in range(U):
        best[1][j] = seg(0, j) + churn(j)
    for k in range(2, K + 1):
        for j in range(k - 1, U):
            for i in range(k - 2, j):
                c = best[k - 1][i] + seg(i + 1, j) + churn(j)
                if c < best[k][j]:
                    best[k][j] = c
                    back[k][j] = i
    k_best = min(range(1, K + 1), key=lambda k: best[k][U - 1])
    ladder = []
    k, j = k_best, U - 1
    while j >= 0 and k >= 1:
        ladder.append(uniq[j])
        j = back[k][j]
        k -= 1
    ladder = tuple(sorted(ladder))
    if baseline and pad_waste(lengths, ladder) > pad_waste(lengths,
                                                          baseline):
        hit = set(token_bucket(n, baseline) for n in lengths)
        ladder = tuple(sorted(set(ladder) | hit))
    return ladder


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# training layout per (arch, seq)
# ---------------------------------------------------------------------------

def train_layout(cfg: ModelConfig, seq: int) -> M.SegmentLayout:
    t, m = cfg.ccm.max_steps, cfg.ccm.comp_len
    tail = max(128, seq // 16)
    chunk = (seq - tail) // t - m
    assert chunk >= 2, (cfg.name, seq)
    tail = seq - t * (chunk + m)
    return M.segment_layout(t, chunk, m, tail)


def _scaled_shape(spec: ShapeSpec, smoke: bool) -> ShapeSpec:
    if not smoke:
        return spec
    return ShapeSpec(spec.kind, 512, 4 if spec.kind == "train" else 2)


# ---------------------------------------------------------------------------
# batch / state ShapeDtypeStructs
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, layout: M.SegmentLayout,
                      batch: int, enc_len: int = 0) -> Dict[str, Any]:
    out = {"tokens": sds((batch, layout.seq_len), I32),
           "loss_mask": sds((batch, layout.tail_len - 1), F32)}
    if cfg.family == "encdec":
        out["frames"] = sds((batch, enc_len, cfg.d_model), F32)
    if cfg.family == "vlm":
        out["patches"] = sds((batch, cfg.n_frontend_tokens, 1024), F32)
    return out


def state_specs(cfg: ModelConfig, batch: int, cache_len: int,
                enc_len: int = 0) -> I.OnlineState:
    st = jax.eval_shape(
        functools.partial(I.init_online_state, cfg, batch, cache_len))
    if cfg.family == "encdec":
        L, H, D = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        cross = (sds((L, batch, enc_len, H, D), cfg.cdtype),
                 sds((L, batch, enc_len, H, D), cfg.cdtype))
        st = st._replace(cross=cross)
    return st


def stream_state_specs(cfg: ModelConfig, batch: int):
    return jax.eval_shape(
        functools.partial(STR.init_stream_state, cfg, batch))


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: T.init_lm(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# cell builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable            # jitted; call .lower(*args)
    args: Tuple
    note: str = ""


def build_train_cell(cfg: ModelConfig, spec: ShapeSpec,
                     dist: Optional[DistContext]) -> Cell:
    enc_len = spec.seq // 2 if cfg.family == "encdec" else 0
    seq = spec.seq // 2 if cfg.family == "encdec" else spec.seq
    if seq >= 2048:
        cfg = cfg.replace(attn_impl="chunked")
    if cfg.sharding_strategy == "fsdp" and dist is not None:
        # ZeRO-3: batch over every mesh axis; weights gathered per layer
        dist = dataclasses.replace(
            dist, data_axes=tuple(dist.data_axes) + (dist.model_axis,))
    layout = train_layout(cfg, seq)
    pshapes = params_specs(cfg)
    trainable = trainable_mask_for(cfg, pshapes)
    tp_s, fp_s = PT.partition(pshapes, trainable)
    opt_s = jax.eval_shape(init_adamw, tp_s)
    batch_s = train_batch_specs(cfg, layout, spec.batch, enc_len)
    opt_cfg = AdamWConfig()
    step = make_train_step(cfg, layout, opt_cfg, dist)
    if dist is None:
        fn = jax.jit(step)
    else:
        fn = jit_train_step(step, cfg, dist, pshapes, opt_s, batch_s,
                            trainable)
    return Cell(name=f"{cfg.name}:train", fn=fn,
                args=(tp_s, fp_s, opt_s, batch_s, None),
                note=f"mode={cfg.train_mode}")


def _serve_shardings(cfg, dist, st_specs, batch_sharded, extra_token_dims=1):
    mesh = dist.mesh
    sspec = SH.online_state_pspecs(cfg, dist, batch_sharded=batch_sharded)
    tok = P(dist.batch_axes if batch_sharded else None,
            *([None] * extra_token_dims))
    return SH.named(mesh, sspec), SH.named(mesh, tok)


def build_prefill_cell(cfg: ModelConfig, spec: ShapeSpec,
                       dist: Optional[DistContext]) -> Cell:
    enc_len = spec.seq // 2 if cfg.family == "encdec" else 0
    seq = spec.seq // 2 if cfg.family == "encdec" else spec.seq
    B = spec.batch
    cfg = cfg.replace(attn_impl="chunked") if seq > 4096 else cfg
    st = state_specs(cfg, B, cache_len=seq, enc_len=enc_len)
    toks = sds((B, seq), I32)
    patches = sds((B, cfg.n_frontend_tokens, 1024), F32) \
        if cfg.family == "vlm" else None

    def fn(params, state, tokens, pt=None):
        return I.prefill(params, cfg, state, tokens, dist, patches=pt)

    pshapes = params_specs(cfg)
    args = (pshapes, st, toks) + ((patches,) if patches is not None
                                  else ())
    if dist is None:
        return Cell(f"{cfg.name}:prefill", jax.jit(fn), args)
    p_sh = SH.named(dist.mesh, SH.param_pspecs(cfg, pshapes, dist))
    st_sh, tok_sh = _serve_shardings(cfg, dist, st, batch_sharded=True)
    vocab_ax = dist.model_axis if divisible(cfg.vocab_size, dist.n_model) \
        else None
    out_logit = SH.named(dist.mesh, P(dist.batch_axes, None, vocab_ax))
    in_sh = (p_sh, st_sh, tok_sh) + (
        (SH.named(dist.mesh, P(dist.batch_axes, None, None)),)
        if patches is not None else ())
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=(out_logit, st_sh),
                  donate_argnums=(1,))
    return Cell(f"{cfg.name}:prefill", jfn, args)


def build_decode_cell(cfg: ModelConfig, spec: ShapeSpec,
                      dist: Optional[DistContext],
                      cache_len: Optional[int] = None) -> Cell:
    B = spec.batch
    enc_len = 1024 if cfg.family == "encdec" else 0
    clen = cache_len if cache_len is not None else \
        (cfg.serve_cache_len or spec.seq)
    st = state_specs(cfg, B, cache_len=clen, enc_len=enc_len)
    # decode with a FULL cache of spec.seq tokens:
    if st.cache is not None:
        st = st._replace(cache=st.cache._replace(
            length=sds((), I32)))
    toks = sds((B, 1), I32)

    def fn(params, state, tokens):
        return I.decode_step(params, cfg, state, tokens, dist)

    pshapes = params_specs(cfg)
    args = (pshapes, st, toks)
    if dist is None:
        return Cell(f"{cfg.name}:decode", jax.jit(fn), args)
    p_sh = SH.named(dist.mesh, SH.param_pspecs(cfg, pshapes, dist))
    batch_sharded = B >= dist.n_data
    st_sh, tok_sh = _serve_shardings(cfg, dist, st, batch_sharded)
    vocab_ax = dist.model_axis if divisible(cfg.vocab_size, dist.n_model) \
        else None
    out_logit = SH.named(dist.mesh,
                         P(dist.batch_axes if batch_sharded else None,
                           None, vocab_ax))
    jfn = jax.jit(fn, in_shardings=(p_sh, st_sh, tok_sh),
                  out_shardings=(out_logit, st_sh), donate_argnums=(1,))
    return Cell(f"{cfg.name}:decode", jfn, args)


def build_long_cell(cfg: ModelConfig, spec: ShapeSpec,
                    dist: Optional[DistContext]) -> Cell:
    B = spec.batch
    if cfg.family == "ssm":
        # native O(1) decode; 500k context lives in the SSD state
        return dataclasses.replace(
            build_decode_cell(cfg, dataclasses.replace(spec, seq=8), dist),
            name=f"{cfg.name}:long",
            note="native SSM decode: O(1) state, no KV cache")
    if cfg.family == "hybrid":
        cell = build_decode_cell(cfg, spec, dist,
                                 cache_len=cfg.ccm.stream_window)
        return dataclasses.replace(
            cell, name=f"{cfg.name}:long",
            note="SSM states O(1); attention sites CCM-bounded "
                 f"(window {cfg.ccm.stream_window})")
    # attention archs: CCM streaming (paper Fig. 9) — bounded window + mem
    st = stream_state_specs(cfg, B)
    toks = sds((B, 1), I32)

    def fn(params, state, tokens):
        return STR.stream_step(params, cfg, state, tokens)

    pshapes = params_specs(cfg)
    args = (pshapes, st, toks)
    note = ("CCM streaming: dense 500k-KV decode skipped per DESIGN §5; "
            f"window {cfg.ccm.stream_window} + {cfg.ccm.stream_mem_slots} "
            "mem slots")
    if dist is None:
        return Cell(f"{cfg.name}:long", jax.jit(fn), args, note)
    p_sh = SH.named(dist.mesh, SH.param_pspecs(cfg, pshapes, dist))
    sspec = SH.stream_state_pspecs(cfg, dist, batch_sharded=False)
    st_sh = SH.named(dist.mesh, sspec)
    vocab_ax = dist.model_axis if divisible(cfg.vocab_size, dist.n_model) \
        else None
    out_logit = SH.named(dist.mesh, P(None, None, vocab_ax))
    jfn = jax.jit(fn,
                  in_shardings=(p_sh, st_sh,
                                SH.named(dist.mesh, P(None, None))),
                  out_shardings=(out_logit, st_sh), donate_argnums=(1,))
    return Cell(f"{cfg.name}:long", jfn, args, note)


def build_cell(cfg: ModelConfig, shape_name: str,
               dist: Optional[DistContext], smoke: bool = False) -> Cell:
    spec = _scaled_shape(SHAPES[shape_name], smoke)
    if spec.kind == "train":
        cell = build_train_cell(cfg, spec, dist)
    elif spec.kind == "prefill":
        cell = build_prefill_cell(cfg, spec, dist)
    elif spec.kind == "decode":
        cell = build_decode_cell(cfg, spec, dist)
    else:
        cell = build_long_cell(cfg, spec, dist)
    cell.name = f"{cfg.name}:{shape_name}"
    return cell
