"""Production meshes (DESIGN §6).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.distributed.context import DistContext


def _mk(shape, axes):
    if hasattr(jax.sharding, "AxisType"):      # jax >= 0.6
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=auto)
    return jax.make_mesh(shape, axes)          # older jax: Auto is implied


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_dist(mesh) -> DistContext:
    axes = mesh.axis_names
    return DistContext(mesh=mesh,
                       data_axes=("data",) if "data" in axes else (),
                       model_axis="model",
                       pod_axis="pod" if "pod" in axes else None)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU tests (requires host-platform device count)."""
    return _mk((n_data, n_model), ("data", "model"))


def available_mesh(model_parallel: int = 1):
    """Elastic: build the best mesh from whatever devices are alive."""
    n = jax.device_count()
    nm = model_parallel
    while n % nm:
        nm -= 1
    return _mk((n // nm, nm), ("data", "model"))
