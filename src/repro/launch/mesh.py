"""Production meshes (DESIGN §6).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.distributed.context import DistContext


def _mk(shape, axes):
    if hasattr(jax.sharding, "AxisType"):      # jax >= 0.6
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=auto)
    return jax.make_mesh(shape, axes)          # older jax: Auto is implied


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_dist(mesh) -> DistContext:
    axes = mesh.axis_names
    return DistContext(mesh=mesh,
                       data_axes=("data",) if "data" in axes else (),
                       model_axis="model",
                       pod_axis="pod" if "pod" in axes else None)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU tests (requires host-platform device count)."""
    return _mk((n_data, n_model), ("data", "model"))


def make_session_mesh(n_shards: Optional[int] = None):
    """1-D mesh over the SESSION axis for the sharded serve engine: each
    device owns one arena shard (a contiguous block of session rows —
    see `serve.arena`).  Per-session CCM state is tiny and independent,
    so the session axis is the embarrassingly-parallel one; model
    parallelism composes separately (ROADMAP).  Defaults to every alive
    device."""
    n = n_shards if n_shards is not None else jax.device_count()
    if n < 1:
        raise ValueError("session mesh needs at least one device")
    return _mk((n,), ("shards",))


def available_mesh(model_parallel: int = 1):
    """Elastic: build the best mesh from whatever devices are alive."""
    n = jax.device_count()
    nm = model_parallel
    while n % nm:
        nm -= 1
    return _mk((n // nm, nm), ("data", "model"))
