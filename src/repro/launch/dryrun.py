import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices back the production meshes (16x16 single pod /
2x16x16 multi-pod); parameters and inputs are ShapeDtypeStructs (never
allocated). Per cell we record:
  - memory_analysis()  — per-device bytes (fits-on-v5e proof)
  - cost_analysis()    — per-device HLO FLOPs / bytes accessed
  - collective bytes   — parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute result
    shapes; per-device, post-SPMD)
Results go to experiments/dryrun/*.json (resumable; benchmarks/roofline.py
derives the three roofline terms from them).

Usage:
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
"""
import argparse
import json
import re
import sys
import traceback

import jax

from repro.configs.registry import ASSIGNED, get_config
from repro.obs import perf_counter
from repro.launch.mesh import make_dist, make_production_mesh
from repro.launch.specs import SHAPES, build_cell

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\][^\s]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Per-device bytes moved by each collective kind (result shapes)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        ty, kind = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue  # counted at -start
        out[kind] = out.get(kind, 0) + _shape_bytes(ty)
    return out


def _cell_cost(cfg, shape, dist):
    """(flops, bytes, collective_bytes) of one compiled cell variant."""
    cell = build_cell(cfg, shape, dist)
    compiled = cell.fn.lower(*cell.args).compile()
    cost = compiled.cost_analysis()
    colls = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(sum(colls.values())))


VARIANTS = {
    "fsdp": lambda c: c.replace(sharding_strategy="fsdp"),
    "int8kv": lambda c: c.replace(kv_cache_dtype="int8"),
    "noremat": lambda c: c.replace(remat=False),
    "ep": lambda c: c.replace(moe_impl="ep"),
    "merge": lambda c: c.replace(
        ccm=__import__("dataclasses").replace(c.ccm, mode="merge")),
    # CCM compressed serving (paper Eq. 3): bounded cache + memory instead
    # of the full-length KV cache
    "ccmserve": lambda c: c.replace(serve_cache_len=4096),
}


def _apply_variant(cfg, variant):
    if not variant:
        return cfg
    for v in variant.split("+"):
        cfg = VARIANTS[v](cfg)
    return cfg


def calibrated_cost(arch: str, shape: str, dist, variant=None):
    """XLA's cost_analysis counts a while-loop (scan) body ONCE, so scanned
    layer stacks undercount by ~L x. Fit cost = base + b * n_layers from
    reduced-depth compiles and extrapolate to the real depth (hybrid:
    cost = base + b*n_mamba + c*n_attn_sites from three variants).

    Returns dict of corrected per-device (flops, bytes, collective_bytes).
    """
    full = _apply_variant(get_config(arch), variant).replace(
        unroll_layers=True, remat=False)
    L = full.n_layers
    if full.family == "hybrid":
        A = _cell_cost(full.replace(n_layers=2, attn_every=2), shape, dist)
        B = _cell_cost(full.replace(n_layers=4, attn_every=2), shape, dist)
        C = _cell_cost(full.replace(n_layers=3, attn_every=3), shape, dist)
        out = []
        n_sites = L // full.attn_every
        for a, b_, c_ in zip(A, B, C):
            b = c_ - a                 # per-mamba-layer
            c = b_ + a - 2 * c_        # per-attn-site
            base = 2 * a - b_
            out.append(base + b * L + c * n_sites)
        return {"flops": out[0], "bytes": out[1], "collective": out[2]}
    one = _cell_cost(full.replace(
        n_layers=1, n_enc_layers=min(1, full.n_enc_layers)), shape, dist)
    two = _cell_cost(full.replace(
        n_layers=2, n_enc_layers=min(2, full.n_enc_layers)), shape, dist)
    out = []
    for f1, f2 in zip(one, two):
        body = f2 - f1
        out.append(f1 + body * (L - 1))
    return {"flops": out[0], "bytes": out[1], "collective": out[2]}


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             force: bool = False, variant=None):
    tag = f"__{variant}" if variant else ""
    fname = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{tag}.json")
    if os.path.exists(fname) and not force:
        print(f"skip {arch} {shape} {mesh_kind} (cached)")
        return json.load(open(fname))
    t0 = perf_counter()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    dist = make_dist(mesh)
    cfg = _apply_variant(get_config(arch), variant)
    cell = build_cell(cfg, shape, dist)
    lowered = cell.fn.lower(*cell.args)
    t_lower = perf_counter() - t0
    compiled = lowered.compile()
    t_compile = perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    colls = collective_bytes(txt)
    calib = calibrated_cost(arch, shape, dist, variant)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "variant": variant,
        "devices": int(mesh.size),
        "note": cell.note,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals", "utilization")
                 if k in cost} if isinstance(cost, dict) else str(cost),
        "collective_bytes": colls,
        "collective_total": sum(colls.values()),
        "calibrated": calib,   # scan-trip-count-corrected per-device costs
        "n_params": get_config(arch).param_count(),
        "n_params_active": get_config(arch).param_count(active_only=True),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"DONE {arch} {shape} {mesh_kind}: "
          f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
          f"flops={rec['cost'].get('flops') if isinstance(rec['cost'], dict) else '?'} "
          f"coll={rec['collective_total']/1e6:.1f}MB "
          f"peak={(rec['memory']['peak_bytes'] or 0)/1e9:.2f}GB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="'+'-joined cfg variants: fsdp,int8kv,noremat,ep,merge")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                try:
                    run_cell(arch, shape, mk, args.out, force=args.force,
                             variant=args.variant)
                except Exception:
                    failures.append((arch, shape, mk))
                    print(f"FAIL {arch} {shape} {mk}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:", failures)
        sys.exit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
