"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs import (codeqwen15_7b, gemma_2b, llama4_maverick,
                           llama_7b_paper, mamba2_370m, phi35_moe,
                           pixtral_12b, qwen2_05b, smollm_360m, whisper_tiny,
                           zamba2_12b)

_MODULES = {
    "smollm-360m": smollm_360m,
    "codeqwen1.5-7b": codeqwen15_7b,
    "qwen2-0.5b": qwen2_05b,
    "gemma-2b": gemma_2b,
    "zamba2-1.2b": zamba2_12b,
    "whisper-tiny": whisper_tiny,
    "llama4-maverick-400b-a17b": llama4_maverick,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "mamba2-370m": mamba2_370m,
    "pixtral-12b": pixtral_12b,
    "llama-7b": llama_7b_paper,   # the paper's own model (fidelity benches)
}

ASSIGNED = [k for k in _MODULES if k != "llama-7b"]


def get_config(arch: str, smoke: bool = False, **kw):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch '{arch}'; known: {list(_MODULES)}")
    mod = _MODULES[arch]
    return mod.smoke(**kw) if smoke else mod.config(**kw)
