"""llama-7b — the PAPER's own evaluation model (Touvron et al., 2023).
32L d_model=4096 32H (kv=32) d_ff=11008 vocab=32000. Not part of the
assigned 10-arch pool; used by the paper-fidelity benchmarks."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="llama-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab_size=32000, activation="swiglu",
        rope_theta=10000.0,
        train_mode="lora",
        param_dtype="bfloat16",  # frozen base; LoRA moments stay fp32
        ccm=CCMConfig(comp_len=8, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=256, ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
