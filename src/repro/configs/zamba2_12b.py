"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242] 38L d_model=2048, shared attn 32H (kv=32) d_ff=8192,
vocab=32000, ssm_state=64. CCM compresses the shared attention sites' KV;
the Mamba2 state is the arch's native fixed-size memory (DESIGN §5)."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000, activation="swiglu",
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
        attn_every=6,
        train_mode="full",
        ccm=CCMConfig(comp_len=8, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        attn_every=2, ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
