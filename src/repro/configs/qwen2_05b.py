"""qwen2-0.5b [dense] — GQA kv=2, QKV bias, tied embeddings.
[arXiv:2407.10671] 24L d_model=896 14H (kv=2) d_ff=4864 vocab=151936."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab_size=151936, activation="swiglu",
        qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=True,
        train_mode="full",
        ccm=CCMConfig(comp_len=8, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=128,
        vocab_size=256, ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
