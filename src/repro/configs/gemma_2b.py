"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1), scaled embeddings.
[arXiv:2403.08295] 18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", family="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=256000, activation="geglu",
        rope_theta=10000.0, tie_embeddings=True, embed_scale=True,
        train_mode="full",
        ccm=CCMConfig(comp_len=8, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512,
        ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
