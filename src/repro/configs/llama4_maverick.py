"""llama4-maverick-400b-a17b [moe] — MoE 128 experts top-1, early fusion.
[hf:meta-llama/Llama-4-*; unverified] 48L d_model=5120 40H (kv=8)
d_ff=8192 (per expert) vocab=202048. Trains conditional LoRA only (paper
regime — also the only memory-feasible mode at 400B on 256 v5e chips)."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048, activation="swiglu",
        n_experts=128, top_k=1, moe_impl="ragged_tp",
        rope_theta=500_000.0,
        train_mode="lora",
        param_dtype="bfloat16",  # frozen base; LoRA moments stay fp32
        ccm=CCMConfig(comp_len=8, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=96, vocab_size=256, n_experts=8, top_k=1,
        ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
