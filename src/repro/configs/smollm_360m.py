"""smollm-360m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-360M; hf]
32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab_size=49152, activation="swiglu",
        rope_theta=10000.0, tie_embeddings=True,
        train_mode="full",
        ccm=CCMConfig(comp_len=8, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=60, n_heads=3, n_kv_heads=1, d_ff=128,
        vocab_size=256, ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
