"""codeqwen1.5-7b [dense] — qwen1.5 arch, QKV bias. [hf:Qwen/CodeQwen1.5-7B]
32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=13440, vocab_size=92416, activation="swiglu",
        qkv_bias=True, rope_theta=1_000_000.0,
        train_mode="lora",   # paper regime: 7B trains conditional LoRA only
        param_dtype="bfloat16",  # frozen base; LoRA moments stay fp32
        ccm=CCMConfig(comp_len=8, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=256, ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
