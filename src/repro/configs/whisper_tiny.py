"""whisper-tiny [audio] — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356]
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865. LayerNorm/GELU/learned pos.
CCM applies to decoder self-attention (long transcription history)."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec",
        n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab_size=51865, activation="gelu", norm="ln",
        pos_embed="learned", max_pos=65536, frontend="audio",
        train_mode="full",
        ccm=CCMConfig(comp_len=4, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, max_pos=2048,
        ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
