"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060] 48L d_model=1024 vocab=50280 ssm_state=128.

CCM is INAPPLICABLE (no attention KV to compress — DESIGN
§Arch-applicability): the SSD state is the arch's own constant-size
context memory. Implemented without the technique; all shapes lower the
native train/prefill/decode programs."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        n_heads=1, n_kv_heads=1, d_ff=0,
        train_mode="full",
        ccm=CCMConfig(enabled=False, comp_len=2, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=16,
        ccm=CCMConfig(enabled=False, comp_len=2, max_steps=4), **kw)
