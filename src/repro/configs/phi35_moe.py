"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct] 32L d_model=4096 32H (kv=8)
d_ff=6400 (per expert) vocab=32064."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=6400, vocab_size=32064, activation="swiglu",
        n_experts=16, top_k=2, moe_impl="ragged_tp",
        rope_theta=10000.0,
        train_mode="lora",
        param_dtype="bfloat16",  # frozen base; LoRA moments stay fp32
        ccm=CCMConfig(comp_len=8, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=96, vocab_size=256, n_experts=4, top_k=2,
        ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
