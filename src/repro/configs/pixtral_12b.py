"""pixtral-12b [vlm] — ViT frontend STUB + Mistral-NeMo-style decoder.
[hf:mistralai/Pixtral-12B-2409; unverified] 40L d_model=5120 32H (kv=8)
d_ff=14336 vocab=131072. input_specs() provides precomputed patch
embeddings (1024-dim ViT output, projected in-model)."""
from repro.models.config import CCMConfig, ModelConfig


def config(**kw) -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072, activation="swiglu",
        rope_theta=1_000_000_000.0, frontend="vision",
        n_frontend_tokens=1024,
        train_mode="lora",
        param_dtype="bfloat16",  # frozen base; LoRA moments stay fp32
        ccm=CCMConfig(comp_len=8, max_steps=16), **kw)


def smoke(**kw) -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=256, n_frontend_tokens=8,
        ccm=CCMConfig(comp_len=2, max_steps=4), **kw)
