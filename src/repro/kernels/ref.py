"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ccm_attention_ref(q, k, v, q_idx, q_seg, k_idx, k_seg, k_comp, k_valid,
                      scale: float):
    """Dense-mask flash-attention oracle.

    q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D); metadata 1-D int32/bool.
    Mask: (k_idx <= q_idx) & ((k_seg == q_seg) | k_comp) & k_valid.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k).astype(jnp.float32) * scale
    mask = (k_idx[None, :] <= q_idx[:, None]) \
        & ((k_seg[None, :] == q_seg[:, None]) | k_comp[None, :]) \
        & k_valid[None, :]
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows -> zero output (not uniform garbage)
    any_valid = mask.any(axis=-1)[None, None, None, :, None]
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(q.dtype), v)
    out = jnp.where(any_valid, out, 0)
    return out.reshape(B, Hq, Sq, D)


def cond_lora_ref(x, w, a, b, gate, scale: float,
                  bias: Optional[jnp.ndarray] = None):
    """y = x@w (+bias) + gate * ((x@a^T)@b) * scale.

    x (M, K); w (K, N); a (r, K); b (r, N); gate (M,)."""
    y = x @ w
    if bias is not None:
        y = y + bias
    d = ((x @ a.T) @ b) * scale
    return y + d * gate[:, None].astype(y.dtype)


def kv_merge_ref(mem, h, t):
    """Arithmetic-mean memory update: (1 - 1/t) * mem + (1/t) * h."""
    a = (1.0 / t.astype(jnp.float32)).astype(mem.dtype)
    return mem * (1 - a) + h * a


def kv_cummean_ref(h):
    """h (T, ...) -> running means along axis 0 (merge-mode training)."""
    csum = jnp.cumsum(h.astype(jnp.float32), axis=0)
    denom = jnp.arange(1, h.shape[0] + 1, dtype=jnp.float32)
    denom = denom.reshape((-1,) + (1,) * (h.ndim - 1))
    return (csum / denom).astype(h.dtype)


def session_gather_ref(slab, ids):
    """Arena pack: slab (S, R), ids (B,) -> (B, R)."""
    return jnp.take(slab, ids, axis=0)


def session_scatter_ref(slab, ids, rows):
    """Arena unpack: slab with slab[ids] = rows (last write wins on dups)."""
    return slab.at[ids].set(rows)


def ragged_block_write_ref(buf, blk, start, valid_len, axis: int):
    """Oracle for core.masks.ragged_block_write: copy ``blk``'s first
    ``valid_len`` rows into ``buf`` at ``start`` along ``axis``; every
    other position is frozen (no dynamic_update_slice clamp-shift).
    A write overhanging the buffer end keeps only the rows that fit."""
    buf = jnp.asarray(buf)
    # clamp like the implementation's `pos < n` bound: an overhanging
    # valid_len writes only the rows that fit, never shifts earlier ones
    n = max(0, min(int(valid_len), buf.shape[axis] - int(start)))
    idx = [slice(None)] * buf.ndim
    idx[axis] = slice(int(start), int(start) + n)
    src = [slice(None)] * buf.ndim
    src[axis] = slice(0, n)
    return buf.at[tuple(idx)].set(jnp.asarray(blk)[tuple(src)].astype(buf.dtype))
