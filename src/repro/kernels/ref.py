"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def pad_axis(x, mult, axis, fill=0):
    """Pad ``x`` up to a multiple of ``mult`` along ``axis`` (shared by
    the kernel wrappers' block-alignment paths)."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def ccm_attention_ref(q, k, v, q_idx, q_seg, k_idx, k_seg, k_comp, k_valid,
                      scale: float):
    """Dense-mask flash-attention oracle.

    q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D); metadata 1-D int32/bool.
    Mask: (k_idx <= q_idx) & ((k_seg == q_seg) | k_comp) & k_valid.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k).astype(jnp.float32) * scale
    mask = (k_idx[None, :] <= q_idx[:, None]) \
        & ((k_seg[None, :] == q_seg[:, None]) | k_comp[None, :]) \
        & k_valid[None, :]
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows -> zero output (not uniform garbage)
    any_valid = mask.any(axis=-1)[None, None, None, :, None]
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(q.dtype), v)
    out = jnp.where(any_valid, out, 0)
    return out.reshape(B, Hq, Sq, D)


def segmented_attention_ref(q, segs, q_idx, q_seg, scale: float):
    """Oracle for decode_attention.segmented_flash_attention: dense attend
    over the EXPLICIT concatenation of the segments (the very thing the
    kernel never materializes).

    q (B, Sq, Hq, D); each seg a dict of arrays: k/v (B, S, Hkv, D)
    [int8 with k_scale/v_scale (B, S, Hkv)], length () or None,
    idx/seg/comp/valid (S,) metadata or None (memory-like segment:
    idx=-1, seg=0, comp=True), layer () or None (k/v stacked with a
    leading layer axis; that layer is attended).
    """
    ks, vs, idxs, sgs, cps, vls = [], [], [], [], [], []
    for s in segs:
        k, v = s["k"], s["v"]
        ksc, vsc = s.get("k_scale"), s.get("v_scale")
        if s.get("layer") is not None:
            li = s["layer"]
            k, v = k[li], v[li]
            ksc = None if ksc is None else ksc[li]
            vsc = None if vsc is None else vsc[li]
        if ksc is not None:
            k = k.astype(jnp.float32) * ksc[..., None]
            v = v.astype(jnp.float32) * vsc[..., None]
        S = k.shape[1]
        ks.append(k.astype(q.dtype))
        vs.append(v.astype(q.dtype))
        if s.get("idx") is not None:
            idxs.append(jnp.asarray(s["idx"], jnp.int32))
            sgs.append(jnp.asarray(s["seg"], jnp.int32))
            cps.append(jnp.asarray(s["comp"], bool))
            valid = s["valid"] if s.get("valid") is not None \
                else jnp.ones((S,), bool)
        else:
            idxs.append(jnp.full((S,), -1, jnp.int32))
            sgs.append(jnp.zeros((S,), jnp.int32))
            cps.append(jnp.ones((S,), bool))
            valid = jnp.ones((S,), bool)
        if s.get("length") is not None:
            valid = valid & (jnp.arange(S) < s["length"])
        vls.append(valid)
    k = jnp.concatenate(ks, axis=1).transpose(0, 2, 1, 3)
    v = jnp.concatenate(vs, axis=1).transpose(0, 2, 1, 3)
    out = ccm_attention_ref(
        q.transpose(0, 2, 1, 3), k, v,
        jnp.asarray(q_idx, jnp.int32), jnp.asarray(q_seg, jnp.int32),
        jnp.concatenate(idxs), jnp.concatenate(sgs),
        jnp.concatenate(cps), jnp.concatenate(vls), scale)
    return out.transpose(0, 2, 1, 3)


def segmented_attention_lanes_ref(q, segs, q_idx, q_seg, scale: float):
    """Batched oracle for the lane-batched segmented kernel: a plain
    Python loop over lanes, each lane attending its OWN segment slices
    through :func:`segmented_attention_ref`.

    q (N, Sq, Hq, D) with N the lane axis; each seg a dict in the
    *normalized lane schema* of ``segmented_flash_attention``:
    non-layered k/v (N, S, Hkv, D); layered ``lane_major`` k/v
    (N, L, S, Hkv, D) (scales (N, L, S, Hkv)); length/layer () or (N,);
    idx/seg/comp/valid (S,) or (N, S).  q_idx/q_seg (Sq,) or (N, Sq).
    """
    N, Sq = q.shape[:2]
    q_idx = jnp.broadcast_to(jnp.asarray(q_idx, jnp.int32), (N, Sq))
    q_seg = jnp.broadcast_to(jnp.asarray(q_seg, jnp.int32), (N, Sq))

    def lane(x, i):
        x = jnp.asarray(x)
        return x[i] if x.ndim else x

    outs = []
    for i in range(N):
        per = []
        for s in segs:
            layered = s.get("layer") is not None
            d = {"layer": None if s.get("layer") is None
                 else lane(s["layer"], i)}
            for key in ("k", "v", "k_scale", "v_scale"):
                a = s.get(key)
                if a is None:
                    d[key] = None
                elif layered and s.get("lane_major"):
                    d[key] = a[i][:, None]          # (L, S, ..) -> (L,1,S,..)
                elif layered:
                    d[key] = a[:, i:i + 1]
                else:
                    d[key] = a[i:i + 1]
            d["length"] = None if s.get("length") is None \
                else lane(s["length"], i)
            for key in ("idx", "seg", "comp", "valid"):
                a = s.get(key)
                d[key] = None if a is None \
                    else (jnp.asarray(a)[i] if jnp.asarray(a).ndim == 2
                          else jnp.asarray(a))
            per.append(d)
        outs.append(segmented_attention_ref(q[i:i + 1], per, q_idx[i],
                                            q_seg[i], scale))
    return jnp.concatenate(outs, axis=0)


def cond_lora_ref(x, w, a, b, gate, scale: float,
                  bias: Optional[jnp.ndarray] = None):
    """y = x@w (+bias) + gate * ((x@a^T)@b) * scale.

    x (M, K); w (K, N); a (r, K); b (r, N); gate (M,)."""
    y = x @ w
    if bias is not None:
        y = y + bias
    d = ((x @ a.T) @ b) * scale
    return y + d * gate[:, None].astype(y.dtype)


def kv_merge_ref(mem, h, t):
    """Arithmetic-mean memory update: (1 - 1/t) * mem + (1/t) * h."""
    a = (1.0 / t.astype(jnp.float32)).astype(mem.dtype)
    return mem * (1 - a) + h * a


def recompress_memory_ref(x, slots: int, comp_len: int, group: int):
    """Oracle for core.memory.recompress_memory (one of k/v at a time):
    collapse every ``group`` consecutive filled <COMP> groups of
    ``x`` (L, B, M, Hkv, hd) into their position-aligned arithmetic
    mean; groups at or past ceil(slots/group) are zeroed.  ``slots`` is
    a CONCRETE fill count (the jit path handles it dynamically)."""
    L, B, M, H, D = x.shape
    G = M // comp_len
    xg = x.reshape(L, B, G, comp_len, H, D)
    out = jnp.zeros_like(xg)
    new_g = -(-slots // group)
    for j in range(new_g):
        lo, hi = j * group, min((j + 1) * group, slots)
        mean = jnp.mean(xg[:, :, lo:hi].astype(jnp.float32), axis=2)
        out = out.at[:, :, j].set(mean.astype(x.dtype))
    return out.reshape(L, B, M, H, D)


def kv_cummean_ref(h):
    """h (T, ...) -> running means along axis 0 (merge-mode training)."""
    csum = jnp.cumsum(h.astype(jnp.float32), axis=0)
    denom = jnp.arange(1, h.shape[0] + 1, dtype=jnp.float32)
    denom = denom.reshape((-1,) + (1,) * (h.ndim - 1))
    return (csum / denom).astype(h.dtype)


def session_gather_ref(slab, ids):
    """Arena pack: slab (S, R), ids (B,) -> (B, R)."""
    return jnp.take(slab, ids, axis=0)


def session_scatter_ref(slab, ids, rows):
    """Arena unpack: slab with slab[ids] = rows (last write wins on dups)."""
    return slab.at[ids].set(rows)


def ragged_block_write_ref(buf, blk, start, valid_len, axis: int):
    """Oracle for core.masks.ragged_block_write: copy ``blk``'s first
    ``valid_len`` rows into ``buf`` at ``start`` along ``axis``; every
    other position is frozen (no dynamic_update_slice clamp-shift).
    A write overhanging the buffer end keeps only the rows that fit."""
    buf = jnp.asarray(buf)
    # clamp like the implementation's `pos < n` bound: an overhanging
    # valid_len writes only the rows that fit, never shifts earlier ones
    n = max(0, min(int(valid_len), buf.shape[axis] - int(start)))
    idx = [slice(None)] * buf.ndim
    idx[axis] = slice(int(start), int(start) + n)
    src = [slice(None)] * buf.ndim
    src[axis] = slice(0, n)
    return buf.at[tuple(idx)].set(jnp.asarray(blk)[tuple(src)].astype(buf.dtype))
