"""Flash attention with the CCM block-sparse mask — Pallas TPU kernel.

Mask predicate per (q, k): causal AND (same-segment OR key-is-<COMP>) AND
key-valid — evaluated per (block_q x block_k) tile from i32 metadata vectors
(VMEM-resident, SMEM-sized). Tiles that cannot contain any visible key
(k-segment strictly ahead of every q-segment and no <COMP>/memory key in the
tile, or entirely a-causal) are *skipped*: since <COMP> keys are a few
percent of the sequence, the off-diagonal cost collapses to the comp columns
and the effective FLOPs approach block-diagonal + t*m gather columns
(DESIGN §3/§4).

Layouts: q (B, Hq, Sq, D), k/v (B, Hkv, Sk, D) — GQA is handled by the
k/v index_map (h // group), no repetition is materialized.

Grid: (B, Hq, nq, nk); the k dimension is 'arbitrary' (sequential) with
running-softmax state in VMEM scratch — the canonical TPU flash pattern.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qidx_ref, qseg_ref, kidx_ref, kseg_ref, kcomp_ref, kval_ref,
            q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qidx = qidx_ref[0, :]                       # (bq,) i32
    qseg = qseg_ref[0, :]
    kidx = kidx_ref[0, :]                       # (bk,) i32
    kseg = kseg_ref[0, :]
    kcomp = kcomp_ref[0, :]                     # i32 {0,1}
    kval = kval_ref[0, :]

    # ---- tile-level visibility precheck (block sparsity) ----
    causal_possible = jnp.min(kidx) <= jnp.max(qidx)
    has_comp = jnp.max(kcomp * kval) > 0
    seg_overlap = (jnp.min(kseg) <= jnp.max(qseg)) & \
                  (jnp.max(kseg) >= jnp.min(qseg))
    visible = causal_possible & (has_comp | seg_overlap)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)      # (bk, D)
        v = v_ref[0, 0]                          # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        mask = (kidx[None, :] <= qidx[:, None]) \
            & ((kseg[None, :] == qseg[:, None]) | (kcomp[None, :] > 0)) \
            & (kval[None, :] > 0)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] \
            + jax.lax.dot_general(p, v.astype(jnp.float32),
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new
        l_ref[:, 0] = l_new

    @pl.when(ik == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[:, 0], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def ccm_flash_attention(q, k, v, q_idx, q_seg, k_idx, k_seg, k_comp, k_valid,
                        scale: float, block_q: int = 128, block_k: int = 128,
                        interpret: Optional[bool] = None):
    """q (B,Hq,Sq,D); k/v (B,Hkv,Sk,D); metadata i32 (Sq,)/(Sk,).

    Sq/Sk must be multiples of block_q/block_k (ops.py pads).
    ``interpret=None`` backend-selects like ops.py: compiled on TPU,
    Pallas interpreter elsewhere — direct callers no longer silently run
    the interpreter on TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = Sq // block_q, Sk // block_k

    def im_q(b, h, iq, ik):
        return (b, h, iq, 0)

    def im_kv(b, h, iq, ik):
        return (b, h // G, ik, 0)

    def im_qmeta(b, h, iq, ik):
        return (0, iq)

    def im_kmeta(b, h, iq, ik):
        return (0, ik)

    grid = (B, Hq, nq, nk)
    kernel = functools.partial(_kernel, scale=scale, nk=nk)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    except AttributeError:  # older jax
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), im_qmeta),
            pl.BlockSpec((1, block_q), im_qmeta),
            pl.BlockSpec((1, block_k), im_kmeta),
            pl.BlockSpec((1, block_k), im_kmeta),
            pl.BlockSpec((1, block_k), im_kmeta),
            pl.BlockSpec((1, block_k), im_kmeta),
            pl.BlockSpec((1, 1, block_q, D), im_q),
            pl.BlockSpec((1, 1, block_k, D), im_kv),
            pl.BlockSpec((1, 1, block_k, D), im_kv),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), im_q),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=cparams,
        interpret=interpret,
    )(q_idx[None, :], q_seg[None, :], k_idx[None, :], k_seg[None, :],
      k_comp[None, :], k_valid[None, :], q, k, v)
