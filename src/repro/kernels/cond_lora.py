"""Fused conditional-LoRA matmul — Pallas TPU kernel.

y = x @ W + gate * ((x @ A^T) @ B) * scale, gate in {0,1} per row
(1 at <COMP> tokens). Both matmuls and the gate are fused in one VMEM pass:
the rank-r intermediate (block_m x r) lives entirely in scratch, the base
GEMM accumulates in fp32, and the delta is applied at the final k-step —
no separate LoRA kernel launch, no gather of <COMP> rows (DESIGN §3).

Grid (nm, nn, nk): k sequential ('arbitrary') with fp32 accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, g_ref, o_ref,
            acc_ref, xa_ref, *, scale: float, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    xa_ref[...] += jax.lax.dot_general(
        x, a_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _final():
        delta = jax.lax.dot_general(
            xa_ref[...], b_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        gate = g_ref[...].astype(jnp.float32)      # (bm, 1)
        o_ref[...] = (acc_ref[...] + delta * gate).astype(o_ref.dtype)


def cond_lora_matmul(x, w, a, b, gate, scale: float,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 512, interpret: bool = True):
    """x (M, K); w (K, N); a (r, K); b (r, N); gate (M,). Returns (M, N).

    M/N/K must be multiples of the block sizes (ops.py pads).
    """
    M, K = x.shape
    N = w.shape[1]
    r = a.shape[0]
    nm, nn, nk = M // block_m, N // block_n, K // block_k
    kernel = functools.partial(_kernel, scale=scale, nk=nk)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except AttributeError:
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda im, i_n, ik: (im, ik)),
            pl.BlockSpec((block_k, block_n), lambda im, i_n, ik: (ik, i_n)),
            pl.BlockSpec((r, block_k), lambda im, i_n, ik: (0, ik)),
            pl.BlockSpec((r, block_n), lambda im, i_n, ik: (0, i_n)),
            pl.BlockSpec((block_m, 1), lambda im, i_n, ik: (im, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda im, i_n, ik: (im, i_n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, r), jnp.float32),
        ],
        compiler_params=cparams,
        interpret=interpret,
    )(x, w, a, b, gate[:, None])
