"""jit'd public wrappers around the Pallas kernels: layout adaptation,
padding to block multiples, backend selection (TPU compiled / CPU interpret).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ccm_attention as _attn
from repro.kernels import cond_lora as _lora
from repro.kernels import decode_attention as _dattn
from repro.kernels import kv_merge as _merge
from repro.kernels import ref as _ref
from repro.kernels import session_gather as _sess


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


_pad_axis = _ref.pad_axis


@functools.partial(jax.jit, static_argnames=("scale", "block_q", "block_k",
                                             "interpret"))
def ccm_attention(q, k, v, q_info, k_info, scale: float,
                  block_q: int = 128, block_k: int = 128,
                  interpret: Optional[bool] = None):
    """Drop-in for repro.models.attention.attend: q (B,Sq,Hq,D), k/v
    (B,Sk,Hkv,D), KeyInfo metadata. Returns (B,Sq,Hq,D)."""
    interpret = _use_interpret() if interpret is None else interpret
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    qt = _pad_axis(q.transpose(0, 2, 1, 3), block_q, 2)
    kt = _pad_axis(k.transpose(0, 2, 1, 3), block_k, 2)
    vt = _pad_axis(v.transpose(0, 2, 1, 3), block_k, 2)
    big = 2 ** 30
    q_idx = _pad_axis(q_info.idx.astype(jnp.int32), block_q, 0, fill=-big)
    q_seg = _pad_axis(q_info.seg.astype(jnp.int32), block_q, 0, fill=-3)
    k_idx = _pad_axis(k_info.idx.astype(jnp.int32), block_k, 0, fill=big)
    k_seg = _pad_axis(k_info.seg.astype(jnp.int32), block_k, 0, fill=-2)
    k_comp = _pad_axis(k_info.comp.astype(jnp.int32), block_k, 0, fill=0)
    valid = k_info.valid if k_info.valid is not None else \
        jnp.ones((Sk,), bool)
    k_val = _pad_axis(valid.astype(jnp.int32), block_k, 0, fill=0)
    out = _attn.ccm_flash_attention(
        qt, kt, vt, q_idx, q_seg, k_idx, k_seg, k_comp, k_val, scale,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


def segmented_attention(q, segs, q_idx, q_seg, scale: float,
                        block_q: int = 128, block_k: int = 128,
                        interpret: Optional[bool] = None):
    """Drop-in for repro.models.attention.attend_segments (impl='pallas'):
    q (B, Sq, Hq, D) over in-place KV segments — see
    decode_attention.segmented_flash_attention for the seg-dict schema.

    B is the LANE axis: segment ``length``/``layer`` may be per-lane
    ``(B,)`` vectors (metadata ``(B, S)``, q_idx/q_seg ``(B, Sq)``), and
    a per-lane stacked cache uses the lane-major ``(B, L, S, Hkv, D)``
    layout with ``lane_major=True`` — each lane then tile-skips past its
    own valid prefix (the serve engine's vmapped-session route).  Scalars
    / 1-D metadata broadcast to all lanes (the single-session layout).

    Not jitted here: hot paths call it from inside already-jitted steps
    and the segment list's None-structure is part of the trace."""
    return _dattn.segmented_flash_attention(
        q, segs, q_idx, q_seg, scale, block_q=block_q, block_k=block_k,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "block_k", "interpret"))
def cond_lora(x, w, a, b, gate, scale: float, block_m: int = 128,
              block_n: int = 128, block_k: int = 512,
              interpret: Optional[bool] = None):
    """x (M,K) @ w (K,N) + gate*(x@a.T@b)*scale — fused."""
    interpret = _use_interpret() if interpret is None else interpret
    M, K = x.shape
    N = w.shape[1]
    bm = min(block_m, M) if M % block_m else block_m
    xp = _pad_axis(_pad_axis(x, block_m, 0), block_k, 1)
    wp = _pad_axis(_pad_axis(w, block_k, 0), block_n, 1)
    ap = _pad_axis(a, block_k, 1)
    bp = _pad_axis(b, block_n, 1)
    gp = _pad_axis(gate.astype(x.dtype), block_m, 0)
    out = _lora.cond_lora_matmul(xp, wp, ap, bp, gp, scale,
                                 block_m=block_m, block_n=block_n,
                                 block_k=block_k, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("interpret",))
def session_gather(slab, ids, interpret: Optional[bool] = None):
    """Arena pack: slab (S, ...), ids (B,) int32 -> (B, ...) rows.

    TPU -> compiled Pallas DMA gather; elsewhere the pure-jnp ref (unless
    ``interpret=True`` forces the Pallas interpreter for validation).
    """
    if interpret is None and not _use_interpret():
        interpret = False
    if interpret is None:
        return _ref.session_gather_ref(slab, ids)
    S = slab.shape[0]
    flat = slab.reshape(S, -1)
    out = _sess.session_gather(flat, ids, interpret=interpret)
    return out.reshape((ids.shape[0],) + slab.shape[1:])


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def session_scatter(slab, ids, rows, interpret: Optional[bool] = None):
    """Arena unpack: slab (S, ...) with slab[ids] = rows (B, ...), in place
    (the slab argument is donated on both backends)."""
    if interpret is None and not _use_interpret():
        interpret = False
    if interpret is None:
        return _ref.session_scatter_ref(slab, ids, rows)
    S = slab.shape[0]
    out = _sess.session_scatter(slab.reshape(S, -1), ids,
                                rows.reshape(rows.shape[0], -1),
                                interpret=interpret)
    return out.reshape(slab.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_merge_update(mem, h, a, interpret: Optional[bool] = None):
    interpret = _use_interpret() if interpret is None else interpret
    return _merge.kv_merge_update(mem, h, a, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_cummean(h, interpret: Optional[bool] = None):
    interpret = _use_interpret() if interpret is None else interpret
    T = h.shape[0]
    flat = h.reshape(T, -1)
    out = _merge.kv_cummean(flat, interpret=interpret)
    return out.reshape(h.shape)
