"""Fused segmented decode attention — Pallas TPU kernel.

A small q block attends an ordered list of KV *segments* —
[mem | cache(:length) | self] — each read IN PLACE from its own refs.
Nothing is ever concatenated: the grid's sequential k dimension walks the
segments' k-blocks back to back and a running softmax (m, l, acc) in VMEM
scratch combines them, exactly like flash-decoding's split-softmax merge
(Infini-attention fuses compressive memory + local attention in the same
two-segment form; this kernel generalizes to any static segment list).

Per-segment valid-prefix lengths arrive via scalar prefetch and gate a
tile-level skip: a k-block whose start lies past ``length`` costs nothing,
so decode work scales with ``cache.length`` rounded up to ``block_k`` —
not with the cache's allocated capacity.  int8 segments are dequantized
tile-wise in-kernel from their ``k_scale``/``v_scale`` refs (the fp
full-cache dequant copy of the concat path disappears).

The leading grid axis is the *lane* axis (a serve batch of independent
sessions, or a plain batch): the scalar-prefetch table is 2-D,
``(lanes, 2 * n_segments)`` holding ``[lens | layer ids]`` PER LANE, and
both the in-kernel skip predicate and the layered index maps read row
``program_id(0)``.  Each lane therefore skips past its *own* valid
prefix — under the serve engine's vmapped session steps this is what
keeps decode cost proportional to per-lane cache occupancy instead of
lowering to a batch-wide ``select`` (see ``models.attention``'s
``custom_vmap`` route).  Per-lane layered segments (each lane brings its
own stacked cache) use the lane-major layout ``(lanes, L, S, H, D)``
(``lane_major=True``); a layered segment shared across an inner batch
keeps the model-native layer-major ``(L, B, S, H, D)``.

Layouts are the model's native (B, S, H, D) — segments are consumed where
they live; no per-step transpose of a large cache.  Block shapes are
(1, bk, 1, D), i.e. strided row DMA per head; revisit sublane packing if
a real-TPU profile shows the DMA bound (this container validates via
interpret).

Mask predicate per (q, k), identical to models.attention.mask_from_info:
  causal AND (same-segment OR key-is-<COMP>) AND key-valid AND pos<length
with memory-like segments (no metadata refs) reducing to pos < length.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import pad_axis as _pad_axis

NEG_INF = -1e30


class SegDesc(NamedTuple):
    """Static per-segment layout inside the fused grid."""
    off: int          # first grid index along the k dimension
    nk: int           # number of k-blocks
    bk: int           # k-block width
    quantized: bool   # int8 k/v with fp32 scale refs
    has_info: bool    # per-token idx/seg/comp/valid metadata refs follow
    layered: bool     # k/v carry a layer axis, indexed by the
                      # scalar-prefetched per-lane layer id (stacked-state)
    lane_major: bool  # layered layout is (lanes, L, S, ...) — each lane
                      # owns its stack — vs layer-major (L, B, S, ...)
    n_refs: int       # tensor+meta refs this segment contributes


def _desc(off: int, S: int, bk: int, quantized: bool, has_info: bool,
          layered: bool, lane_major: bool) -> SegDesc:
    nk = pl.cdiv(S, bk)
    n = 2 + (2 if quantized else 0) + (4 if has_info else 0)
    return SegDesc(off, nk, bk, quantized, has_info, layered, lane_major, n)


def _kernel(descs, scale, nk_total,
            lens_ref, qidx_ref, qseg_ref, q_ref, *rest):
    n_in = sum(d.n_refs for d in descs)
    o_ref = rest[n_in]
    m_ref, l_ref, acc_ref = rest[n_in + 1:]
    b = pl.program_id(0)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ptr = 0
    for si, d in enumerate(descs):
        refs = rest[ptr:ptr + d.n_refs]
        ptr += d.n_refs
        k_ref, v_ref = refs[0], refs[1]
        ks_ref, vs_ref = (refs[2], refs[3]) if d.quantized else (None, None)
        meta = refs[2 + (2 if d.quantized else 0):]
        start = (ik - d.off) * d.bk
        in_seg = (ik >= d.off) & (ik < d.off + d.nk)
        seg_len = lens_ref[b, si]                   # THIS lane's [lens | ids]
        visible = in_seg & (start < seg_len)
        if d.has_info:
            # tile-level CCM visibility precheck (block sparsity): skip
            # tiles that cannot contain a visible key for any q row
            kidx, kseg, kcomp, kval = (r[0, :] for r in meta)
            qidx = qidx_ref[0, :]
            qseg = qseg_ref[0, :]
            causal_possible = jnp.min(kidx) <= jnp.max(qidx)
            has_comp = jnp.max(kcomp * kval) > 0
            seg_overlap = (jnp.min(kseg) <= jnp.max(qseg)) & \
                          (jnp.max(kseg) >= jnp.min(qseg))
            visible = visible & causal_possible & (has_comp | seg_overlap)

        @pl.when(visible)
        def _fold(d=d, k_ref=k_ref, v_ref=v_ref, ks_ref=ks_ref,
                  vs_ref=vs_ref, meta=meta, start=start, seg_len=seg_len):
            q = q_ref[0, :, 0, :].astype(jnp.float32)        # (bq, D)
            if d.layered:
                k, v = k_ref[0, 0, :, 0, :], v_ref[0, 0, :, 0, :]
            else:
                k, v = k_ref[0, :, 0, :], v_ref[0, :, 0, :]
            if d.quantized:   # tile-wise in-kernel dequant
                ks = ks_ref[0, 0, :, 0] if d.layered else ks_ref[0, :, 0]
                vs = vs_ref[0, 0, :, 0] if d.layered else vs_ref[0, :, 0]
                k = k.astype(jnp.float32) * ks[:, None]
                v = v.astype(jnp.float32) * vs[:, None]
            else:
                k = k.astype(jnp.float32)
                v = v.astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # (bq, bk)
            pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = pos < seg_len
            if d.has_info:
                kidx, kseg, kcomp, kval = (r[0, :] for r in meta)
                qidx = qidx_ref[0, :]
                qseg = qseg_ref[0, :]
                mask = mask & (kidx[None, :] <= qidx[:, None]) \
                    & ((kseg[None, :] == qseg[:, None])
                       | (kcomp[None, :] > 0)) \
                    & (kval[None, :] > 0)
            s = jnp.where(mask, s, NEG_INF)
            m_prev = m_ref[:, 0]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
            p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
            alpha = jnp.exp(m_prev - m_new)
            l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
            acc_ref[...] = acc_ref[...] * alpha[:, None] \
                + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
            m_ref[:, 0] = m_new

    @pl.when(ik == nk_total - 1)
    def _final():
        l = jnp.maximum(l_ref[:, 0], 1e-37)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def segmented_flash_attention(q, segs: Sequence[Dict[str, Any]],
                              q_idx, q_seg, scale: float,
                              block_q: int = 128, block_k: int = 128,
                              interpret: Optional[bool] = None):
    """q (B, Sq, Hq, D) — B is the lane axis (independent serve lanes, or
    a plain batch).  Each seg a dict of arrays:

      k/v (B, S, Hkv, D) [int8 allowed with k_scale/v_scale (B, S, Hkv)],
      length () or (B,) int32, or None (fully valid) — PER-LANE valid
      prefix when (B,): each lane's k-block loop skips past its own,
      idx/seg/comp/valid (S,) or (B, S) metadata, or None (memory-like
      segment: always-visible keys),
      layer () or (B,) int32, or None — when set, k/v (and scales) carry
      a layer axis and blocks are DMA'd straight out of that layer of
      the stacked state (no layer-slice copy).  Layout is layer-major
      (L, B, S, ...) by default; ``lane_major=True`` marks the per-lane
      stacked form (B, L, S, ...) produced by the serve engine's arena
      gather (lane axis outermost).

    Returns (B, Sq, Hq, D).  Sq and every S are padded to block multiples
    here; hot-path callers keep capacities block-aligned so this is free.
    The scalar-prefetch table is (B, 2 * n_segments) int32 —
    ``[valid lengths | layer ids]`` per lane — read by both the in-kernel
    tile-skip predicate and the layered index maps at row
    ``program_id(0)``, which is what makes the skip truly per-lane.
    ``q_idx``/``q_seg`` are (Sq,) shared or (B, Sq) per-lane.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, Hq, D = q.shape
    Hkv = segs[0]["k"].shape[-2]
    G = Hq // Hkv
    big = 2 ** 30

    def lanes(x):
        """Broadcast shared 1-D metadata to the (B, S) per-lane form."""
        x = jnp.asarray(x)
        if x.ndim == 1:
            x = jnp.broadcast_to(x, (B,) + x.shape)
        return x

    bq = min(block_q, max(Sq, 8))
    qp = _pad_axis(q, bq, 1)
    nq = qp.shape[1] // bq
    qi = _pad_axis(lanes(jnp.asarray(q_idx, jnp.int32)), bq, 1, fill=-big)
    qs = _pad_axis(lanes(jnp.asarray(q_seg, jnp.int32)), bq, 1, fill=-3)

    descs: List[SegDesc] = []
    ns = len(segs)
    lens, layers, inputs, in_specs = [], [], [], []
    off = 0
    for si, s in enumerate(segs):
        layered = s.get("layer") is not None
        lane_major = layered and bool(s.get("lane_major"))
        tok_ax = 2 if layered else 1
        S = s["k"].shape[tok_ax]
        quant = s.get("k_scale") is not None
        has_info = s.get("idx") is not None
        bk = min(block_k, max(S, 8))
        d = _desc(off, S, bk, quant, has_info, layered, lane_major)
        descs.append(d)
        off += d.nk
        lens.append(jnp.broadcast_to(
            jnp.asarray(S if s.get("length") is None else s["length"],
                        jnp.int32), (B,)))
        layers.append(jnp.broadcast_to(
            jnp.zeros((), jnp.int32) if not layered
            else jnp.asarray(s["layer"], jnp.int32), (B,)))

        def im_kv(b, h, iq, ik, lens_ref, d=d, si=si):
            blk = jnp.clip(ik - d.off, 0, d.nk - 1)
            if d.lane_major:
                return (b, lens_ref[b, ns + si], blk, h // G, 0)
            if d.layered:
                return (lens_ref[b, ns + si], b, blk, h // G, 0)
            return (b, blk, h // G, 0)

        def im_sc(b, h, iq, ik, lens_ref, d=d, si=si):
            blk = jnp.clip(ik - d.off, 0, d.nk - 1)
            if d.lane_major:
                return (b, lens_ref[b, ns + si], blk, h // G)
            if d.layered:
                return (lens_ref[b, ns + si], b, blk, h // G)
            return (b, blk, h // G)

        def im_meta(b, h, iq, ik, lens_ref, d=d):
            return (b, jnp.clip(ik - d.off, 0, d.nk - 1))

        kv_block = (1, 1, bk, 1, D) if layered else (1, bk, 1, D)
        sc_block = (1, 1, bk, 1) if layered else (1, bk, 1)
        inputs += [_pad_axis(s["k"], bk, tok_ax),
                   _pad_axis(s["v"], bk, tok_ax)]
        in_specs += [pl.BlockSpec(kv_block, im_kv)] * 2
        if quant:
            inputs += [_pad_axis(s["k_scale"], bk, tok_ax),
                       _pad_axis(s["v_scale"], bk, tok_ax)]
            in_specs += [pl.BlockSpec(sc_block, im_sc)] * 2
        if has_info:
            valid = s.get("valid")
            if valid is None:
                valid = jnp.ones((S,), bool)
            inputs += [
                _pad_axis(lanes(jnp.asarray(s["idx"], jnp.int32)), bk, 1,
                          fill=big),
                _pad_axis(lanes(jnp.asarray(s["seg"], jnp.int32)), bk, 1,
                          fill=-2),
                _pad_axis(lanes(s["comp"]).astype(jnp.int32), bk, 1),
                _pad_axis(lanes(valid).astype(jnp.int32), bk, 1)]
            in_specs += [pl.BlockSpec((1, bk), im_meta)] * 4

    nk_total = off

    def im_q(b, h, iq, ik, lens_ref):
        return (b, iq, h, 0)

    def im_qmeta(b, h, iq, ik, lens_ref):
        return (b, iq)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, nq, nk_total),
        in_specs=[pl.BlockSpec((1, bq), im_qmeta),
                  pl.BlockSpec((1, bq), im_qmeta),
                  pl.BlockSpec((1, bq, 1, D), im_q)] + in_specs,
        out_specs=pl.BlockSpec((1, bq, 1, D), im_q),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)])
    kernel = functools.partial(_kernel, tuple(descs), scale, nk_total)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    except AttributeError:  # older jax
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        compiler_params=cparams,
        interpret=interpret,
    )(jnp.stack(lens + layers, axis=1), qi, qs, qp, *inputs)
    return out[:, :Sq]
