"""Session-arena pack/unpack kernels — Pallas TPU.

The serving arena stores per-session state as slabs with a leading slot
axis (S, R).  Building a scheduler batch is a row gather (pack) and the
post-step writeback is a row scatter (unpack).  Both are pure DMA: the
scalar-prefetched slot ids drive the BlockSpec index maps, so each grid
step copies one (1, block_cols) tile HBM->VMEM->HBM with no compute.

  session_gather  — rows = slab[ids]          (B, R) out of (S, R)
  session_scatter — slab[ids] = rows, in place via input/output aliasing
                    (donated slab buffer; untouched rows are not copied)

Duplicate ids in ``session_scatter`` (the scheduler's padding rows all
point at the arena's scratch slot) write the same row more than once;
any serialization order is acceptable since pad rows carry scratch data.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(ids_ref, src_ref, dst_ref):
    del ids_ref
    dst_ref[...] = src_ref[...]


def session_gather(slab, ids, block_cols: int = 1024,
                   interpret: bool = True):
    """slab (S, R), ids (B,) int32 -> (B, R) packed rows."""
    S, R = slab.shape
    B = ids.shape[0]
    bc = min(block_cols, R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, pl.cdiv(R, bc)),
        in_specs=[pl.BlockSpec((1, bc), lambda b, c, ids_ref:
                               (ids_ref[b], c))],
        out_specs=pl.BlockSpec((1, bc), lambda b, c, ids_ref: (b, c)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R), slab.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), slab)


def _scatter_kernel(ids_ref, rows_ref, slab_ref, out_ref):
    del ids_ref, slab_ref
    out_ref[...] = rows_ref[...]


def session_scatter(slab, ids, rows, block_cols: int = 1024,
                    interpret: bool = True):
    """slab (S, R), ids (B,), rows (B, R) -> slab with slab[ids] = rows.

    The slab operand is aliased to the output, so only the B touched rows
    move; everything else stays in the donated buffer.
    """
    S, R = slab.shape
    B = ids.shape[0]
    bc = min(block_cols, R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, pl.cdiv(R, bc)),
        in_specs=[
            pl.BlockSpec((1, bc), lambda b, c, ids_ref: (b, c)),
            pl.BlockSpec((1, bc), lambda b, c, ids_ref: (ids_ref[b], c)),
        ],
        out_specs=pl.BlockSpec((1, bc), lambda b, c, ids_ref:
                               (ids_ref[b], c)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, R), slab.dtype),
        input_output_aliases={2: 0},   # slab (after the prefetched ids) -> out
        interpret=interpret,
    )(ids.astype(jnp.int32), rows, slab)
