"""CCM-merge memory-update kernels — Pallas TPU.

  kv_merge_update  — online update Mem(t) = (1-a_t) Mem(t-1) + a_t h(t),
                     a_t a runtime scalar (1/t arithmetic mean or EMA).
                     Elementwise, bandwidth-bound; blocked rows in VMEM.
  kv_cummean       — parallel-training form: running means over the time
                     axis, one sequential grid dim carrying the fp32
                     accumulator (associative-scan analogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _merge_kernel(a_ref, mem_ref, h_ref, o_ref):
    a = a_ref[0, 0]
    mem = mem_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    o_ref[...] = ((1.0 - a) * mem + a * h).astype(o_ref.dtype)


def kv_merge_update(mem, h, a, block_rows: int = 256,
                    interpret: bool = True):
    """mem/h: any shape (flattened to (R, C)); a: scalar fp32 weight."""
    shape = mem.shape
    C = shape[-1]
    R = mem.size // C
    memf = mem.reshape(R, C)
    hf = h.reshape(R, C)
    br = min(block_rows, R)
    nr = pl.cdiv(R, br)
    out = pl.pallas_call(
        _merge_kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ir: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((br, C), lambda ir: (ir, 0)),
            pl.BlockSpec((br, C), lambda ir: (ir, 0)),
        ],
        out_specs=pl.BlockSpec((br, C), lambda ir: (ir, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), mem.dtype),
        interpret=interpret,
    )(jnp.asarray(a, jnp.float32).reshape(1, 1), memf, hf)
    return out.reshape(shape)


def _cummean_kernel(h_ref, o_ref, acc_ref, *, T: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += h_ref[...].astype(jnp.float32)
    denom = (it + 1).astype(jnp.float32)
    o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def kv_cummean(h, block_cols: int = 512, interpret: bool = True):
    """h (T, R) -> running means along axis 0."""
    T, R = h.shape
    bc = min(block_cols, R)
    ncol = pl.cdiv(R, bc)
    kernel = functools.partial(_cummean_kernel, T=T)
    try:
        cparams = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    except AttributeError:
        cparams = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=(ncol, T),
        in_specs=[pl.BlockSpec((1, bc), lambda ic, it: (it, ic))],
        out_specs=pl.BlockSpec((1, bc), lambda ic, it: (it, ic)),
        out_shape=jax.ShapeDtypeStruct((T, R), h.dtype),
        scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)],
        compiler_params=cparams,
        interpret=interpret,
    )(h)
