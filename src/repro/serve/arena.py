"""Fixed-shape device memory arena for per-session serving state.

Every session's state (``OnlineState`` for ingest/query sessions,
``StreamState`` for streaming ones) is one *row* of a set of preallocated
slabs: each pytree leaf of the single-session template (inner batch dim
1) becomes a slab with a leading row axis.  Slot ids are handed out from
a free-list; nothing is ever reallocated per session.

REFCOUNTED ROWS: a live row is held by one or more logical references —
a resident session, a forked child sharing its parent's state
copy-on-write, a prefix-cache entry pinning a compressed shared prefix.
``alloc`` hands a row out at refcount 1, ``incref`` adds a holder, and
``free`` DROPS ONE REFERENCE — the row only returns to its shard's
free-list when the count hits zero.  Shared rows are read-only by
contract: every scatter entry point (``unpack`` / ``mark_dirty`` /
``reset_slots``) refuses target rows with refcount > 1, because a write
through one holder would silently corrupt every sibling — writers must
break sharing first (clone the row into a fresh slot and decref the
shared one; `SessionManager.activate_batch` does this with one jitted
clone per shard, `launch.serve.cow_clone_slots`).  The consistency
probe asserts the refcount bookkeeping (every live row counted >= 1,
refs tracked only for live rows) and reports any recorded write-guard
violation.

SHARDING (session-axis partitioning): the arena is split into
``n_shards`` equal contiguous row blocks along the leading axis — one
block per device when the engine runs mesh-native.  Shard ``s`` owns
rows ``[s * (slots_per_shard + 1), (s + 1) * (slots_per_shard + 1))``:
``slots_per_shard`` data rows handed out by the shard's OWN free-list,
plus one reserved *scratch* row at the block's end (``pad_slot_of(s)``).
Slot ids stay GLOBAL row indices, so every jitted gather/scatter —
``pack``/``unpack`` here, the engine's fused step, the pressure
controller's recompression — works verbatim on a sharded arena; when the
slabs carry a `NamedSharding` over the row axis the block boundaries
coincide with device boundaries and shard-local batches never touch
another device's rows.  ``n_shards=1`` reproduces the original layout
exactly (``n_slots + 1`` rows, scratch at ``n_slots``).

``pack`` gathers any set of active slot ids into a contiguous batch for
the vmapped session ops (`launch.serve.session_vmap`), and ``unpack``
scatters the updated batch back — both one jitted gather/scatter over
donated buffers (`kernels.ops.session_gather` / `session_scatter`,
Pallas DMA on TPU).  The engine's hot path fuses all three into one
program via `launch.serve.make_arena_step` (or, sharded, one
`shard_map` program via `make_sharded_arena_step`); pack/unpack here
serve the offload/restore and single-slot paths.

The scheduler pads a short batch up to its bucket size with the owning
shard's scratch row, so padding lanes gather scratch, compute garbage,
and scatter the garbage back to scratch — shapes stay bucketed with no
semantic effect and pad traffic stays shard-local.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import inference as I
from repro.core import streaming as STR
from repro.kernels import ops
from repro.models.config import ModelConfig


class ArenaFull(RuntimeError):
    """No free session slots (caller should offload or shed load).

    Internal to the serve package: `ServeEngine` admission control
    guarantees this never escapes `submit`/`run` (batches are capped at
    evictable capacity — see `serve.admission`); it can still surface
    from direct `SessionArena`/`SessionManager` misuse."""


# Shared across every arena instance: jax.jit caches by function
# identity, so per-instance `jax.jit(...)` wrappers would recompile the
# same gather/scatter for every arena built (one per fuzzed trace in
# tests/simulation.py, one per engine elsewhere).
@jax.jit
def _pack_slabs(slabs, ids):
    return jax.tree.map(lambda slab: ops.session_gather(slab, ids), slabs)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_slabs(slabs, ids, state):
    return jax.tree.map(
        lambda slab, rows: ops.session_scatter(slab, ids, rows),
        slabs, state)


def online_template(cfg: ModelConfig, cache_len: int,
                    mem_slots: Optional[int] = None):
    """Single-session (inner batch 1) OnlineState shape tree."""
    return jax.eval_shape(
        functools.partial(I.init_online_state, cfg, 1, cache_len, mem_slots))


def stream_template(cfg: ModelConfig):
    """Single-session (inner batch 1) StreamState shape tree."""
    return jax.eval_shape(functools.partial(STR.init_stream_state, cfg, 1))


class SessionArena:
    """Slab allocator + jitted pack/unpack for one state template.

    ``n_shards``: partition the slots into equal contiguous row blocks,
    each with its own free-list and scratch row (see module docstring).
    ``place``: optional callable applied to the freshly-zeroed slabs
    (e.g. ``lambda t: jax.device_put(t, NamedSharding(mesh, P("shards")))``
    to pin one row block per device)."""

    def __init__(self, template: Any, n_slots: int, n_shards: int = 1,
                 place: Optional[Callable] = None):
        if n_slots < 1:
            raise ValueError("arena needs at least one slot")
        if n_shards < 1:
            raise ValueError("arena needs at least one shard")
        if n_slots % n_shards:
            raise ValueError(
                f"n_slots ({n_slots}) must divide evenly into n_shards "
                f"({n_shards}) so every device owns an equal block")
        self.template = template
        self.n_slots = n_slots
        self.n_shards = n_shards
        self.slots_per_shard = n_slots // n_shards
        self._stride = self.slots_per_shard + 1   # rows per shard block
        self.n_rows = n_shards * self._stride
        self.slabs = jax.tree.map(
            lambda s: jnp.zeros((self.n_rows,) + s.shape, s.dtype), template)
        # placed (mesh-sharded) slabs span several devices: callers that
        # stage data for pack/unpack must NOT commit it to one device
        # (committed single-device operands conflict with the sharded
        # slab inside the jitted gather/scatter) — see
        # `SessionManager._restore_batch`
        self.placed = place is not None
        if place is not None:
            self.slabs = place(self.slabs)
        self._free = [deque(self.shard_slots(s)) for s in range(n_shards)]
        self._live = set()
        self._refs = {}               # slot -> reference count (live only)
        self._dirty = set()           # slots that have ever been written
        self._violations = []         # recorded shared-row write attempts
        self._pack = _pack_slabs
        self._scatter = _scatter_slabs

    # -- allocation ----------------------------------------------------
    @classmethod
    def for_online(cls, cfg: ModelConfig, n_slots: int, cache_len: int,
                   mem_slots: Optional[int] = None, n_shards: int = 1,
                   place: Optional[Callable] = None) -> "SessionArena":
        return cls(online_template(cfg, cache_len, mem_slots), n_slots,
                   n_shards, place)

    @classmethod
    def for_stream(cls, cfg: ModelConfig, n_slots: int, n_shards: int = 1,
                   place: Optional[Callable] = None) -> "SessionArena":
        return cls(stream_template(cfg), n_slots, n_shards, place)

    # -- shard geometry ------------------------------------------------
    def shard_slots(self, shard: int) -> range:
        """The data rows shard ``shard`` owns (its scratch row excluded)."""
        base = shard * self._stride
        return range(base, base + self.slots_per_shard)

    def pad_slot_of(self, shard: int) -> int:
        """The shard's reserved scratch row (batch padding lanes)."""
        return shard * self._stride + self.slots_per_shard

    @property
    def pad_slot(self) -> int:
        """Shard 0's scratch row — with ``n_shards == 1`` this is row
        ``n_slots``, the original single-arena scratch slot."""
        return self.pad_slot_of(0)

    def shard_of(self, slot: int) -> int:
        """Owning shard of a global slot/row id."""
        return slot // self._stride

    def local_row(self, slot: int) -> int:
        """Row index within the owning shard's block (what a device sees
        under `shard_map`: ``slots_per_shard`` is every shard's local
        scratch row)."""
        return slot % self._stride

    @property
    def n_free(self) -> int:
        return sum(len(f) for f in self._free)

    def shard_free(self, shard: int) -> int:
        return len(self._free[shard])

    @property
    def occupancy(self) -> float:
        return 1.0 - self.n_free / self.n_slots

    def alloc(self, shard: int = 0) -> int:
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range "
                             f"[0, {self.n_shards})")
        if not self._free[shard]:
            raise ArenaFull(
                f"all {self.slots_per_shard} slots of shard {shard} in use")
        slot = self._free[shard].popleft()
        self._live.add(slot)
        self._refs[slot] = 1
        return slot

    def incref(self, slot: int) -> int:
        """Add one logical reference to a live row (fork / prefix-cache
        attach); returns the new count.  The row will survive ``free``
        calls until every holder has released it."""
        if slot not in self._live:
            raise ValueError(f"slot {slot} is not allocated")
        self._refs[slot] += 1
        return self._refs[slot]

    def refcount(self, slot: int) -> int:
        """Current reference count (0 for rows not allocated)."""
        return self._refs.get(slot, 0)

    def shared(self, slot: int) -> bool:
        """Whether the row has more than one holder (writes forbidden
        until sharing is broken)."""
        return self._refs.get(slot, 0) > 1

    def shared_slots(self) -> List[int]:
        """Live rows currently held by more than one reference."""
        return sorted(s for s, n in self._refs.items() if n > 1)

    def free(self, slot: int) -> int:
        """Drop ONE reference; the row returns to its shard's free-list
        only when no holder remains.  Returns the remaining count."""
        if slot not in self._live:
            raise ValueError(f"slot {slot} is not allocated")
        self._refs[slot] -= 1
        left = self._refs[slot]
        if left == 0:
            del self._refs[slot]
            self._live.remove(slot)
            self._free[self.shard_of(slot)].append(slot)
        return left

    def _guard_writes(self, slot_ids) -> None:
        """Reject any scatter targeting a shared row: one holder writing
        through a row with refcount > 1 would corrupt every sibling.
        Violations are recorded (surfaced by `consistency_errors`) and
        raised — callers must COW-break first."""
        bad = sorted({int(s) for s in slot_ids
                      if self._refs.get(int(s), 0) > 1})
        if bad:
            msg = (f"write targets shared rows {bad} (refcount > 1): "
                   "break sharing (cow_clone_slots) before any scatter")
            self._violations.append(msg)
            raise RuntimeError(msg)

    def metrics_sample(self) -> dict:
        """Point-in-time occupancy sample for gauge export (the engine's
        ``_sample_gauges`` reads this on every metrics snapshot).  The
        ``shards`` list carries the same sample per shard block."""
        return {"n_slots": self.n_slots, "live": self.n_slots - self.n_free,
                "free": self.n_free, "occupancy": self.occupancy,
                "shared": len(self.shared_slots()),
                "shards": [
                    {"n_slots": self.slots_per_shard,
                     "live": self.slots_per_shard - len(self._free[s]),
                     "free": len(self._free[s]),
                     "occupancy": 1.0 - (len(self._free[s])
                                         / self.slots_per_shard)}
                    for s in range(self.n_shards)]}

    def consistency_errors(self) -> list:
        """Free-list / live-set invariant violations (empty = healthy):
        no slot both free and live, no duplicates in any shard's free
        list, every data row of every shard accounted exactly once, and
        no slot parked on the wrong shard's free-list.  The serve
        property suite asserts this after every simulated event
        (double-free / leak / cross-shard corruption detection)."""
        errs = []
        all_free = []
        for shard in range(self.n_shards):
            free = list(self._free[shard])
            owned = set(self.shard_slots(shard))
            stray = [s for s in free if s not in owned]
            if stray:
                errs.append(f"shard {shard} free list holds foreign "
                            f"slots: {sorted(stray)}")
            all_free.extend(free)
        if len(all_free) != len(set(all_free)):
            errs.append(f"duplicate slots in free lists: "
                        f"{sorted(all_free)}")
        overlap = set(all_free) & self._live
        if overlap:
            errs.append(f"slots both free and live: {sorted(overlap)}")
        data_rows = set()
        for shard in range(self.n_shards):
            data_rows.update(self.shard_slots(shard))
        missing = data_rows - set(all_free) - self._live
        if missing:
            errs.append(f"slots leaked (neither free nor live): "
                        f"{sorted(missing)}")
        bogus = (set(all_free) | self._live) - data_rows
        if bogus:
            errs.append(f"out-of-range slots tracked: {sorted(bogus)}")
        unref = self._live - set(self._refs)
        if unref:
            errs.append(f"live slots with no refcount: {sorted(unref)}")
        ghost = set(self._refs) - self._live
        if ghost:
            errs.append(f"refcounts tracked for dead slots: "
                        f"{sorted(ghost)}")
        nonpos = sorted(s for s, n in self._refs.items() if n < 1)
        if nonpos:
            errs.append(f"non-positive refcounts: {nonpos}")
        errs.extend(f"shared-row write attempted: {v}"
                    for v in self._violations)
        return errs

    # -- batched pack/unpack -------------------------------------------
    def pack(self, slot_ids: Sequence[int]):
        """Gather slots into a batch: leaves (B,) + template shape."""
        ids = jnp.asarray(slot_ids, jnp.int32)
        return self._pack(self.slabs, ids)

    def unpack(self, slot_ids: Sequence[int], state) -> None:
        """Scatter an updated batch back (donates slabs + batch)."""
        self._guard_writes(slot_ids)
        ids = jnp.asarray(slot_ids, jnp.int32)
        self._dirty.update(int(i) for i in slot_ids)
        self.slabs = self._scatter(self.slabs, ids, state)

    def mark_dirty(self, slot_ids: Sequence[int]) -> None:
        """Record external writes (the engine's fused step updates
        ``slabs`` directly without going through ``unpack``)."""
        self._guard_writes(slot_ids)
        self._dirty.update(int(i) for i in slot_ids)

    # -- single-slot access (offload/restore path) ---------------------
    def read_slot(self, slot: int):
        """One session's state (template shape, no batch axis)."""
        return jax.tree.map(lambda slab: slab[slot], self.slabs)

    def write_slot(self, slot: int, state) -> None:
        """Write one session's state (template shape) into a slot."""
        batched = jax.tree.map(lambda x: jnp.asarray(x)[None], state)
        self.unpack([slot], batched)

    def reset_slots(self, slot_ids: Sequence[int]) -> None:
        """Zero slots (fresh sessions) — never-written slots are already
        zero from construction and are skipped; the rest are cleared with
        one batched scatter, padded to a bucketed size (extra lanes hit
        the scratch row) so the scatter only ever compiles per bucket."""
        from repro.launch.specs import batch_bucket
        stale = [s for s in slot_ids if s in self._dirty]
        if not stale:
            return
        # bucket for the common case; fall back to the exact count when
        # it exceeds the largest bucket (pad_slot may repeat — harmless)
        n = max(batch_bucket(len(stale)), len(stale))
        ids = stale + [self.pad_slot] * (n - len(stale))
        zeros = jax.tree.map(
            lambda s: jnp.zeros((n,) + s.shape, s.dtype), self.template)
        self.unpack(ids, zeros)
        self._dirty.difference_update(stale)

    def reset_slot(self, slot: int) -> None:
        """Zero a slot (fresh session without a host-side init tree)."""
        self.reset_slots([slot])
