"""Fixed-shape device memory arena for per-session serving state.

Every session's state (``OnlineState`` for ingest/query sessions,
``StreamState`` for streaming ones) is one *row* of a set of preallocated
slabs: each pytree leaf of the single-session template (inner batch dim
1) becomes a slab with a leading ``(n_slots + 1,)`` axis.  Slot ids are
handed out from a free-list; nothing is ever reallocated per session.

``pack`` gathers any set of active slot ids into a contiguous batch for
the vmapped session ops (`launch.serve.session_vmap`), and ``unpack``
scatters the updated batch back — both one jitted gather/scatter over
donated buffers (`kernels.ops.session_gather` / `session_scatter`,
Pallas DMA on TPU).  The engine's hot path fuses all three into one
program via `launch.serve.make_arena_step`; pack/unpack here serve the
offload/restore and single-slot paths.

Row ``n_slots`` is a reserved *scratch* slot: the scheduler pads a
short batch up to its bucket size with ``pad_slot`` ids, so padding
lanes gather scratch, compute garbage, and scatter the garbage back to
scratch — shapes stay bucketed with no semantic effect.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import inference as I
from repro.core import streaming as STR
from repro.kernels import ops
from repro.models.config import ModelConfig


class ArenaFull(RuntimeError):
    """No free session slots (caller should offload or shed load).

    Internal to the serve package: `ServeEngine` admission control
    guarantees this never escapes `submit`/`run` (batches are capped at
    evictable capacity — see `serve.admission`); it can still surface
    from direct `SessionArena`/`SessionManager` misuse."""


# Shared across every arena instance: jax.jit caches by function
# identity, so per-instance `jax.jit(...)` wrappers would recompile the
# same gather/scatter for every arena built (one per fuzzed trace in
# tests/simulation.py, one per engine elsewhere).
@jax.jit
def _pack_slabs(slabs, ids):
    return jax.tree.map(lambda slab: ops.session_gather(slab, ids), slabs)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_slabs(slabs, ids, state):
    return jax.tree.map(
        lambda slab, rows: ops.session_scatter(slab, ids, rows),
        slabs, state)


def online_template(cfg: ModelConfig, cache_len: int,
                    mem_slots: Optional[int] = None):
    """Single-session (inner batch 1) OnlineState shape tree."""
    return jax.eval_shape(
        functools.partial(I.init_online_state, cfg, 1, cache_len, mem_slots))


def stream_template(cfg: ModelConfig):
    """Single-session (inner batch 1) StreamState shape tree."""
    return jax.eval_shape(functools.partial(STR.init_stream_state, cfg, 1))


class SessionArena:
    """Slab allocator + jitted pack/unpack for one state template."""

    def __init__(self, template: Any, n_slots: int):
        if n_slots < 1:
            raise ValueError("arena needs at least one slot")
        self.template = template
        self.n_slots = n_slots
        self.pad_slot = n_slots          # reserved scratch row
        self.slabs = jax.tree.map(
            lambda s: jnp.zeros((n_slots + 1,) + s.shape, s.dtype), template)
        self._free = deque(range(n_slots))
        self._live = set()
        self._dirty = set()           # slots that have ever been written
        self._pack = _pack_slabs
        self._scatter = _scatter_slabs

    # -- allocation ----------------------------------------------------
    @classmethod
    def for_online(cls, cfg: ModelConfig, n_slots: int, cache_len: int,
                   mem_slots: Optional[int] = None) -> "SessionArena":
        return cls(online_template(cfg, cache_len, mem_slots), n_slots)

    @classmethod
    def for_stream(cls, cfg: ModelConfig, n_slots: int) -> "SessionArena":
        return cls(stream_template(cfg), n_slots)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    def alloc(self) -> int:
        if not self._free:
            raise ArenaFull(f"all {self.n_slots} slots in use")
        slot = self._free.popleft()
        self._live.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._live:
            raise ValueError(f"slot {slot} is not allocated")
        self._live.remove(slot)
        self._free.append(slot)

    def metrics_sample(self) -> dict:
        """Point-in-time occupancy sample for gauge export (the engine's
        ``_sample_gauges`` reads this on every metrics snapshot)."""
        return {"n_slots": self.n_slots, "live": self.n_slots - self.n_free,
                "free": self.n_free, "occupancy": self.occupancy}

    def consistency_errors(self) -> list:
        """Free-list / live-set invariant violations (empty = healthy):
        no slot both free and live, no duplicates in the free list, and
        every slot accounted exactly once.  The serve property suite
        asserts this after every simulated event (double-free / leak
        detection)."""
        errs = []
        free = list(self._free)
        if len(free) != len(set(free)):
            errs.append(f"duplicate slots in free list: {sorted(free)}")
        overlap = set(free) & self._live
        if overlap:
            errs.append(f"slots both free and live: {sorted(overlap)}")
        missing = set(range(self.n_slots)) - set(free) - self._live
        if missing:
            errs.append(f"slots leaked (neither free nor live): "
                        f"{sorted(missing)}")
        bogus = (set(free) | self._live) - set(range(self.n_slots))
        if bogus:
            errs.append(f"out-of-range slots tracked: {sorted(bogus)}")
        return errs

    # -- batched pack/unpack -------------------------------------------
    def pack(self, slot_ids: Sequence[int]):
        """Gather slots into a batch: leaves (B,) + template shape."""
        ids = jnp.asarray(slot_ids, jnp.int32)
        return self._pack(self.slabs, ids)

    def unpack(self, slot_ids: Sequence[int], state) -> None:
        """Scatter an updated batch back (donates slabs + batch)."""
        ids = jnp.asarray(slot_ids, jnp.int32)
        self._dirty.update(int(i) for i in slot_ids)
        self.slabs = self._scatter(self.slabs, ids, state)

    def mark_dirty(self, slot_ids: Sequence[int]) -> None:
        """Record external writes (the engine's fused step updates
        ``slabs`` directly without going through ``unpack``)."""
        self._dirty.update(int(i) for i in slot_ids)

    # -- single-slot access (offload/restore path) ---------------------
    def read_slot(self, slot: int):
        """One session's state (template shape, no batch axis)."""
        return jax.tree.map(lambda slab: slab[slot], self.slabs)

    def write_slot(self, slot: int, state) -> None:
        """Write one session's state (template shape) into a slot."""
        batched = jax.tree.map(lambda x: jnp.asarray(x)[None], state)
        self.unpack([slot], batched)

    def reset_slots(self, slot_ids: Sequence[int]) -> None:
        """Zero slots (fresh sessions) — never-written slots are already
        zero from construction and are skipped; the rest are cleared with
        one batched scatter, padded to a bucketed size (extra lanes hit
        the scratch row) so the scatter only ever compiles per bucket."""
        from repro.launch.specs import batch_bucket
        stale = [s for s in slot_ids if s in self._dirty]
        if not stale:
            return
        # bucket for the common case; fall back to the exact count when
        # it exceeds the largest bucket (pad_slot may repeat — harmless)
        n = max(batch_bucket(len(stale)), len(stale))
        ids = stale + [self.pad_slot] * (n - len(stale))
        zeros = jax.tree.map(
            lambda s: jnp.zeros((n,) + s.shape, s.dtype), self.template)
        self.unpack(ids, zeros)
        self._dirty.difference_update(stale)

    def reset_slot(self, slot: int) -> None:
        """Zero a slot (fresh session without a host-side init tree)."""
        self.reset_slots([slot])
