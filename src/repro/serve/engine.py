"""Multi-tenant serve engine: admission -> scheduler -> arena -> steps.

Drives the whole subsystem: submits pass ADMISSION CONTROL
(`serve.admission`: bounded ingress, per-tenant quotas, overflow
policy) and return a structured ``Admitted | Queued | Shed`` verdict —
`ArenaFull` never reaches callers; batches are capped at evictable
capacity by construction (scheduler ``max_batch`` <= ``max_resident``
per kind, per-tenant batch lanes <= the tenant's resident quota).
`run` drains the queue batch by batch — activate the batch's sessions
(batched LRU restore/offload via `SessionManager`, tenant-quota-aware),
then one fused jitted program per batch (`launch.serve.make_arena_step`)
gathers their arena rows, runs the vmapped op, and scatters the updated
rows back, fulfilling the requests.  After every popped batch the
backpressure backlog is pumped, so blocked submits drain as soon as
queue capacity frees.

OBSERVABILITY (`repro.obs`, see docs/OBSERVABILITY.md): every counter
the engine keeps — per-op requests/tokens/padding waste, dispatch
seconds, compile churn, admission verdicts, offload transfer
bytes/seconds — lives in one `MetricsRegistry`, exported as JSON
(`metrics_snapshot`) or Prometheus text (`metrics_prometheus`); the
legacy ``stats`` dicts remain as thin read-only views.  Pass
``obs=Observability.tracing()`` for per-request lifecycle spans
(submit -> verdict -> queue wait -> execute -> terminal), queue-wait /
end-to-end latency histograms, and a bounded flight recorder the
engine dumps to stderr when an exception escapes a drain.  The default
`NullRecorder` makes every trace hook a no-op — cache state and
verdicts are bit-exact with a recorder-enabled run on the same
traffic, and all timing stays outside jit (device work is timed around
dispatch with ``block_until_ready``), so compiled programs never see
the difference.

Online sessions (ingest/query over ``OnlineState``) and streaming
sessions (``stream`` over ``StreamState``) live in separate arenas since
their state templates differ; ``stream_slots=0`` skips the second arena.

SHARDED SERVING (``n_shards > 1`` / ``mesh=``): the arenas partition
into one shard per device along the SESSION axis (`serve.arena`) and
sessions are placed on a shard at creation (least-loaded, deterministic)
and pinned there for life.  The drain pops one `ShardedBatch` per
iteration — a same-shape sub-batch per shard — and runs all shards as
ONE fused program: under `shard_map` on a ``mesh``
(`launch.serve.make_sharded_arena_step`, zero cross-device collectives),
or as a per-shard loop over the single-device step when no mesh is given
(the control-plane-identical path the simulation harness and the
bit-exactness tests drive).  Offload/restore stage host transfers per
shard, pressure levers act on sessions wherever they live (all state
row ids are global), and occupancy/resident/queue/shed metrics gain a
``shard`` label.  Session state NEVER moves between shards —
``serve_cross_shard_moves_total`` exists to prove it stays 0.
"""
from __future__ import annotations

import collections
import dataclasses
import sys
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import serve as SRV
from repro.launch.specs import (SERVE_BATCH_BUCKETS, SERVE_TOKEN_BUCKETS,
                                derive_token_buckets, token_bucket)
from repro.models.config import ModelConfig
from repro.obs import Observability
from repro.serve.admission import (AdmissionController, TenantQuota,
                                   Verdict)
from repro.serve.arena import SessionArena
from repro.serve.prefix import PrefixCache
from repro.serve.pressure import MemoryPressureController, PressurePolicy
from repro.serve.scheduler import (Request, ScheduledBatch, Scheduler,
                                   ShardedBatch)
from repro.serve.session import (CloseResult, OffloadCostModel,
                                 OffloadResult, SessionManager)

_OP_STATE = {"ingest": "online", "query": "online", "stream": "stream"}
_STAT_KEYS = ("requests", "tokens", "pad_lanes", "pad_tokens", "lanes",
              "batches")


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 64,
                 cache_len: int = 256, mem_slots: Optional[int] = None,
                 max_resident: Optional[int] = None, stream_slots: int = 0,
                 stream_max_resident: Optional[int] = None,
                 batch_buckets: Sequence[int] = SERVE_BATCH_BUCKETS,
                 token_buckets="auto", aging: Optional[int] = 32,
                 admission_policy: str = "block",
                 max_queued_tokens: Optional[int] = None,
                 max_backlog: Optional[int] = None,
                 tenant_quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None,
                 batched_offload: bool = True,
                 async_offload: bool = False,
                 offload_cost_model: Optional[OffloadCostModel] = None,
                 pressure_policy: Optional[PressurePolicy] = None,
                 prefix_cache: bool = True,
                 prefix_cache_entries: int = 64,
                 step_factory: Optional[Callable] = None,
                 n_shards: int = 1, mesh=None,
                 edf: bool = True,
                 bucket_policy: str = "static",
                 bucket_refit_interval: int = 256,
                 bucket_max: int = 8,
                 bucket_compile_cost_tokens: float = 128.0,
                 length_history: int = 4096,
                 obs: Optional[Observability] = None):
        """``token_buckets``: ragged-batching token buckets ("auto" picks
        `launch.specs.SERVE_TOKEN_BUCKETS` for attention archs and exact-
        length grouping for SSM/hybrid; None forces exact lengths).
        ``aging``: scheduler starvation knob — a waiting request's
        effective priority improves by one per ``aging`` popped batches.

        Admission (`serve.admission`): ``admission_policy`` is one of
        ``block`` / ``shed-lowest-priority`` / ``reject-new``;
        ``max_queued_tokens`` bounds the global queue
        (``max_backlog`` bounds the block-policy backlog entries);
        ``tenant_quotas`` / ``default_quota`` bound resident slots and
        queued tokens per tenant.  Defaults are unbounded — every
        submit returns ``Admitted``.

        Offload (`serve.session`): ``batched_offload`` moves k victims
        per transfer, ``async_offload`` overlaps the device->host copy
        with scheduling, ``offload_cost_model`` drops state and replays
        request history when that is cheaper than the round trip.

        Prefix dedup (`serve.prefix`): with ``prefix_cache=True`` (the
        default), `create_session`'s ``prefix_tokens=`` consults a
        content-addressed cache of compressed prefixes — a hit attaches
        the new session to the cached row (refcount share, no
        recompression); a miss compresses once and pins the result for
        the next session.  ``prefix_cache_entries`` bounds the LRU.

        Forks: `fork_session(parent, child)` queues a zero-token
        ``fork`` request on the PARENT session (program order picks the
        snapshot point); when it executes, the child shares the
        parent's arena row copy-on-write — the first write through
        either of them clones the row (`serve.session` COW break).

        Pressure (`serve.pressure`): a ``pressure_policy`` turns on the
        unified memory-pressure controller over the ONLINE arena — a
        logical token budget (``capacity_tokens``) enforced at
        admission, with deficits walked down the recompress -> offload
        -> shed degradation ladder instead of shedding outright; the
        drain loop additionally relieves past the high watermark.  See
        docs/SERVING.md "Memory pressure".

        ``step_factory(cfg, op, masked)``: override the fused arena step
        builder (default `launch.serve.make_arena_step`); the serve
        simulation harness injects a control-plane-only null step.

        Sharding: ``n_shards > 1`` partitions both arenas into that
        many session shards (``n_slots`` and ``stream_slots`` must
        divide evenly) and switches the drain to sharded pops; with a
        ``mesh`` (1-D over axis ``"shards"``,
        `launch.mesh.make_session_mesh`) the slabs are placed one shard
        per device and the hot path runs under `shard_map`
        (``n_shards`` defaults to the mesh size).  Without a mesh the
        sharded engine runs each shard's sub-batch through the
        single-device step — same control plane, same results; that is
        also the only sharded mode compatible with a custom
        ``step_factory``.

        Deadlines (docs/SERVING.md "Deadlines and SLOs"): every submit
        accepts ``deadline=`` (absolute seconds on the engine clock —
        ``now()``); a tenant quota's ``slo_seconds`` derives one when
        the caller passes none.  ``edf`` orders deadline-carrying
        requests earliest-deadline-first WITHIN their effective-priority
        class (`Scheduler.effective_key`); with no deadlines submitted
        the schedule is bit-identical either way.  Shed and pressure
        levers prefer already-late work (`Scheduler.shed_preference_key`,
        `PressurePolicy.offload_late_sessions`); outcomes land in the
        ``serve_deadline_*`` metric families.

        Bucket derivation: ``bucket_policy="derived"`` refits the token-
        bucket ladder to the observed request-length distribution every
        ``bucket_refit_interval`` submissions
        (`launch.specs.derive_token_buckets`: pad-waste vs compile-churn
        DP at ``bucket_compile_cost_tokens`` per NEW shape, fed by the
        compile-churn counter's seen shapes, never pad-regressing vs the
        static ladder on the fitted window of the last
        ``length_history`` lengths).  The default ``"static"`` keeps the
        configured ladder untouched; `derived_token_buckets()` previews
        a fit either way.

        ``obs``: `repro.obs.Observability` bundle.  Default = live
        metrics registry + monotonic clock + `NullRecorder` (no traces,
        no flight buffer, bit-exact with pre-obs behavior).  Pass
        ``Observability.tracing()`` for request spans and latency
        histograms, or inject a `ManualClock` for deterministic
        timestamps (the simulation harness does both)."""
        self.params = params
        self.cfg = cfg
        self.cache_len = cache_len
        if token_buckets == "auto":
            token_buckets = SERVE_TOKEN_BUCKETS if SRV.ragged_family(cfg) \
                else None
        elif token_buckets is not None and not SRV.ragged_family(cfg):
            raise ValueError(
                f"token buckets need masked lanes, unsupported for "
                f"family {cfg.family!r}")
        self.ragged = token_buckets is not None
        self._token_buckets = token_buckets
        if bucket_policy not in ("static", "derived"):
            raise ValueError(f"unknown bucket_policy {bucket_policy!r}; "
                             "pick 'static' or 'derived'")
        if bucket_policy == "derived" and not self.ragged:
            raise ValueError("bucket_policy='derived' needs ragged "
                             "batching (token_buckets is None)")
        self.bucket_policy = bucket_policy
        self._bucket_refit_interval = int(bucket_refit_interval)
        self._bucket_max = int(bucket_max)
        self._bucket_compile_cost = float(bucket_compile_cost_tokens)
        # the fit baseline: the configured static ladder (the derived
        # ladder is clamped to never pad WORSE than this on its window)
        self._static_token_buckets = tuple(sorted(token_buckets)) \
            if token_buckets is not None else None
        self._len_history: collections.deque = collections.deque(
            maxlen=int(length_history))
        self._len_seen = 0             # lengths ever recorded
        self._len_at_refit = 0         # _len_seen at the last refit
        self._step_factory = step_factory or SRV.make_arena_step
        if mesh is not None:
            if "shards" not in getattr(mesh, "axis_names", ()):
                raise ValueError(
                    "serve mesh needs a 'shards' axis "
                    "(launch.mesh.make_session_mesh)")
            mesh_n = int(mesh.shape["shards"])
            if n_shards not in (1, mesh_n):
                raise ValueError(
                    f"n_shards ({n_shards}) disagrees with the mesh's "
                    f"'shards' axis size ({mesh_n})")
            n_shards = mesh_n
            if step_factory is not None:
                raise ValueError(
                    "mesh execution uses make_sharded_arena_step; a "
                    "custom step_factory only composes with the "
                    "loop-over-shards mode (omit mesh)")
        self.n_shards = n_shards
        self.mesh = mesh
        place = None
        if mesh is not None:
            from repro.distributed import sharding as SH
            place = lambda slabs: jax.device_put(        # noqa: E731
                slabs, SH.named(mesh, SH.arena_pspecs(slabs)))
        self.obs = obs if obs is not None else Observability()
        self._build_metrics()
        mgr_kw = dict(batched_offload=batched_offload,
                      async_offload=async_offload,
                      cost_model=offload_cost_model,
                      resident_quota_of=self._resident_quota_of,
                      pack_buckets=batch_buckets,
                      obs=self.obs)
        self._mgr: Dict[str, SessionManager] = {
            "online": SessionManager(
                SessionArena.for_online(cfg, n_slots, cache_len, mem_slots,
                                        n_shards=n_shards, place=place),
                max_resident, replay_fn=self._make_replay("online"),
                **mgr_kw),
        }
        if stream_slots:
            c = cfg.ccm
            if c.stream_sink + c.stream_chunk > c.stream_window:
                # stream_step raises this at trace time — mid-drain,
                # after batches were popped; fail at construction instead
                raise ValueError(
                    f"stream_sink ({c.stream_sink}) + stream_chunk "
                    f"({c.stream_chunk}) exceeds stream_window "
                    f"({c.stream_window})")
            self._mgr["stream"] = SessionManager(
                SessionArena.for_stream(cfg, stream_slots,
                                        n_shards=n_shards, place=place),
                stream_max_resident, replay_fn=self._make_replay("stream"),
                **mgr_kw)
        self.prefix_cache: Optional[PrefixCache] = None
        if prefix_cache:
            self.prefix_cache = PrefixCache(
                self._mgr["online"].arena,
                max_entries=prefix_cache_entries, obs=self.obs)
            # activation-scarcity hook: a starved shard reclaims a
            # cache-only prefix row before evicting any live session
            self._mgr["online"].cache_release = \
                self.prefix_cache.release_one
            self._mgr["online"].cache_unpin = \
                self.prefix_cache.unpin_slot
        # prefix-miss bookkeeping: sid -> the in-flight ingest request
        # whose execution should pin the session's row into the cache
        # (and the prefix tokens that key it)
        self._prefix_req: Dict[str, Request] = {}
        self._prefix_toks: Dict[str, np.ndarray] = {}
        self._pending_forks: set = set()   # child sids reserved by
        #                                    queued fork requests
        # derived-bucket refit gating: a refit landing between a sharded
        # pop's sub-batches would pad them to different ladders — the
        # swap is deferred to the next pop boundary
        self._popping = False
        self._refit_pending = False
        caps = {op: self._mgr[kind].max_resident
                for op, kind in _OP_STATE.items() if kind in self._mgr}
        # sharded-pop caps: a pop must fit one activate_batch call —
        # per shard no more lanes than the shard's slots, and in total
        # no more than the arena's resident budget
        self._per_shard_cap = {
            op: min(self._mgr[kind].max_resident,
                    self._mgr[kind].arena.slots_per_shard)
            for op, kind in _OP_STATE.items() if kind in self._mgr}
        self._max_total = dict(caps)
        # a stream op must never pad past the eviction quantum — one
        # eviction per step keeps the window bounded (stream_step guard)
        self.scheduler = Scheduler(
            batch_buckets, max_batch=caps, token_buckets=token_buckets,
            max_token_len={"stream": cfg.ccm.stream_chunk}, aging=aging,
            metrics=self.obs.registry, edf=edf, clock=self.obs.clock)
        # the budget is scoped to the ONLINE arena (memory + KV cache —
        # the states the ladder's levers act on); merge mode pins every
        # session at one group, so only concat memories can recompress
        self._max_mem_groups = 1 if cfg.ccm.mode == "merge" else \
            (mem_slots if mem_slots is not None else cfg.ccm.mem_slots)
        self.pressure: Optional[MemoryPressureController] = None
        if pressure_policy is not None:
            self.pressure = MemoryPressureController(
                pressure_policy,
                sessions_fn=lambda: list(
                    self._mgr["online"].sessions.values()),
                footprint_fn=self._session_footprint,
                queued_tokens_fn=lambda: self.admission.queued_tokens(),
                has_queued_fn=self._has_pending_work,
                recompress_fn=self._recompress_session,
                offload_fn=lambda sid:
                    self._mgr["online"].offload_batch([sid])[0],
                unsalvageable_fn=self._all_pending_late,
                obs=self.obs)
        self.admission = AdmissionController(
            self.scheduler, policy=admission_policy,
            max_queued_tokens=max_queued_tokens, quotas=tenant_quotas,
            default_quota=default_quota, on_shed=self._on_shed,
            max_backlog=max_backlog, metrics=self.obs.registry,
            pressure=self.pressure)
        self._steps = {}               # (op, masked) -> jitted fn
        self._sharded_steps = {}       # (op, masked) -> shard_map'd fn
        self._seen_shapes = set()      # (kind, lanes, token_len, masked)
        self._kind: Dict[str, str] = {}   # sid -> 'online' | 'stream'
        self._shard: Dict[str, int] = {}  # sid -> owning arena shard
        self._tenant: Dict[str, str] = {}  # sid -> tenant
        self._cached: Dict[str, int] = {}  # sid -> KV-cache tokens used
        self._undelivered = []         # [(requests, device out)] per batch

    def _build_metrics(self) -> None:
        reg = self.obs.registry
        self._m = {
            "requests": reg.counter(
                "serve_requests_total",
                "real requests served, per op kind", labels=("kind",)),
            "tokens": reg.counter(
                "serve_tokens_total",
                "real (valid) tokens served, per op kind",
                labels=("kind",)),
            "pad_lanes": reg.counter(
                "serve_pad_lanes_total",
                "scratch lanes added to reach a batch bucket",
                labels=("kind",)),
            "pad_tokens": reg.counter(
                "serve_pad_tokens_total",
                "token-bucket padding waste on real lanes",
                labels=("kind",)),
            "lanes": reg.counter(
                "serve_lanes_total", "total batch lanes dispatched",
                labels=("kind",)),
            "batches": reg.counter(
                "serve_batches_total", "batches dispatched",
                labels=("kind",)),
            "dispatch_s": reg.counter(
                "serve_dispatch_seconds_total",
                "host time spent dispatching fused steps (async — the "
                "synced drain wall clock is serve_wall_seconds_total)",
                labels=("kind",)),
            "wall_s": reg.counter(
                "serve_wall_seconds_total",
                "synchronized wall seconds across all drains"),
            "compiled": reg.counter(
                "serve_compiled_programs_total",
                "first-seen fused-step shapes (compile churn), per "
                "(kind, LANESxTOKENS[/masked]) bucket",
                labels=("kind", "shape")),
        }
        # pre-create per-kind children so exports carry explicit zeros
        for fam in ("requests", "tokens", "pad_lanes", "pad_tokens",
                    "lanes", "batches", "dispatch_s"):
            for k in _OP_STATE:
                self._m[fam].labels(kind=k)
        self._m_deadline = {
            "requests": reg.counter(
                "serve_deadline_requests_total",
                "submitted requests carrying a deadline (explicit or "
                "SLO-derived), per op kind", labels=("kind",)),
            "met": reg.counter(
                "serve_deadline_met_total",
                "deadline-carrying requests delivered on time, per op "
                "kind", labels=("kind",)),
            "missed": reg.counter(
                "serve_deadline_missed_total",
                "deadline-carrying requests delivered PAST their "
                "deadline, per op kind", labels=("kind",)),
            "shed": reg.counter(
                "serve_deadline_shed_total",
                "deadline-carrying requests shed by admission, labeled "
                "by whether the deadline had ALREADY passed at shed "
                "time (late='yes' sheds lose nothing — the SLO was "
                "gone; late='no' sheds are real SLO casualties)",
                labels=("late",)),
            "cancelled": reg.counter(
                "serve_deadline_cancelled_total",
                "deadline-carrying requests cancelled (close_session) "
                "before running — the fourth terminal disposition, so "
                "met + missed + shed + cancelled == requests",
                labels=("kind",)),
        }
        for fam in ("requests", "met", "missed", "cancelled"):
            for k in _OP_STATE:
                self._m_deadline[fam].labels(kind=k)
        for late in ("yes", "no"):
            self._m_deadline["shed"].labels(late=late)
        self._m_fork = reg.counter(
            "serve_fork_total",
            "session forks executed (child attached to the parent's "
            "arena row copy-on-write)")
        self._m_fork_failed = reg.counter(
            "serve_fork_failed_total",
            "fork requests that could not execute (parent closed "
            "before the fork ran, or the child sid was taken)")
        self._h_lateness = reg.histogram(
            "serve_deadline_lateness_seconds",
            "how far past its deadline a MISSED delivery landed "
            "(delivery time - deadline; met deliveries not observed)")
        self._m_refits = reg.counter(
            "serve_bucket_refits_total",
            "token-bucket ladder refits applied from the observed "
            "length distribution (bucket_policy='derived')")
        self._m_refits_deferred = reg.counter(
            "serve_bucket_refits_deferred_total",
            "ladder refits requested mid-pop and deferred to the next "
            "pop boundary (a swap between a sharded pop's per-shard "
            "sub-batches would mix bucket ladders)")
        self._g_ladder = reg.gauge(
            "serve_token_bucket_count",
            "buckets in the active token-bucket ladder (0 = exact-"
            "length grouping)")
        self._g_ladder.set(
            len(self._token_buckets) if self._token_buckets else 0)
        self._g = {
            "occupancy": reg.gauge(
                "serve_arena_occupancy",
                "fraction of arena slots allocated", labels=("arena",)),
            "slots": reg.gauge(
                "serve_arena_slots", "arena slot counts",
                labels=("arena", "state")),
            "resident": reg.gauge(
                "serve_resident_sessions",
                "device-resident sessions", labels=("arena",)),
            "shared_rows": reg.gauge(
                "serve_shared_rows",
                "live arena rows held by more than one reference "
                "(fork siblings / prefix-cache pins) — the dedup "
                "savings currently in effect", labels=("arena",)),
            "queue_depth": reg.gauge(
                "serve_queue_depth",
                "requests in the scheduler queue"),
            "backlog_depth": reg.gauge(
                "serve_backlog_depth",
                "requests held in the admission backlog"),
            "quota_pressure": reg.gauge(
                "serve_tenant_quota_pressure",
                "per-tenant queued-token usage / quota (explicitly "
                "quota'd tenants only)", labels=("tenant",)),
        }
        self._probe = {
            "probes": reg.counter(
                "serve_arena_consistency_probes_total",
                "free-list integrity probes run", labels=("arena",)),
            "errors": reg.counter(
                "serve_arena_consistency_errors_total",
                "free-list integrity violations found (must stay 0)",
                labels=("arena",)),
        }
        # per-shard visibility (one shard per device under a mesh) —
        # populated for n_shards == 1 too, so dashboards are uniform
        self._g_shard = {
            "occupancy": reg.gauge(
                "serve_shard_occupancy",
                "fraction of one arena shard's slots allocated",
                labels=("arena", "shard")),
            "resident": reg.gauge(
                "serve_shard_resident_sessions",
                "device-resident sessions per arena shard",
                labels=("arena", "shard")),
            "queue_depth": reg.gauge(
                "serve_shard_queue_depth",
                "scheduler-queued requests routed to each shard",
                labels=("shard",)),
        }
        self._m_shard_shed = reg.counter(
            "serve_shard_shed_total",
            "requests shed by admission, by the shard that owned their "
            "session (placement-fairness signal: one shard shedding "
            "while another idles means placement is skewed)",
            labels=("shard",))
        self._m_cross_shard = reg.counter(
            "serve_cross_shard_moves_total",
            "session states moved between shards — there is NO "
            "mechanism for this on the steady path (sessions are "
            "pinned to their shard at creation), so this counter "
            "exists to PROVE it stays 0; the sharded benchmark and CI "
            "gate assert exactly that")
        for s in range(self.n_shards):
            for kind in ("online", "stream"):
                self._g_shard["occupancy"].labels(arena=kind, shard=str(s))
                self._g_shard["resident"].labels(arena=kind, shard=str(s))
            self._g_shard["queue_depth"].labels(shard=str(s))
            self._m_shard_shed.labels(shard=str(s))

    def _resident_quota_of(self, tenant: str) -> Optional[int]:
        return self.admission.quota(tenant).max_resident

    # -- session lifecycle --------------------------------------------
    def _place(self, kind: str) -> int:
        """Deterministic least-loaded shard placement: fewest open
        sessions on that kind's arena, lowest shard index on ties —
        reproducible given the same creation order, which the
        bit-exactness tests rely on."""
        load = self._mgr[kind].shard_load()
        return min(range(len(load)), key=lambda s: (load[s], s))

    def create_session(self, sid: str, kind: str = "online",
                       tenant: str = "default",
                       shard: Optional[int] = None,
                       prefix_tokens=None) -> int:
        """Open a session and return its owning shard.  ``shard=None``
        (default) places it on the least-loaded shard of its kind's
        arena; an explicit shard pins it there (operators co-locating a
        tenant, tests pinning layouts).  The placement is for life —
        session state never migrates between shards.

        ``prefix_tokens`` (online sessions): the session's opening
        context.  With the prefix cache enabled, a session whose tenant
        already compressed this exact prefix ATTACHES to the cached row
        (copy-on-write share — no ingest, no recompression; the session
        is born resident and pins to the cached row's shard); otherwise
        the prefix is submitted as a normal ingest and its compressed
        row is pinned into the cache when it executes, so the NEXT
        session with this prefix dedups."""
        if kind not in self._mgr:
            raise ValueError(
                f"no arena for session kind {kind!r} "
                "(construct the engine with stream_slots > 0?)")
        if prefix_tokens is not None and kind != "online":
            raise ValueError("prefix_tokens applies to online sessions "
                             "(compressed-memory prefixes)")
        if prefix_tokens is not None and self.prefix_cache is not None:
            ent = self.prefix_cache.lookup(tenant, prefix_tokens)
            if ent is not None and (shard is None or shard == ent.shard):
                # dedup hit: born resident on the shared row, read-only
                # until the first write COW-breaks
                self._mgr[kind].adopt_row(sid, tenant, ent.shard,
                                          ent.slot, ent.mem_groups)
                self._kind[sid] = kind
                self._shard[sid] = ent.shard
                self._tenant[sid] = tenant
                self.prefix_cache.note_hit()
                self.obs.recorder.note(
                    "prefix", f"dedup hit sid={sid} slot={ent.slot} "
                              f"shard={ent.shard}")
                return ent.shard
        if shard is None:
            shard = self._place(kind)
        self._mgr[kind].create(sid, tenant, shard=shard)
        self._kind[sid] = kind
        self._shard[sid] = shard
        self._tenant[sid] = tenant
        if prefix_tokens is not None:
            verdict = self.ingest(sid, prefix_tokens)
            req = verdict.request
            if not req.shed and self.prefix_cache is not None:
                # pin the compressed row into the cache when this very
                # request executes (cancel/shed clean these up)
                self._prefix_req[sid] = req
                self._prefix_toks[sid] = np.array(
                    np.asarray(prefix_tokens, np.int32).reshape(-1),
                    copy=True)
        return shard

    def fork_session(self, parent_sid: str, child_sid: str,
                     priority: int = 0) -> Verdict:
        """Fork ``parent_sid`` into a copy-on-write child.  The fork is
        SCHEDULED, not immediate: a zero-token ``fork`` request queues
        on the PARENT session, so the snapshot point respects the
        parent's program order (ops submitted before the fork are in
        the child's branch; ops submitted after are not).  When it
        executes, the child shares the parent's arena row (resident
        parent), host tree (offloaded parent) or replay history — zero
        device copies either way — and pins to the parent's shard.

        The child is addressable IMMEDIATELY: requests may queue on it
        right away, but the scheduler HOLDS them (no priority or
        deadline can reorder a child op before the fork that creates
        the session) until the fork request executes and releases the
        hold."""
        kind = self._kind.get(parent_sid)
        if kind is None:
            raise ValueError(f"unknown parent session {parent_sid!r}")
        if child_sid in self._kind or child_sid in self._pending_forks:
            raise ValueError(f"session {child_sid!r} already exists")
        tenant = self._tenant[parent_sid]
        req = self.scheduler.make_request(
            parent_sid, "fork", np.zeros(0, np.int32), priority,
            tenant=tenant)
        req.shard = self._shard[parent_sid]
        req.fork_child = child_sid
        self._pending_forks.add(child_sid)
        rec = self.obs.recorder
        rec.submit(req)
        verdict = self.admission.submit_request(req)
        self._record_verdict(verdict)
        if not req.shed:
            # reserve the child's address now: submits on it validate
            # and queue (held), a competing create/fork on the sid
            # raises.  _abort_fork unwinds all of this if the fork dies
            # before executing.
            self._kind[child_sid] = kind
            self._shard[child_sid] = self._shard[parent_sid]
            self._tenant[child_sid] = tenant
            self.scheduler.hold(child_sid)
        return dataclasses.replace(verdict, shard=req.shard)

    def _exec_fork(self, r: Request) -> None:
        """Execute one popped fork request — pure control plane (no
        arena activation, no device compute): wire the child into the
        manager and release the scheduler hold on its queued requests.
        A fork whose parent or child vanished between submit and
        execution (close/shed races) fails with a counted, structured
        outcome rather than an exception mid-drain."""
        child = r.fork_child
        kind = self._kind.get(r.sid)
        if kind is not None and child is not None \
                and child in self._pending_forks:
            self._pending_forks.discard(child)
            self._mgr[kind].fork(r.sid, child, tenant=r.tenant)
            if r.sid in self._cached:
                # the child's row shares the parent's KV cache rows —
                # ADD the parent's accounting to any reservations the
                # child's own held queries already made
                self._cached[child] = (self._cached[r.sid]
                                       + self._cached.get(child, 0))
            self.scheduler.release(child)
            self._m_fork.inc()
            self.obs.recorder.executed(r, "fork")
        else:
            self._abort_fork(child)
            self._m_fork_failed.inc()
            self.obs.recorder.note(
                "fork", f"failed parent={r.sid} child={child}")
        r.result = None
        r.done = True
        self.obs.recorder.finished(r)

    def _abort_fork(self, child: Optional[str]) -> None:
        """Unwind a fork that died before executing (parent closed, fork
        request shed as an overflow victim): drop the child-sid
        reservation, cancel its held queued requests (recursively
        aborting any grandchild forks queued on it), and release the
        scheduler hold."""
        if child is None or child not in self._pending_forks:
            return
        self._pending_forks.discard(child)
        self.scheduler.release(child)
        if self._kind.pop(child, None) is None:
            return                    # shed before registration
        rec = self.obs.recorder
        for r in self.admission.cancel(child):
            rec.cancelled(r)
            if r.deadline is not None:
                self._m_deadline["cancelled"].labels(kind=r.kind).inc()
            self._abort_fork(r.fork_child)
        self._cached.pop(child, None)
        self._shard.pop(child, None)
        self._tenant.pop(child, None)

    def shard_of(self, sid: str) -> Optional[int]:
        """The shard owning ``sid``'s session (None = unknown sid)."""
        return self._shard.get(sid)

    def close_session(self, sid: str,
                      shard: Optional[int] = None) -> CloseResult:
        """Tear a session down everywhere (queue, backlog, side tables,
        manager).  Closing an unknown (or already-closed) sid is a
        structured no-op — it used to KeyError out of ``self._kind``
        AFTER cancelling queue entries, leaving a double-close half
        applied.  ``shard``: optional routing assertion — a close
        routed to a shard that does not own the sid is a structured
        no-op (``status="wrong-shard"``) with NOTHING torn down, so a
        misrouted control call can never cancel another shard's
        work."""
        if shard is not None and self._shard.get(sid) != shard:
            return CloseResult(sid, "wrong-shard")
        kind = self._kind.pop(sid, None)
        if kind is None:
            return CloseResult(sid, "unknown")
        dropped = self.admission.cancel(sid)  # backlog + queue
        rec = self.obs.recorder
        for r in dropped:                     # terminal span: cancelled
            rec.cancelled(r)
            if r.deadline is not None:
                # terminal disposition: a cancelled deadline-carrying
                # request never reaches met/missed, so without this the
                # deadline conservation met+missed+shed+cancelled ==
                # requests would leak on every close
                self._m_deadline["cancelled"].labels(kind=r.kind).inc()
            if r.fork_child is not None:
                # a queued fork dies with its parent: unwind the child
                # reservation and its held queued work
                self._abort_fork(r.fork_child)
        # closing a not-yet-created fork child: drop the reservation so
        # the queued fork fails structurally instead of resurrecting it
        self._pending_forks.discard(sid)
        self.scheduler.release(sid)
        self._prefix_req.pop(sid, None)
        self._prefix_toks.pop(sid, None)
        self._cached.pop(sid, None)
        self._shard.pop(sid, None)
        self._tenant.pop(sid, None)
        return self._mgr[kind].close(sid)

    def offload_session(self, sid: str,
                        shard: Optional[int] = None) -> OffloadResult:
        """Explicitly push a session's state to host.  A no-op with a
        telling status for unknown / already-offloaded / never-activated
        sessions — never raises.  ``shard``: optional routing assertion,
        as in `close_session` — a mismatch returns
        ``OffloadResult(status="wrong-shard")`` without touching the
        session."""
        kind = self._kind.get(sid)
        if kind is None:
            return OffloadResult(sid, "unknown")
        if shard is not None and self._shard.get(sid) != shard:
            return OffloadResult(sid, "wrong-shard")
        return self._mgr[kind].offload_batch([sid])[0]

    # -- memory-pressure plumbing (serve.pressure callbacks) -----------
    def _session_footprint(self, sid: str) -> int:
        """Logical device-memory tokens a resident ONLINE session holds:
        its filled compressed-memory groups times comp_len, plus its
        live KV-cache tokens.  A SHARED row (fork siblings, prefix-cache
        attachment) is charged ONCE — to its first resident holder by
        sid order — because the device genuinely holds one copy; this is
        the accounting that lets the pressure budget admit more sessions
        under prefix-heavy dedup at equal capacity."""
        mgr = self._mgr["online"]
        sess = mgr.sessions.get(sid)
        if sess is None or not sess.resident:
            return 0
        mem = sess.mem_groups * self.cfg.ccm.comp_len
        if mgr.arena.shared(sess.slot):
            sharers = mgr.slot_sharers(sess.slot)
            if sharers and sid != sharers[0]:
                mem = 0
        return mem + self._cached.get(sid, 0)

    def _has_pending_work(self, sid: str) -> bool:
        """Whether the session has work anywhere (scheduler queue or
        admission backlog) — the pressure controller never offloads
        such sessions: they would restore on the very next batch."""
        if self.scheduler.queued(sid=sid):
            return True
        return any(r.sid == sid for r in self.admission.backlog)

    def _all_pending_late(self, sid: str) -> bool:
        """Whether EVERY pending request of the session (queue +
        backlog) is already past its deadline — the pressure
        controller's 'unsalvageable' predicate: offloading such a
        session delays only work whose SLO is lost anyway
        (`PressurePolicy.offload_late_sessions`)."""
        reqs = self.scheduler.queued(sid=sid) + [
            r for r in self.admission.backlog if r.sid == sid]
        if not reqs:
            return False
        now = self.obs.clock.now()
        return all(self.scheduler.is_late(r, now) for r in reqs)

    def _recompress_session(self, sid: str) -> int:
        """Pressure lever 1: collapse the session's resident compressed
        memory at ``recompress_group`` (one jitted gather -> masked
        recompress -> scatter over the mem slabs); returns logical
        tokens freed (0 when nothing would shrink)."""
        mgr = self._mgr["online"]
        sess = mgr.sessions.get(sid)
        if sess is None or not sess.resident:
            return 0
        if mgr.arena.shared(sess.slot):
            # a shared row is read-only: recompressing in place would
            # silently corrupt every sibling (the arena's write guard
            # would refuse the scatter anyway) — refuse the lever; the
            # controller moves on to the next candidate
            return 0
        group = self.pressure.policy.recompress_group
        new_groups = -(-sess.mem_groups // group)
        freed = (sess.mem_groups - new_groups) * self.cfg.ccm.comp_len
        if freed <= 0:
            return 0
        arena = mgr.arena
        arena.slabs = arena.slabs._replace(mem=SRV.recompress_arena_slots(
            arena.slabs.mem, jnp.asarray([sess.slot], jnp.int32),
            cfg=self.cfg, group=group))
        arena.mark_dirty([sess.slot])
        sess.mem_groups = new_groups
        return freed

    # -- request submission -------------------------------------------
    def _on_shed(self, req: Request) -> None:
        """Admission dropped a request: release any resources its
        submit-time validation reserved (KV-cache token accounting),
        and attribute the shed to the owning shard (fairness signal)."""
        if req.kind == "query" and req.sid in self._cached:
            # plain decrement: every shed query (newcomer or queued
            # victim) carries a reservation made at its own submit
            self._cached[req.sid] -= req.token_len
        if req.fork_child is not None:
            self._abort_fork(req.fork_child)
        if self._prefix_req.get(req.sid) is req:
            # the shed request was the prefix ingest that would have
            # pinned the cache entry — it never runs
            self._prefix_req.pop(req.sid, None)
            self._prefix_toks.pop(req.sid, None)
        self._m_shard_shed.labels(shard=str(req.shard)).inc()
        if req.deadline is not None:
            late = self.scheduler.is_late(req)
            self._m_deadline["shed"].labels(
                late="yes" if late else "no").inc()

    def _submit(self, sid: str, op: str, tokens, priority: int,
                deadline: Optional[float] = None) -> Verdict:
        kind = self._kind[sid]
        if _OP_STATE[op] != kind:
            raise ValueError(f"op {op!r} invalid for {kind!r} session {sid!r}")
        tenant = self._tenant[sid]
        if deadline is None:
            # SLO-derived deadline: the tenant's per-kind budget from now
            slo = self.admission.quota(tenant).slo_for(op)
            if slo is not None:
                deadline = self.obs.clock.now() + slo
        # make (and shape-validate) the request BEFORE any reservation —
        # a validation error must raise with zero side effects
        req = self.scheduler.make_request(sid, op, tokens, priority,
                                          tenant=tenant, deadline=deadline)
        req.shard = self._shard[sid]   # route to the session's placement
        if deadline is not None:
            self._m_deadline["requests"].labels(kind=op).inc()
        # offered-traffic length sample for the bucket-derivation fit
        # (recorded regardless of verdict: the ladder should serve what
        # ARRIVES, not just what survived admission)
        self._len_history.append(req.token_len)
        self._len_seen += 1
        n = req.token_len
        if op == "stream" and n > self.cfg.ccm.stream_chunk:
            # mirror the stream_step trace-time guard HERE, before the
            # request enters the queue — a trace error mid-drain would
            # abort run() after the batch was already popped
            raise ValueError(
                f"stream chunk ({n} tokens) exceeds "
                f"cfg.ccm.stream_chunk ({self.cfg.ccm.stream_chunk}); "
                "split the input")
        if op == "query":
            # queries append their tokens to the session's KV cache; the
            # cache write clamps silently past cache_len, corrupting
            # earlier rows — admit only what fits (counts queued work).
            # The reservation happens BEFORE admission so _on_shed can
            # reverse it symmetrically whether the shed request is this
            # one (shed at submit) or a queued victim it displaces.
            used = self._cached.get(sid, 0)
            if used + n > self.cache_len:
                raise ValueError(
                    f"session {sid!r} KV cache exhausted: {used} tokens "
                    f"cached + {n} requested > cache_len "
                    f"{self.cache_len}; close the session or build the "
                    "engine with a larger cache_len")
            self._cached[sid] = used + n
        rec = self.obs.recorder
        rec.submit(req)
        verdict = self.admission.submit_request(req)
        self._record_verdict(verdict)
        # surface the owning shard on the verdict so callers can route
        # follow-up control calls (close/offload) without a lookup
        return dataclasses.replace(verdict, shard=req.shard)

    def _record_verdict(self, verdict: Verdict) -> None:
        """Span events for the verdict — the engine observes everything
        from the structured return value, so admission stays recorder-
        free (pure control plane)."""
        rec = self.obs.recorder
        req = verdict.request
        cls = type(verdict).__name__
        if cls == "Admitted":
            rec.admitted(req)
            for v in verdict.shed_victims:     # terminal: displaced
                rec.shed(v, "displaced by higher-priority submit")
        elif cls == "Queued":
            rec.backlogged(req, verdict.reason)
        else:                                  # Shed
            rec.shed(req, verdict.reason)

    def now(self) -> float:
        """Current time on the engine's clock — the base for absolute
        ``deadline=`` arguments (``eng.ingest(sid, toks,
        deadline=eng.now() + 0.5)``)."""
        return self.obs.clock.now()

    def ingest(self, sid, tokens, priority: int = 0,
               deadline: Optional[float] = None) -> Verdict:
        return self._submit(sid, "ingest", tokens, priority, deadline)

    def query(self, sid, tokens, priority: int = 0,
              deadline: Optional[float] = None) -> Verdict:
        return self._submit(sid, "query", tokens, priority, deadline)

    def stream(self, sid, tokens, priority: int = 0,
               deadline: Optional[float] = None) -> Verdict:
        return self._submit(sid, "stream", tokens, priority, deadline)

    # -- execution -----------------------------------------------------
    def _step(self, op: str, masked: bool):
        """Jitted fused step per (op, masked).  Full-length batches take
        the unmasked program — masking costs ~10% per step (valid-mask
        attention + take-based frozen writes), so uniform traffic pays
        nothing; only genuinely ragged batches run the masked variant."""
        key = (op, masked)
        if key not in self._steps:
            self._steps[key] = self._step_factory(self.cfg, op, masked)
        return self._steps[key]

    def _sharded_step(self, op: str, masked: bool):
        """`shard_map` fused step per (op, masked) — the mesh hot path
        (`launch.serve.make_sharded_arena_step`)."""
        key = (op, masked)
        if key not in self._sharded_steps:
            self._sharded_steps[key] = SRV.make_sharded_arena_step(
                self.cfg, op, self.mesh, ragged=masked)
        return self._sharded_steps[key]

    def _note_shape(self, op: str, lanes: int, token_len: int,
                    masked: bool) -> None:
        """Count first-seen fused-step shapes: the compile-churn signal
        the bucket-ladder cost model (ROADMAP item 5) feeds on.  jit
        caches by (B, token_len) and program variant, so each new key
        here is (at most) one fresh XLA compile."""
        key = (op, lanes, token_len, masked)
        if key not in self._seen_shapes:
            self._seen_shapes.add(key)
            shape = f"{lanes}x{token_len}" + ("/masked" if masked else "")
            self._m["compiled"].labels(kind=op, shape=shape).inc()

    def _make_replay(self, state_kind: str):
        """Replay a recompute-dropped session's request history into its
        (zeroed) slot: one B=1 fused step per recorded request, padded
        into the same token buckets as live traffic so replay shares the
        serve programs instead of compiling exact-length ones."""
        def replay(sid: str, slot: int, history) -> None:
            mgr = self._mgr[state_kind]
            arena = mgr.arena
            ids = jnp.asarray([slot], jnp.int32)
            for op, toks in history:
                flat = np.asarray(toks, np.int32).reshape(-1)
                L = flat.size
                tl = token_bucket(L, self._token_buckets) if self.ragged \
                    else L
                if op == "stream":
                    tl = min(tl, self.cfg.ccm.stream_chunk)
                tl = max(tl, L)
                buf = np.zeros((1, 1, tl), np.int32)
                buf[0, 0, :L] = flat
                masked = self.ragged and tl != L
                step = self._step(op, masked)
                self._note_shape(op, 1, tl, masked)
                _, arena.slabs = step(self.params, arena.slabs, ids, buf,
                                      np.asarray([L], np.int32))
            arena.mark_dirty([slot])
            if state_kind == "online":
                # a replay rebuilds memory at the BASE ratio: the group
                # count is the replayed ingests (capped), regardless of
                # any recompression the dropped state had absorbed
                mgr.sessions[sid].mem_groups = min(
                    sum(1 for op, _ in history if op == "ingest"),
                    self._max_mem_groups)
        return replay

    def _maybe_cache_prefix(self, r: Request, sess) -> None:
        """Pin a just-executed prefix ingest into the prefix cache.
        Identity-checked against the request recorded at
        `create_session` (NOT just the sid) so a later ordinary ingest
        on the same session never caches non-prefix content.  Runs
        AFTER the batch's scatter + `mark_dirty`, so the incref lands on
        a row the write guard has already cleared at refcount 1."""
        if self._prefix_req.get(r.sid) is not r:
            return
        self._prefix_req.pop(r.sid, None)
        ptoks = self._prefix_toks.pop(r.sid, None)
        if self.prefix_cache is None or ptoks is None:
            return
        ent = self.prefix_cache.insert(
            sess.tenant, ptoks, sess.slot, sess.shard, sess.mem_groups)
        self.obs.recorder.note(
            "prefix", f"cached sid={r.sid} slot={ent.slot} "
                      f"shard={ent.shard} groups={ent.mem_groups}")

    def _run_batch(self, batch: ScheduledBatch) -> None:
        mgr = self._mgr[_OP_STATE[batch.kind]]
        arena = mgr.arena
        rec = self.obs.recorder
        pinned = {r.sid for r in batch.requests}
        t0 = self.obs.clock.now()
        slots = mgr.activate_batch([r.sid for r in batch.requests], pinned)
        ids = slots + [arena.pad_slot] * batch.pad
        # lanes padded up to the batch's token bucket; per-lane valid
        # lengths drive the masked ops (pad lanes claim the full bucket —
        # they gather/scatter the scratch row, semantics don't matter)
        toks = np.zeros((batch.bucket, 1, batch.token_len), np.int32)
        for i, r in enumerate(batch.requests):
            toks[i, 0, :r.token_len] = r.tokens[0]
        lengths = np.asarray(batch.valid_lens
                             + [batch.token_len] * batch.pad, np.int32)
        # one fused jitted program: gather rows -> vmapped op -> scatter
        # rows back into the donated slabs.  No block here: batches chain
        # through the slab dependency and overlap Python scheduling;
        # run() syncs once at the end of the drain.
        masked = self.ragged and any(vl != batch.token_len
                                     for vl in batch.valid_lens)
        step = self._step(batch.kind, masked)
        self._note_shape(batch.kind, batch.bucket, batch.token_len, masked)
        out, arena.slabs = step(self.params, arena.slabs,
                                jnp.asarray(ids, jnp.int32), toks, lengths)
        arena.mark_dirty(ids)
        dt = self.obs.clock.now() - t0
        # results are NOT materialized here — np.asarray(out) would
        # block on this batch's compute and serialize the drain; run()
        # converts all outs after the last dispatch (one transfer per
        # batch, per-request results become zero-copy numpy views)
        self._undelivered.append((batch.requests, out))
        shape = f"{batch.bucket}x{batch.token_len}" \
            + ("/masked" if masked else "")
        for r in batch.requests:
            sess = mgr.sessions[r.sid]
            sess.n_ops += 1
            if batch.kind == "ingest":
                # host mirror of the slot's MemState.slots (concat mode
                # caps at max_slots; merge pins at 1) — the pressure
                # controller's footprint accounting
                sess.mem_groups = min(sess.mem_groups + 1,
                                      self._max_mem_groups)
                self._maybe_cache_prefix(r, sess)
            mgr.record(r.sid, r.kind, r.tokens[0])
            rec.executed(r, shape)
        rec.note("batch", f"kind={batch.kind} shape={shape} "
                          f"real={len(batch.requests)} pad={batch.pad} "
                          f"dispatch_s={dt:.6f}")
        m = self._m
        m["requests"].labels(kind=batch.kind).inc(len(batch.requests))
        m["tokens"].labels(kind=batch.kind).inc(sum(batch.valid_lens))
        m["pad_lanes"].labels(kind=batch.kind).inc(batch.pad)
        m["pad_tokens"].labels(kind=batch.kind).inc(
            len(batch.requests) * batch.token_len - sum(batch.valid_lens))
        m["lanes"].labels(kind=batch.kind).inc(batch.bucket)
        m["batches"].labels(kind=batch.kind).inc()
        m["dispatch_s"].labels(kind=batch.kind).inc(dt)

    def _run_sharded_batch(self, sb: ShardedBatch) -> None:
        """Execute one sharded pop: activate every sub-batch's sessions
        in ONE `activate_batch` call (shard-local slot allocation,
        per-shard staged offload/restore), then run all shards — as one
        `shard_map` program over (S, B, ...) lanes on a mesh, or as a
        loop of per-shard single-device steps otherwise (identical
        control plane; empty sub-batches are skipped on the loop path
        since their all-pad lanes only write scratch garbage)."""
        mgr = self._mgr[_OP_STATE[sb.kind]]
        arena = mgr.arena
        rec = self.obs.recorder
        all_reqs = sb.requests                       # shard-major
        pinned = {r.sid for r in all_reqs}
        t0 = self.obs.clock.now()
        slots = mgr.activate_batch([r.sid for r in all_reqs], pinned)
        slot_of = dict(zip((r.sid for r in all_reqs), slots))
        S, B, L = self.n_shards, sb.bucket, sb.token_len
        use_mesh = self.mesh is not None
        # mesh mode feeds LOCAL row ids (each device indexes its own
        # block under shard_map); loop mode feeds global slot ids
        ids = np.empty((S, B), np.int32)
        toks = np.zeros((S, B, 1, L), np.int32)
        lengths = np.full((S, B), L, np.int32)
        gids: List[int] = []                         # global, for dirty
        for s, sub in enumerate(sb.shards):
            pad = arena.pad_slot_of(s)
            ids[s, :] = arena.local_row(pad) if use_mesh else pad
            for i, r in enumerate(sub.requests):
                slot = slot_of[r.sid]
                ids[s, i] = arena.local_row(slot) if use_mesh else slot
                toks[s, i, 0, :r.token_len] = r.tokens[0]
                lengths[s, i] = r.token_len
                gids.append(slot)
            gids.extend([pad] * (B - len(sub.requests)))
        masked = self.ragged and any(r.token_len != L for r in all_reqs)
        lanes_run = S * B
        if use_mesh:
            step = self._sharded_step(sb.kind, masked)
            self._note_shape(sb.kind, B, L, masked)
            out, arena.slabs = step(
                self.params, arena.slabs, jnp.asarray(ids, jnp.int32),
                toks, lengths)
            outs = [None if out is None else out[s] for s in range(S)]
        else:
            step = self._step(sb.kind, masked)
            self._note_shape(sb.kind, B, L, masked)
            outs = []
            lanes_run = 0
            for s, sub in enumerate(sb.shards):
                if not sub.requests:
                    outs.append(None)
                    continue
                out_s, arena.slabs = step(
                    self.params, arena.slabs,
                    jnp.asarray(ids[s], jnp.int32), toks[s], lengths[s])
                outs.append(out_s)
                lanes_run += B
        arena.mark_dirty(gids)
        dt = self.obs.clock.now() - t0
        for s, sub in enumerate(sb.shards):
            if sub.requests:
                self._undelivered.append((sub.requests, outs[s]))
        shape = f"{S}x{B}x{L}" + ("/masked" if masked else "")
        for r in all_reqs:
            sess = mgr.sessions[r.sid]
            sess.n_ops += 1
            if sb.kind == "ingest":
                sess.mem_groups = min(sess.mem_groups + 1,
                                      self._max_mem_groups)
                self._maybe_cache_prefix(r, sess)
            mgr.record(r.sid, r.kind, r.tokens[0])
            rec.executed(r, shape)
        valid = sum(r.token_len for r in all_reqs)
        rec.note("batch", f"kind={sb.kind} shape={shape} "
                          f"real={len(all_reqs)} "
                          f"pad={lanes_run - len(all_reqs)} "
                          f"dispatch_s={dt:.6f}")
        m = self._m
        m["requests"].labels(kind=sb.kind).inc(len(all_reqs))
        m["tokens"].labels(kind=sb.kind).inc(valid)
        m["pad_lanes"].labels(kind=sb.kind).inc(lanes_run - len(all_reqs))
        m["pad_tokens"].labels(kind=sb.kind).inc(
            len(all_reqs) * L - valid)
        m["lanes"].labels(kind=sb.kind).inc(lanes_run)
        m["batches"].labels(kind=sb.kind).inc()
        m["dispatch_s"].labels(kind=sb.kind).inc(dt)

    def run(self, max_batches: Optional[int] = None) -> int:
        """Drain the queue (or up to ``max_batches``); returns batches
        run.  After every popped batch the admission backlog is pumped —
        backpressured submits enter the queue as soon as their tokens
        fit — and the drain only ends once both the queue AND the
        pumpable backlog are empty.  Synchronizes once at the end, so
        per-kind dispatch seconds are dispatch times and the drain's
        wall clock is the true cost.  If anything escapes mid-drain the
        flight recorder's last events are dumped to stderr before the
        exception propagates."""
        try:
            return self._run(max_batches)
        except Exception as exc:                 # noqa: BLE001 — re-raised
            self._dump_flight_on_error(exc)
            raise

    def _run(self, max_batches: Optional[int]) -> int:
        rec = self.obs.recorder
        n = 0
        t0 = self.obs.clock.now()
        while max_batches is None or n < max_batches:
            # pop boundary: the ONLY place a derived-bucket refit may
            # land.  A pop (especially a sharded one, whose per-shard
            # sub-batches must share one ladder) and its execution run
            # under `_popping`; a refit requested meanwhile is deferred
            # and applied here, before the next pop starts
            if (self.bucket_policy == "derived"
                    and self._len_seen - self._len_at_refit
                    >= self._bucket_refit_interval):
                self.refit_token_buckets()
            self._popping = True
            try:
                # recomputed per pop: pumped backlog entries can
                # introduce tenants that were not queued when the drain
                # started
                caps, default_cap = self.admission.lane_caps()
                if self.n_shards == 1:
                    batch = self.scheduler.next_batch(caps, default_cap)
                else:
                    batch = self.scheduler.next_sharded_batches(
                        self.n_shards, caps, default_cap,
                        per_shard_cap=self._per_shard_cap,
                        max_total=self._max_total)
                if batch is None:
                    pumped = self.admission.pump()
                    if pumped:
                        for r in pumped:
                            rec.pumped(r)
                        continue
                    break
                self.admission.note_popped(batch.requests)
                for r in batch.requests:
                    rec.popped(r)
                if batch.kind == "fork":
                    # control-plane only: snapshot the parent at its
                    # program-order point — no device step runs
                    for r in batch.requests:
                        self._exec_fork(r)
                elif isinstance(batch, ShardedBatch):
                    self._run_sharded_batch(batch)
                else:
                    self._run_batch(batch)
                if self.pressure is not None:
                    # drain hook: footprints grew by the batch's ingest
                    # groups / query cache writes AFTER their admission
                    # check — re-absorb past the high watermark so the
                    # next submit doesn't start from a deep deficit
                    self.pressure.maybe_relieve()
                for r in self.admission.pump():
                    rec.pumped(r)
                n += 1
            finally:
                self._popping = False
            if self._refit_pending:
                self._refit_pending = False
                self.refit_token_buckets()
        if n:
            now = self.obs.clock.now()
            for reqs, out in self._undelivered:
                out_np = np.asarray(out) if out is not None else None
                for i, r in enumerate(reqs):
                    # slice off bucket padding: a request padded into a
                    # larger token lane only owns its first valid_len
                    # logit rows (the rest are masked-lane garbage)
                    r.result = out_np[i, 0, :r.token_len] \
                        if out_np is not None else None
                    r.done = True
                    if r.deadline is not None:
                        if now > r.deadline:
                            self._m_deadline["missed"].labels(
                                kind=r.kind).inc()
                            self._h_lateness.observe(now - r.deadline)
                        else:
                            self._m_deadline["met"].labels(
                                kind=r.kind).inc()
                    rec.finished(r)
            self._undelivered.clear()
        for m in self._mgr.values():
            # unconditional: async offload_session() transfers may be in
            # flight even when this drain popped zero batches — leaving
            # them unbarriered would pin the stacked host buffers forever
            m.sync()
        if n:
            for m in self._mgr.values():
                jax.block_until_ready(jax.tree.leaves(m.arena.slabs)[0])
            self._m["wall_s"].inc(self.obs.clock.now() - t0)
        if self._refit_pending:
            # a refit deferred by the final pop (the loop broke before
            # reaching the next pop boundary) — apply it now, the drain
            # is over
            self._refit_pending = False
            self.refit_token_buckets()
        elif (self.bucket_policy == "derived"
                and self._len_seen - self._len_at_refit
                >= self._bucket_refit_interval):
            # off the hot path: refit between drains so the next drain's
            # pops (and replay padding) use the updated ladder
            self.refit_token_buckets()
        return n

    def _dump_flight_on_error(self, exc: BaseException) -> None:
        """Crash forensics: print the flight recorder's bounded ring of
        recent events to stderr (no-op under `NullRecorder`)."""
        rec = self.obs.recorder
        rec.note("error", repr(exc))
        lines = rec.flight_lines()
        if lines:
            print(f"--- serve flight recorder ({len(lines)} events, "
                  f"most recent last) ---", file=sys.stderr)
            for line in lines:
                print(line, file=sys.stderr)
            print("--- end flight recorder ---", file=sys.stderr)

    # -- introspection -------------------------------------------------
    @property
    def stats(self) -> Dict[str, Dict[str, float]]:
        """Legacy per-kind stats view, now read from the registry
        (``serve_*_total{kind}``).  ``seconds`` are dispatch times only;
        the synced drain wall clock is ``stats_wall``."""
        out = {}
        for k in _OP_STATE:
            out[k] = {key: int(self._m[key].labels(kind=k).value)
                      for key in _STAT_KEYS}
            out[k]["seconds"] = float(
                self._m["dispatch_s"].labels(kind=k).value)
        return out

    @property
    def stats_wall(self) -> float:
        """Synchronized wall seconds across all drains (registry view of
        ``serve_wall_seconds_total``)."""
        return float(self._m["wall_s"].value)

    def compile_stats(self, clamped: bool = False) -> Dict[str, int]:
        """Compiled-program count per op kind (recompile-churn metric),
        summed over the masked/unmasked step variants.

        A kind whose jit cache size is unavailable (private
        ``_cache_size`` API missing) reports the sentinel ``-1`` —
        *unmeasured*, not zero.  ``clamped=True`` maps that sentinel to
        0 so totals can be summed; this is the ONE place the clamp
        happens (callers must not re-clamp)."""
        out: Dict[str, int] = {}
        for (op, _), fn in self._steps.items():
            n = fn._cache_size() if hasattr(fn, "_cache_size") else -1
            prev = out.get(op, 0)
            out[op] = -1 if (n < 0 or prev < 0) else prev + n
        if clamped:
            out = {k: max(v, 0) for k, v in out.items()}
        return out

    # -- traffic-derived token buckets ---------------------------------
    @property
    def token_buckets(self):
        """The ACTIVE token-bucket ladder (None = exact-length
        grouping).  Static by default; ``bucket_policy='derived'``
        refits it from traffic (`refit_token_buckets`)."""
        return self._token_buckets

    def length_history(self) -> List[int]:
        """Recent offered request token lengths (bounded window) — the
        sample `derive_token_buckets` fits on."""
        return list(self._len_history)

    def derived_token_buckets(self,
                              compile_cost_tokens: Optional[float] = None
                              ) -> Tuple[int, ...]:
        """Fit a ladder to the observed length window WITHOUT applying
        it (`launch.specs.derive_token_buckets`).  Already-compiled
        padded lengths (the compile-churn counter's seen shapes) cost no
        churn, so refits gravitate to warm shapes; the result never
        pads worse than the configured static ladder on this window.
        With an empty window the static ladder comes back unchanged."""
        if not self.ragged:
            raise ValueError("bucket derivation needs ragged batching")
        compiled = {tl for (_op, _lanes, tl, _masked) in self._seen_shapes}
        return derive_token_buckets(
            list(self._len_history),
            max_buckets=self._bucket_max,
            compile_cost_tokens=(self._bucket_compile_cost
                                 if compile_cost_tokens is None
                                 else compile_cost_tokens),
            compiled_lens=compiled,
            baseline=self._static_token_buckets)

    def refit_token_buckets(self) -> Tuple[int, ...]:
        """Apply a fresh fit as the active ladder (scheduler pops and
        replay padding pick it up immediately; per-kind max_token_len
        caps still apply at pop time).  Counted in
        ``serve_bucket_refits_total``; the drain loop calls this
        automatically under ``bucket_policy='derived'`` every
        ``bucket_refit_interval`` submissions.

        ATOMICITY: a ladder swap must never land between a sharded
        pop's per-shard sub-batches (they would bucket to different
        token lengths and the (S, B, L) lanes could not stack).  While
        the drain loop is inside a pop (``_popping``) the refit is
        DEFERRED — recorded and applied at the next pop boundary — and
        the active ladder is returned unchanged."""
        if self._popping:
            self._refit_pending = True
            self._m_refits_deferred.inc()
            self.obs.recorder.note(
                "buckets", "refit deferred: pop in progress "
                           "(applied at the next pop boundary)")
            return self._token_buckets
        ladder = self.derived_token_buckets()
        self._token_buckets = ladder
        self.scheduler.token_buckets = ladder
        self._len_at_refit = self._len_seen
        self._m_refits.inc()
        self._g_ladder.set(len(ladder))
        self.obs.recorder.note(
            "buckets", f"refit token ladder -> {ladder}")
        return ladder

    def compiled_programs(self) -> int:
        """Total compiled programs across op kinds (compile-cache churn:
        compare exact-length vs token-bucketed scheduling on the same
        traffic).  Unmeasured kinds count as 0 (see ``compile_stats``)."""
        return sum(self.compile_stats(clamped=True).values())

    def batch_occupancy(self) -> Dict[str, float]:
        """Mean fraction of batch lanes holding a real request, per op
        kind (1.0 = no pad lanes; higher is better batch sharing)."""
        return {k: (s["requests"] / s["lanes"] if s["lanes"] else 0.0)
                for k, s in self.stats.items()}

    def occupancy(self) -> Dict[str, float]:
        return {k: m.arena.occupancy for k, m in self._mgr.items()}

    def resident(self) -> Dict[str, int]:
        return {k: m.n_resident for k, m in self._mgr.items()}

    def queue_depth(self) -> int:
        """Requests waiting anywhere: scheduler queue + admission
        backlog (the open-loop benchmark's saturation metric)."""
        return self.scheduler.pending + len(self.admission.backlog)

    def throughput(self) -> float:
        """Overall tokens/s across all drains (synced wall clock).
        Per-kind ``stats[kind]['seconds']`` are dispatch times only."""
        total = sum(s["tokens"] for s in self.stats.values())
        wall = self.stats_wall
        return total / wall if wall else 0.0

    # -- metrics export ------------------------------------------------
    def _sample_gauges(self) -> None:
        """Refresh point-in-time gauges and run the arena free-list
        integrity probe (probe/error counters) — called on every
        snapshot/export so gauges are current at read time."""
        g, probe = self._g, self._probe
        for kind, mgr in self._mgr.items():
            arena = mgr.arena
            sample = arena.metrics_sample()
            g["occupancy"].labels(arena=kind).set(sample["occupancy"])
            g["slots"].labels(arena=kind, state="live").set(sample["live"])
            g["slots"].labels(arena=kind, state="free").set(sample["free"])
            g["resident"].labels(arena=kind).set(mgr.n_resident)
            g["shared_rows"].labels(arena=kind).set(sample["shared"])
            errs = arena.consistency_errors()
            probe["probes"].labels(arena=kind).inc()
            if errs:
                probe["errors"].labels(arena=kind).inc(len(errs))
                self.obs.recorder.note(
                    "arena-integrity", f"{kind}: {errs}")
            gs = self._g_shard
            res_by_shard = [0] * self.n_shards
            for sess in mgr.sessions.values():
                if sess.resident:
                    res_by_shard[sess.shard] += 1
            for s, sh in enumerate(sample["shards"]):
                gs["occupancy"].labels(arena=kind, shard=str(s)).set(
                    sh["occupancy"])
                gs["resident"].labels(arena=kind, shard=str(s)).set(
                    res_by_shard[s])
        q_by_shard = [0] * self.n_shards
        for r in self.scheduler.queued():
            q_by_shard[r.shard] += 1
        for s, d in enumerate(q_by_shard):
            self._g_shard["queue_depth"].labels(shard=str(s)).set(d)
        g["queue_depth"].set(self.scheduler.pending)
        g["backlog_depth"].set(len(self.admission.backlog))
        if self.pressure is not None:
            self.pressure.sample_gauges()
        for tenant, quota in self.admission.quotas.items():
            if quota.max_queued_tokens:
                g["quota_pressure"].labels(tenant=tenant).set(
                    self.admission.queued_tokens(tenant)
                    / quota.max_queued_tokens)

    def metrics_snapshot(self) -> dict:
        """Full JSON-ready metrics export: every registry family plus a
        ``derived`` block of ratios the registry cannot express
        (throughput, occupancy, compile stats).  See
        docs/OBSERVABILITY.md for the catalog."""
        self._sample_gauges()
        return {
            "metrics": self.obs.registry.snapshot(),
            "derived": {
                "throughput_tok_per_s": self.throughput(),
                "batch_occupancy": self.batch_occupancy(),
                "arena_occupancy": self.occupancy(),
                "resident": self.resident(),
                "queue_depth": self.queue_depth(),
                "compile_stats": self.compile_stats(),
                "admission": dict(self.admission.stats),
            },
        }

    def metrics_prometheus(self) -> str:
        """Prometheus text exposition of the registry (gauges freshly
        sampled).  Derived ratios are JSON-snapshot-only — Prometheus
        consumers compute rates from the raw counters."""
        self._sample_gauges()
        return self.obs.registry.to_prometheus()
