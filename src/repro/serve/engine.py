"""Multi-tenant serve engine: admission -> scheduler -> arena -> steps.

Drives the whole subsystem: submits pass ADMISSION CONTROL
(`serve.admission`: bounded ingress, per-tenant quotas, overflow
policy) and return a structured ``Admitted | Queued | Shed`` verdict —
`ArenaFull` never reaches callers; batches are capped at evictable
capacity by construction (scheduler ``max_batch`` <= ``max_resident``
per kind, per-tenant batch lanes <= the tenant's resident quota).
`run` drains the queue batch by batch — activate the batch's sessions
(batched LRU restore/offload via `SessionManager`, tenant-quota-aware),
then one fused jitted program per batch (`launch.serve.make_arena_step`)
gathers their arena rows, runs the vmapped op, and scatters the updated
rows back, fulfilling the requests.  After every popped batch the
backpressure backlog is pumped, so blocked submits drain as soon as
queue capacity frees.  Per-op stats (tokens/s, batches, padding waste),
arena occupancy and compile counts are tracked for the benchmark
harness.

Online sessions (ingest/query over ``OnlineState``) and streaming
sessions (``stream`` over ``StreamState``) live in separate arenas since
their state templates differ; ``stream_slots=0`` skips the second arena.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import serve as SRV
from repro.launch.specs import (SERVE_BATCH_BUCKETS, SERVE_TOKEN_BUCKETS,
                                token_bucket)
from repro.models.config import ModelConfig
from repro.serve.admission import (AdmissionController, TenantQuota,
                                   Verdict)
from repro.serve.arena import SessionArena
from repro.serve.scheduler import Request, ScheduledBatch, Scheduler
from repro.serve.session import (OffloadCostModel, OffloadResult,
                                 SessionManager)

_OP_STATE = {"ingest": "online", "query": "online", "stream": "stream"}


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 64,
                 cache_len: int = 256, mem_slots: Optional[int] = None,
                 max_resident: Optional[int] = None, stream_slots: int = 0,
                 stream_max_resident: Optional[int] = None,
                 batch_buckets: Sequence[int] = SERVE_BATCH_BUCKETS,
                 token_buckets="auto", aging: Optional[int] = 32,
                 admission_policy: str = "block",
                 max_queued_tokens: Optional[int] = None,
                 max_backlog: Optional[int] = None,
                 tenant_quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None,
                 batched_offload: bool = True,
                 async_offload: bool = False,
                 offload_cost_model: Optional[OffloadCostModel] = None,
                 step_factory: Optional[Callable] = None):
        """``token_buckets``: ragged-batching token buckets ("auto" picks
        `launch.specs.SERVE_TOKEN_BUCKETS` for attention archs and exact-
        length grouping for SSM/hybrid; None forces exact lengths).
        ``aging``: scheduler starvation knob — a waiting request's
        effective priority improves by one per ``aging`` popped batches.

        Admission (`serve.admission`): ``admission_policy`` is one of
        ``block`` / ``shed-lowest-priority`` / ``reject-new``;
        ``max_queued_tokens`` bounds the global queue
        (``max_backlog`` bounds the block-policy backlog entries);
        ``tenant_quotas`` / ``default_quota`` bound resident slots and
        queued tokens per tenant.  Defaults are unbounded — every
        submit returns ``Admitted``.

        Offload (`serve.session`): ``batched_offload`` moves k victims
        per transfer, ``async_offload`` overlaps the device->host copy
        with scheduling, ``offload_cost_model`` drops state and replays
        request history when that is cheaper than the round trip.

        ``step_factory(cfg, op, masked)``: override the fused arena step
        builder (default `launch.serve.make_arena_step`); the serve
        simulation harness injects a control-plane-only null step."""
        self.params = params
        self.cfg = cfg
        self.cache_len = cache_len
        if token_buckets == "auto":
            token_buckets = SERVE_TOKEN_BUCKETS if SRV.ragged_family(cfg) \
                else None
        elif token_buckets is not None and not SRV.ragged_family(cfg):
            raise ValueError(
                f"token buckets need masked lanes, unsupported for "
                f"family {cfg.family!r}")
        self.ragged = token_buckets is not None
        self._token_buckets = token_buckets
        self._step_factory = step_factory or SRV.make_arena_step
        mgr_kw = dict(batched_offload=batched_offload,
                      async_offload=async_offload,
                      cost_model=offload_cost_model,
                      resident_quota_of=self._resident_quota_of,
                      pack_buckets=batch_buckets)
        self._mgr: Dict[str, SessionManager] = {
            "online": SessionManager(
                SessionArena.for_online(cfg, n_slots, cache_len, mem_slots),
                max_resident, replay_fn=self._make_replay("online"),
                **mgr_kw),
        }
        if stream_slots:
            c = cfg.ccm
            if c.stream_sink + c.stream_chunk > c.stream_window:
                # stream_step raises this at trace time — mid-drain,
                # after batches were popped; fail at construction instead
                raise ValueError(
                    f"stream_sink ({c.stream_sink}) + stream_chunk "
                    f"({c.stream_chunk}) exceeds stream_window "
                    f"({c.stream_window})")
            self._mgr["stream"] = SessionManager(
                SessionArena.for_stream(cfg, stream_slots),
                stream_max_resident, replay_fn=self._make_replay("stream"),
                **mgr_kw)
        caps = {op: self._mgr[kind].max_resident
                for op, kind in _OP_STATE.items() if kind in self._mgr}
        # a stream op must never pad past the eviction quantum — one
        # eviction per step keeps the window bounded (stream_step guard)
        self.scheduler = Scheduler(
            batch_buckets, max_batch=caps, token_buckets=token_buckets,
            max_token_len={"stream": cfg.ccm.stream_chunk}, aging=aging)
        self.admission = AdmissionController(
            self.scheduler, policy=admission_policy,
            max_queued_tokens=max_queued_tokens, quotas=tenant_quotas,
            default_quota=default_quota, on_shed=self._on_shed,
            max_backlog=max_backlog)
        self._steps = {}               # op kind -> jitted fn
        self._kind: Dict[str, str] = {}   # sid -> 'online' | 'stream'
        self._tenant: Dict[str, str] = {}  # sid -> tenant
        self._cached: Dict[str, int] = {}  # sid -> KV-cache tokens used
        self._undelivered = []         # [(requests, device out)] per batch
        self.stats_wall = 0.0
        self.stats = {k: {"requests": 0, "tokens": 0, "pad_lanes": 0,
                          "pad_tokens": 0, "lanes": 0,
                          "batches": 0, "seconds": 0.0}
                      for k in ("ingest", "query", "stream")}

    def _resident_quota_of(self, tenant: str) -> Optional[int]:
        return self.admission.quota(tenant).max_resident

    # -- session lifecycle --------------------------------------------
    def create_session(self, sid: str, kind: str = "online",
                       tenant: str = "default") -> None:
        if kind not in self._mgr:
            raise ValueError(
                f"no arena for session kind {kind!r} "
                "(construct the engine with stream_slots > 0?)")
        self._mgr[kind].create(sid, tenant)
        self._kind[sid] = kind
        self._tenant[sid] = tenant

    def close_session(self, sid: str) -> None:
        self.admission.cancel(sid)      # backlog + queue, flags `cancelled`
        self._cached.pop(sid, None)
        self._tenant.pop(sid, None)
        self._mgr[self._kind.pop(sid)].close(sid)

    def offload_session(self, sid: str) -> OffloadResult:
        """Explicitly push a session's state to host.  A no-op with a
        telling status for unknown / already-offloaded / never-activated
        sessions — never raises."""
        kind = self._kind.get(sid)
        if kind is None:
            return OffloadResult(sid, "unknown")
        return self._mgr[kind].offload_batch([sid])[0]

    # -- request submission -------------------------------------------
    def _on_shed(self, req: Request) -> None:
        """Admission dropped a request: release any resources its
        submit-time validation reserved (KV-cache token accounting)."""
        if req.kind == "query" and req.sid in self._cached:
            # plain decrement: every shed query (newcomer or queued
            # victim) carries a reservation made at its own submit
            self._cached[req.sid] -= req.token_len

    def _submit(self, sid: str, op: str, tokens, priority: int) -> Verdict:
        kind = self._kind[sid]
        if _OP_STATE[op] != kind:
            raise ValueError(f"op {op!r} invalid for {kind!r} session {sid!r}")
        # make (and shape-validate) the request BEFORE any reservation —
        # a validation error must raise with zero side effects
        req = self.scheduler.make_request(sid, op, tokens, priority,
                                          tenant=self._tenant[sid])
        n = req.token_len
        if op == "stream" and n > self.cfg.ccm.stream_chunk:
            # mirror the stream_step trace-time guard HERE, before the
            # request enters the queue — a trace error mid-drain would
            # abort run() after the batch was already popped
            raise ValueError(
                f"stream chunk ({n} tokens) exceeds "
                f"cfg.ccm.stream_chunk ({self.cfg.ccm.stream_chunk}); "
                "split the input")
        if op == "query":
            # queries append their tokens to the session's KV cache; the
            # cache write clamps silently past cache_len, corrupting
            # earlier rows — admit only what fits (counts queued work).
            # The reservation happens BEFORE admission so _on_shed can
            # reverse it symmetrically whether the shed request is this
            # one (shed at submit) or a queued victim it displaces.
            used = self._cached.get(sid, 0)
            if used + n > self.cache_len:
                raise ValueError(
                    f"session {sid!r} KV cache exhausted: {used} tokens "
                    f"cached + {n} requested > cache_len "
                    f"{self.cache_len}; close the session or build the "
                    "engine with a larger cache_len")
            self._cached[sid] = used + n
        return self.admission.submit_request(req)

    def ingest(self, sid, tokens, priority: int = 0) -> Verdict:
        return self._submit(sid, "ingest", tokens, priority)

    def query(self, sid, tokens, priority: int = 0) -> Verdict:
        return self._submit(sid, "query", tokens, priority)

    def stream(self, sid, tokens, priority: int = 0) -> Verdict:
        return self._submit(sid, "stream", tokens, priority)

    # -- execution -----------------------------------------------------
    def _step(self, op: str, masked: bool):
        """Jitted fused step per (op, masked).  Full-length batches take
        the unmasked program — masking costs ~10% per step (valid-mask
        attention + take-based frozen writes), so uniform traffic pays
        nothing; only genuinely ragged batches run the masked variant."""
        key = (op, masked)
        if key not in self._steps:
            self._steps[key] = self._step_factory(self.cfg, op, masked)
        return self._steps[key]

    def _make_replay(self, state_kind: str):
        """Replay a recompute-dropped session's request history into its
        (zeroed) slot: one B=1 fused step per recorded request, padded
        into the same token buckets as live traffic so replay shares the
        serve programs instead of compiling exact-length ones."""
        def replay(sid: str, slot: int, history) -> None:
            mgr = self._mgr[state_kind]
            arena = mgr.arena
            ids = jnp.asarray([slot], jnp.int32)
            for op, toks in history:
                flat = np.asarray(toks, np.int32).reshape(-1)
                L = flat.size
                tl = token_bucket(L, self._token_buckets) if self.ragged \
                    else L
                if op == "stream":
                    tl = min(tl, self.cfg.ccm.stream_chunk)
                tl = max(tl, L)
                buf = np.zeros((1, 1, tl), np.int32)
                buf[0, 0, :L] = flat
                masked = self.ragged and tl != L
                step = self._step(op, masked)
                _, arena.slabs = step(self.params, arena.slabs, ids, buf,
                                      np.asarray([L], np.int32))
            arena.mark_dirty([slot])
        return replay

    def _run_batch(self, batch: ScheduledBatch) -> None:
        mgr = self._mgr[_OP_STATE[batch.kind]]
        arena = mgr.arena
        pinned = {r.sid for r in batch.requests}
        t0 = time.perf_counter()
        slots = mgr.activate_batch([r.sid for r in batch.requests], pinned)
        ids = slots + [arena.pad_slot] * batch.pad
        # lanes padded up to the batch's token bucket; per-lane valid
        # lengths drive the masked ops (pad lanes claim the full bucket —
        # they gather/scatter the scratch row, semantics don't matter)
        toks = np.zeros((batch.bucket, 1, batch.token_len), np.int32)
        for i, r in enumerate(batch.requests):
            toks[i, 0, :r.token_len] = r.tokens[0]
        lengths = np.asarray(batch.valid_lens
                             + [batch.token_len] * batch.pad, np.int32)
        # one fused jitted program: gather rows -> vmapped op -> scatter
        # rows back into the donated slabs.  No block here: batches chain
        # through the slab dependency and overlap Python scheduling;
        # run() syncs once at the end of the drain.
        masked = self.ragged and any(vl != batch.token_len
                                     for vl in batch.valid_lens)
        step = self._step(batch.kind, masked)
        out, arena.slabs = step(self.params, arena.slabs,
                                jnp.asarray(ids, jnp.int32), toks, lengths)
        arena.mark_dirty(ids)
        dt = time.perf_counter() - t0
        # results are NOT materialized here — np.asarray(out) would
        # block on this batch's compute and serialize the drain; run()
        # converts all outs after the last dispatch (one transfer per
        # batch, per-request results become zero-copy numpy views)
        self._undelivered.append((batch.requests, out))
        for r in batch.requests:
            mgr.sessions[r.sid].n_ops += 1
            mgr.record(r.sid, r.kind, r.tokens[0])
        s = self.stats[batch.kind]
        s["requests"] += len(batch.requests)
        s["tokens"] += sum(batch.valid_lens)
        s["pad_lanes"] += batch.pad
        s["pad_tokens"] += (len(batch.requests) * batch.token_len
                            - sum(batch.valid_lens))
        s["lanes"] += batch.bucket
        s["batches"] += 1
        s["seconds"] += dt

    def run(self, max_batches: Optional[int] = None) -> int:
        """Drain the queue (or up to ``max_batches``); returns batches
        run.  After every popped batch the admission backlog is pumped —
        backpressured submits enter the queue as soon as their tokens
        fit — and the drain only ends once both the queue AND the
        pumpable backlog are empty.  Synchronizes once at the end, so
        per-kind ``seconds`` are dispatch times and the drain's wall
        clock is the true cost."""
        n = 0
        t0 = time.perf_counter()
        while max_batches is None or n < max_batches:
            # recomputed per pop: pumped backlog entries can introduce
            # tenants that were not queued when the drain started
            batch = self.scheduler.next_batch(*self.admission.lane_caps())
            if batch is None:
                if self.admission.pump():
                    continue
                break
            self.admission.note_popped(batch.requests)
            self._run_batch(batch)
            self.admission.pump()
            n += 1
        if n:
            for reqs, out in self._undelivered:
                out_np = np.asarray(out) if out is not None else None
                for i, r in enumerate(reqs):
                    # slice off bucket padding: a request padded into a
                    # larger token lane only owns its first valid_len
                    # logit rows (the rest are masked-lane garbage)
                    r.result = out_np[i, 0, :r.token_len] \
                        if out_np is not None else None
                    r.done = True
            self._undelivered.clear()
        for m in self._mgr.values():
            # unconditional: async offload_session() transfers may be in
            # flight even when this drain popped zero batches — leaving
            # them unbarriered would pin the stacked host buffers forever
            m.sync()
        if n:
            for m in self._mgr.values():
                jax.block_until_ready(jax.tree.leaves(m.arena.slabs)[0])
            self.stats_wall += time.perf_counter() - t0
        return n

    # -- introspection -------------------------------------------------
    def compile_stats(self) -> Dict[str, int]:
        """Compiled-program count per op kind (recompile-churn metric),
        summed over the masked/unmasked step variants; -1 when the jit
        cache size is unavailable (private API) — unmeasured, not zero."""
        out: Dict[str, int] = {}
        for (op, _), fn in self._steps.items():
            n = fn._cache_size() if hasattr(fn, "_cache_size") else -1
            prev = out.get(op, 0)
            out[op] = -1 if (n < 0 or prev < 0) else prev + n
        return out

    def compiled_programs(self) -> int:
        """Total compiled programs across op kinds (compile-cache churn:
        compare exact-length vs token-bucketed scheduling on the same
        traffic)."""
        return sum(max(v, 0) for v in self.compile_stats().values())

    def batch_occupancy(self) -> Dict[str, float]:
        """Mean fraction of batch lanes holding a real request, per op
        kind (1.0 = no pad lanes; higher is better batch sharing)."""
        return {k: (s["requests"] / s["lanes"] if s["lanes"] else 0.0)
                for k, s in self.stats.items()}

    def occupancy(self) -> Dict[str, float]:
        return {k: m.arena.occupancy for k, m in self._mgr.items()}

    def resident(self) -> Dict[str, int]:
        return {k: m.n_resident for k, m in self._mgr.items()}

    def queue_depth(self) -> int:
        """Requests waiting anywhere: scheduler queue + admission
        backlog (the open-loop benchmark's saturation metric)."""
        return self.scheduler.pending + len(self.admission.backlog)

    def throughput(self) -> float:
        """Overall tokens/s across all drains (synced wall clock).
        Per-kind ``stats[kind]['seconds']`` are dispatch times only."""
        total = sum(s["tokens"] for s in self.stats.values())
        return total / self.stats_wall if self.stats_wall else 0.0
