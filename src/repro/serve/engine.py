"""Multi-tenant serve engine: scheduler -> arena -> jitted session steps.

Drives the whole subsystem: requests queue in the `Scheduler`, `run`
drains them batch by batch — activate the batch's sessions (LRU
restore/offload via `SessionManager`), then one fused jitted program
per batch (`launch.serve.make_arena_step`) gathers their arena rows,
runs the vmapped op, and scatters the updated rows back, fulfilling the
requests.  Per-op stats (tokens/s, batches, padding waste),
arena occupancy and compile counts are tracked for the benchmark
harness.

Online sessions (ingest/query over ``OnlineState``) and streaming
sessions (``stream`` over ``StreamState``) live in separate arenas since
their state templates differ; ``stream_slots=0`` skips the second arena.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import serve as SRV
from repro.launch.specs import SERVE_BATCH_BUCKETS, SERVE_TOKEN_BUCKETS
from repro.models.config import ModelConfig
from repro.serve.arena import SessionArena
from repro.serve.scheduler import Request, ScheduledBatch, Scheduler
from repro.serve.session import SessionManager

_OP_STATE = {"ingest": "online", "query": "online", "stream": "stream"}


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 64,
                 cache_len: int = 256, mem_slots: Optional[int] = None,
                 max_resident: Optional[int] = None, stream_slots: int = 0,
                 stream_max_resident: Optional[int] = None,
                 batch_buckets: Sequence[int] = SERVE_BATCH_BUCKETS,
                 token_buckets="auto", aging: Optional[int] = 32):
        """``token_buckets``: ragged-batching token buckets ("auto" picks
        `launch.specs.SERVE_TOKEN_BUCKETS` for attention archs and exact-
        length grouping for SSM/hybrid; None forces exact lengths).
        ``aging``: scheduler starvation knob — a waiting request's
        effective priority improves by one per ``aging`` popped batches."""
        self.params = params
        self.cfg = cfg
        self.cache_len = cache_len
        if token_buckets == "auto":
            token_buckets = SERVE_TOKEN_BUCKETS if SRV.ragged_family(cfg) \
                else None
        elif token_buckets is not None and not SRV.ragged_family(cfg):
            raise ValueError(
                f"token buckets need masked lanes, unsupported for "
                f"family {cfg.family!r}")
        self.ragged = token_buckets is not None
        self._mgr: Dict[str, SessionManager] = {
            "online": SessionManager(
                SessionArena.for_online(cfg, n_slots, cache_len, mem_slots),
                max_resident),
        }
        if stream_slots:
            c = cfg.ccm
            if c.stream_sink + c.stream_chunk > c.stream_window:
                # stream_step raises this at trace time — mid-drain,
                # after batches were popped; fail at construction instead
                raise ValueError(
                    f"stream_sink ({c.stream_sink}) + stream_chunk "
                    f"({c.stream_chunk}) exceeds stream_window "
                    f"({c.stream_window})")
            self._mgr["stream"] = SessionManager(
                SessionArena.for_stream(cfg, stream_slots),
                stream_max_resident)
        caps = {op: self._mgr[kind].max_resident
                for op, kind in _OP_STATE.items() if kind in self._mgr}
        # a stream op must never pad past the eviction quantum — one
        # eviction per step keeps the window bounded (stream_step guard)
        self.scheduler = Scheduler(
            batch_buckets, max_batch=caps, token_buckets=token_buckets,
            max_token_len={"stream": cfg.ccm.stream_chunk}, aging=aging)
        self._steps = {}               # op kind -> jitted fn
        self._kind: Dict[str, str] = {}   # sid -> 'online' | 'stream'
        self._cached: Dict[str, int] = {}  # sid -> KV-cache tokens used
        self._undelivered = []         # [(requests, device out)] per batch
        self.stats_wall = 0.0
        self.stats = {k: {"requests": 0, "tokens": 0, "pad_lanes": 0,
                          "pad_tokens": 0, "lanes": 0,
                          "batches": 0, "seconds": 0.0}
                      for k in ("ingest", "query", "stream")}

    # -- session lifecycle --------------------------------------------
    def create_session(self, sid: str, kind: str = "online") -> None:
        if kind not in self._mgr:
            raise ValueError(
                f"no arena for session kind {kind!r} "
                "(construct the engine with stream_slots > 0?)")
        self._mgr[kind].create(sid)
        self._kind[sid] = kind

    def close_session(self, sid: str) -> None:
        self.scheduler.cancel(sid)      # flags the requests `cancelled`
        self._cached.pop(sid, None)
        self._mgr[self._kind.pop(sid)].close(sid)

    def offload_session(self, sid: str) -> None:
        """Explicitly push a session's state to host (tests/benchmarks)."""
        self._mgr[self._kind[sid]].offload(sid)

    # -- request submission -------------------------------------------
    def _submit(self, sid: str, op: str, tokens, priority: int) -> Request:
        kind = self._kind[sid]
        if _OP_STATE[op] != kind:
            raise ValueError(f"op {op!r} invalid for {kind!r} session {sid!r}")
        n = int(np.asarray(tokens).size)
        if op == "stream" and n > self.cfg.ccm.stream_chunk:
            # mirror the stream_step trace-time guard HERE, before the
            # request enters the queue — a trace error mid-drain would
            # abort run() after the batch was already popped
            raise ValueError(
                f"stream chunk ({n} tokens) exceeds "
                f"cfg.ccm.stream_chunk ({self.cfg.ccm.stream_chunk}); "
                "split the input")
        if op == "query":
            # queries append their tokens to the session's KV cache; the
            # cache write clamps silently past cache_len, corrupting
            # earlier rows — admit only what fits (counts queued work)
            used = self._cached.get(sid, 0)
            if used + n > self.cache_len:
                raise ValueError(
                    f"session {sid!r} KV cache exhausted: {used} tokens "
                    f"cached + {n} requested > cache_len "
                    f"{self.cache_len}; close the session or build the "
                    "engine with a larger cache_len")
            self._cached[sid] = used + n
        return self.scheduler.submit(sid, op, tokens, priority)

    def ingest(self, sid, tokens, priority: int = 0) -> Request:
        return self._submit(sid, "ingest", tokens, priority)

    def query(self, sid, tokens, priority: int = 0) -> Request:
        return self._submit(sid, "query", tokens, priority)

    def stream(self, sid, tokens, priority: int = 0) -> Request:
        return self._submit(sid, "stream", tokens, priority)

    # -- execution -----------------------------------------------------
    def _step(self, op: str, masked: bool):
        """Jitted fused step per (op, masked).  Full-length batches take
        the unmasked program — masking costs ~10% per step (valid-mask
        attention + take-based frozen writes), so uniform traffic pays
        nothing; only genuinely ragged batches run the masked variant."""
        key = (op, masked)
        if key not in self._steps:
            self._steps[key] = SRV.make_arena_step(self.cfg, op, masked)
        return self._steps[key]

    def _run_batch(self, batch: ScheduledBatch) -> None:
        mgr = self._mgr[_OP_STATE[batch.kind]]
        arena = mgr.arena
        pinned = {r.sid for r in batch.requests}
        t0 = time.perf_counter()
        slots = mgr.activate_batch([r.sid for r in batch.requests], pinned)
        ids = slots + [arena.pad_slot] * batch.pad
        # lanes padded up to the batch's token bucket; per-lane valid
        # lengths drive the masked ops (pad lanes claim the full bucket —
        # they gather/scatter the scratch row, semantics don't matter)
        toks = np.zeros((batch.bucket, 1, batch.token_len), np.int32)
        for i, r in enumerate(batch.requests):
            toks[i, 0, :r.token_len] = r.tokens[0]
        lengths = np.asarray(batch.valid_lens
                             + [batch.token_len] * batch.pad, np.int32)
        # one fused jitted program: gather rows -> vmapped op -> scatter
        # rows back into the donated slabs.  No block here: batches chain
        # through the slab dependency and overlap Python scheduling;
        # run() syncs once at the end of the drain.
        masked = self.ragged and any(vl != batch.token_len
                                     for vl in batch.valid_lens)
        step = self._step(batch.kind, masked)
        out, arena.slabs = step(self.params, arena.slabs,
                                jnp.asarray(ids, jnp.int32), toks, lengths)
        arena.mark_dirty(ids)
        dt = time.perf_counter() - t0
        # results are NOT materialized here — np.asarray(out) would
        # block on this batch's compute and serialize the drain; run()
        # converts all outs after the last dispatch (one transfer per
        # batch, per-request results become zero-copy numpy views)
        self._undelivered.append((batch.requests, out))
        for r in batch.requests:
            mgr.sessions[r.sid].n_ops += 1
        s = self.stats[batch.kind]
        s["requests"] += len(batch.requests)
        s["tokens"] += sum(batch.valid_lens)
        s["pad_lanes"] += batch.pad
        s["pad_tokens"] += (len(batch.requests) * batch.token_len
                            - sum(batch.valid_lens))
        s["lanes"] += batch.bucket
        s["batches"] += 1
        s["seconds"] += dt

    def run(self, max_batches: Optional[int] = None) -> int:
        """Drain the queue (or up to ``max_batches``); returns batches
        run.  Synchronizes once at the end, so per-kind ``seconds`` are
        dispatch times and the drain's wall clock is the true cost."""
        n = 0
        t0 = time.perf_counter()
        while max_batches is None or n < max_batches:
            batch = self.scheduler.next_batch()
            if batch is None:
                break
            self._run_batch(batch)
            n += 1
        if n:
            for reqs, out in self._undelivered:
                out_np = np.asarray(out) if out is not None else None
                for i, r in enumerate(reqs):
                    # slice off bucket padding: a request padded into a
                    # larger token lane only owns its first valid_len
                    # logit rows (the rest are masked-lane garbage)
                    r.result = out_np[i, 0, :r.token_len] \
                        if out_np is not None else None
                    r.done = True
            self._undelivered.clear()
            for m in self._mgr.values():
                jax.block_until_ready(jax.tree.leaves(m.arena.slabs)[0])
            self.stats_wall += time.perf_counter() - t0
        return n

    # -- introspection -------------------------------------------------
    def compile_stats(self) -> Dict[str, int]:
        """Compiled-program count per op kind (recompile-churn metric),
        summed over the masked/unmasked step variants; -1 when the jit
        cache size is unavailable (private API) — unmeasured, not zero."""
        out: Dict[str, int] = {}
        for (op, _), fn in self._steps.items():
            n = fn._cache_size() if hasattr(fn, "_cache_size") else -1
            prev = out.get(op, 0)
            out[op] = -1 if (n < 0 or prev < 0) else prev + n
        return out

    def compiled_programs(self) -> int:
        """Total compiled programs across op kinds (compile-cache churn:
        compare exact-length vs token-bucketed scheduling on the same
        traffic)."""
        return sum(max(v, 0) for v in self.compile_stats().values())

    def batch_occupancy(self) -> Dict[str, float]:
        """Mean fraction of batch lanes holding a real request, per op
        kind (1.0 = no pad lanes; higher is better batch sharing)."""
        return {k: (s["requests"] / s["lanes"] if s["lanes"] else 0.0)
                for k, s in self.stats.items()}

    def occupancy(self) -> Dict[str, float]:
        return {k: m.arena.occupancy for k, m in self._mgr.items()}

    def resident(self) -> Dict[str, int]:
        return {k: m.n_resident for k, m in self._mgr.items()}

    def throughput(self) -> float:
        """Overall tokens/s across all drains (synced wall clock).
        Per-kind ``stats[kind]['seconds']`` are dispatch times only."""
        total = sum(s["tokens"] for s in self.stats.values())
        return total / self.stats_wall if self.stats_wall else 0.0
