"""Content-addressed prefix cache: shared-prefix memory dedup.

Thousands of sessions behind one engine typically open with an
identical system-prompt / few-shot prefix.  CCM compresses that prefix
into a tiny per-session memory — byte-identical across sessions — so
compressing it once and REFERENCE-COUNTING the resulting arena row
multiplies effective arena capacity for prefix-heavy traffic.

The cache maps ``(tenant, token_count, sha1(tokens))`` to a live arena
row holding the prefix's compressed state.  `ServeEngine.create_session`
consults it when the caller passes ``prefix_tokens=``:

  * HIT  — the new session ATTACHES to the cached row
    (`SessionManager.adopt_row`: incref + resident on the shared slot,
    read-only until its first write triggers the copy-on-write break in
    `activate_batch`).  No recompression runs; admission never sees the
    prefix tokens.
  * MISS — the session is created normally and the prefix is submitted
    as a regular ingest; when that request executes, the engine inserts
    the session's row here (incref — the cache is one more holder, so
    the row survives the owner's close/offload, and the owner's next
    write COW-breaks AWAY from it, leaving the cached content frozen).

Keys are TENANT-SCOPED: one tenant's cached prefix is never attached to
another tenant's session (isolation beats the marginal extra dedup).

Eviction: LRU past ``max_entries``, plus `release_one` — the
allocation-scarcity hook `SessionManager.activate_batch` calls before
evicting a live session, which drops the least-recently-used CACHE-ONLY
row (refcount 1: no session shares it) on the starved shard.  Releasing
an entry is just a decref; a row still shared with sessions survives.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.obs import Observability
from repro.serve.arena import SessionArena

PrefixKey = Tuple[str, int, str]


@dataclasses.dataclass(frozen=True)
class PrefixEntry:
    """One cached compressed prefix: the arena row and the host-side
    bookkeeping a session needs to attach to it."""
    key: PrefixKey
    slot: int            # live arena row (the cache holds one refcount)
    shard: int           # owning arena shard (attachers pin here)
    mem_groups: int      # filled <COMP> groups the prefix compressed to


class PrefixCache:
    def __init__(self, arena: SessionArena, max_entries: int = 64,
                 obs: Optional[Observability] = None):
        if max_entries < 1:
            raise ValueError("prefix cache needs max_entries >= 1")
        self.arena = arena
        self.max_entries = max_entries
        self._entries: "OrderedDict[PrefixKey, PrefixEntry]" = OrderedDict()
        self.obs = obs if obs is not None else Observability()
        reg = self.obs.registry
        self._m_hits = reg.counter(
            "serve_prefix_dedup_hits_total",
            "sessions attached to an already-compressed prefix row "
            "instead of recompressing it")
        self._m_misses = reg.counter(
            "serve_prefix_misses_total",
            "prefix lookups that found no cached row (the prefix is "
            "compressed once and inserted on execution)")
        self._m_inserts = reg.counter(
            "serve_prefix_inserts_total",
            "compressed prefix rows pinned into the cache")
        self._m_released = reg.counter(
            "serve_prefix_released_total",
            "cache references dropped, by reason: 'capacity' = LRU past "
            "max_entries, 'scarcity' = a starved shard reclaimed a "
            "cache-only row instead of evicting a live session",
            labels=("why",))
        self._g_entries = reg.gauge(
            "serve_prefix_entries", "prefix rows currently cached")
        for why in ("capacity", "scarcity"):
            self._m_released.labels(why=why)

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def key_of(tenant: str, tokens) -> PrefixKey:
        """Content address: (tenant, length, sha1 of the int32 bytes).
        The length rides along so a (vanishingly unlikely) digest
        collision additionally needs a length collision."""
        flat = np.ascontiguousarray(
            np.asarray(tokens, np.int32).reshape(-1))
        return (tenant, int(flat.size),
                hashlib.sha1(flat.tobytes()).hexdigest())

    def lookup(self, tenant: str, tokens) -> Optional[PrefixEntry]:
        """The cached row for this tenant's prefix, refreshing its LRU
        position; None (counted as a miss) when absent.  The caller
        attaches via `SessionManager.adopt_row` and then `note_hit`."""
        ent = self._entries.get(self.key_of(tenant, tokens))
        if ent is None:
            self._m_misses.inc()
            return None
        self._entries.move_to_end(ent.key)
        return ent

    def note_hit(self) -> None:
        """Count one successful dedup attach (separate from `lookup` so
        a hit the caller cannot use — e.g. an explicit-shard request
        pinned elsewhere — is not overcounted)."""
        self._m_hits.inc()

    def insert(self, tenant: str, tokens, slot: int, shard: int,
               mem_groups: int) -> PrefixEntry:
        """Pin a freshly-compressed prefix row (increfs it — the cache
        becomes one more holder).  Re-inserting an existing key is an
        LRU refresh, not a second reference.  May evict the LRU entry
        past ``max_entries``."""
        key = self.key_of(tenant, tokens)
        ent = self._entries.get(key)
        if ent is not None:
            self._entries.move_to_end(key)
            return ent
        self.arena.incref(slot)
        ent = PrefixEntry(key=key, slot=slot, shard=shard,
                          mem_groups=mem_groups)
        self._entries[key] = ent
        self._m_inserts.inc()
        while len(self._entries) > self.max_entries:
            self._release(next(iter(self._entries)), "capacity")
        self._g_entries.set(len(self._entries))
        return ent

    def release_one(self, shard: int) -> int:
        """Allocation-scarcity hook (`SessionManager.cache_release`):
        drop the least-recently-used CACHE-ONLY entry on ``shard`` —
        refcount 1 means no session shares the row, so the decref frees
        a slot immediately.  Returns rows freed (1 or 0).  Entries still
        shared with sessions are kept: releasing them would free
        nothing, and they are exactly the entries earning their keep."""
        for key, ent in self._entries.items():
            if ent.shard == shard and self.arena.refcount(ent.slot) == 1:
                self._release(key, "scarcity")
                self._g_entries.set(len(self._entries))
                return 1
        return 0

    def unpin_slot(self, slot: int) -> bool:
        """Drop the cache pin on ONE specific row
        (`SessionManager.cache_unpin`): when an eviction victim's row
        would survive on the cache reference alone, releasing the entry
        lets the eviction actually free the slot.  Unlike `release_one`
        this drops the entry regardless of refcount — the caller has
        already decided the row must go."""
        for key, ent in self._entries.items():
            if ent.slot == slot:
                self._release(key, "scarcity")
                self._g_entries.set(len(self._entries))
                return True
        return False

    def clear(self) -> None:
        """Drop every cache reference (rows shared with sessions
        survive as session-only rows)."""
        for key in list(self._entries):
            self._release(key, "capacity")
        self._g_entries.set(0)

    def _release(self, key: PrefixKey, why: str) -> None:
        ent = self._entries.pop(key)
        self.arena.free(ent.slot)          # decref; sharers keep the row
        self._m_released.labels(why=why).inc()
        self.obs.recorder.note(
            "prefix", f"released slot={ent.slot} shard={ent.shard} "
                      f"why={why}")
