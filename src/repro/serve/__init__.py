"""Multi-tenant session serving over compressed context memory.

The paper's premise — per-user context compressed into a tiny bounded
memory — is what makes packing thousands of user sessions onto one
device feasible.  This package is that serving layer:

  arena.py     — fixed-shape device slabs of per-session state with a
                 free-list and jitted pack/unpack (gather/scatter)
  admission.py — bounded ingress: per-tenant quotas (resident slots,
                 queued tokens), overflow policies (block /
                 shed-lowest-priority / reject-new), structured
                 Admitted | Queued | Shed verdicts
  scheduler.py — continuous batching: queue per-session requests, group
                 by op kind + token bucket (ragged lanes carry a
                 valid_len; priorities age to prevent starvation;
                 deadlines drain earliest-first within a priority
                 class), pad to bucketed batch sizes
  session.py   — session lifecycle + batched/async LRU host offload
                 (restore-vs-recompute cost model, optionally calibrated
                 from measured transfer/replay rates); copy-on-write
                 forks share the parent's refcounted arena row
  prefix.py    — content-addressed prefix cache: sessions opening with
                 an identical (tenant-scoped) prefix attach to one
                 shared compressed row instead of recompressing it
  pressure.py  — unified memory-pressure controller: a logical token
                 budget walked down the recompress -> offload -> shed
                 degradation ladder (cheapest lever first)
  engine.py    — the driver loop wiring admission -> scheduler ->
                 jitted steps (optionally session-sharded: one arena
                 shard per device, `shard_map` hot path)
"""
from repro.serve.admission import (Admitted, AdmissionController, Queued,
                                   Shed, TenantQuota, Verdict)
from repro.serve.arena import ArenaFull, SessionArena
from repro.serve.engine import ServeEngine
from repro.serve.prefix import PrefixCache, PrefixEntry
from repro.serve.pressure import MemoryPressureController, PressurePolicy
from repro.serve.scheduler import (Request, ScheduledBatch, Scheduler,
                                   ShardedBatch)
from repro.serve.session import (CloseResult, OffloadCostModel,
                                 OffloadResult, SessionManager)

__all__ = ["Admitted", "AdmissionController", "ArenaFull", "CloseResult",
           "MemoryPressureController", "OffloadCostModel",
           "OffloadResult", "PrefixCache", "PrefixEntry",
           "PressurePolicy", "Queued", "Request", "ScheduledBatch",
           "Scheduler", "ServeEngine", "SessionArena", "SessionManager",
           "ShardedBatch", "Shed", "TenantQuota", "Verdict"]
