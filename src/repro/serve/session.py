"""Session lifecycle + batched host offload with tenant-aware eviction.

A session is a named user stream whose state lives in one arena slot
while *resident*.  When the arena (or the ``max_resident`` budget, or
its tenant's resident-slot quota — see `serve.admission`) is exhausted,
least-recently-used victims are offloaded to host memory
(`jax.device_put` to the CPU device) and their slots freed; the next
request on an offloaded session transparently restores it.

Offload and restore are BATCHED: activation picks every victim the
batch needs up front, packs their arena rows with ONE gather, and moves
the stacked states with ONE `device_put` each way (per-victim transfers
survive as ``batched_offload=False`` — the benchmark baseline and the
bit-exactness oracle).  ``async_offload=True`` additionally skips the
blocking sync on the device->host copy, overlapping the transfer with
the engine's next scheduler pop (`sync()` is the barrier; restores of
in-flight sessions order correctly through the data dependency).

On a SHARDED arena (one row block per device — see `serve.arena`) the
manager stays global: one LRU clock, one session table, one
``max_resident`` budget.  Shard-awareness enters at three points: a
session is pinned to one shard for life (``Session.shard``, assigned at
creation and never migrated — the no-cross-device-transfer invariant),
slot scarcity is resolved PER SHARD during activation planning (a full
shard evicts its own LRU victim even while another shard has free
slots), and batched offload/restore stage host transfers per shard
(each shard's rows pack and move as their own gather + `device_put`, so
every transfer touches exactly one device; transfer counters and the
bandwidth gauges carry a ``shard`` label).

Offload -> restore is a pure device transfer of the state pytree, so a
restored session's next logits are bit-identical to never having been
offloaded — total sessions can exceed device HBM with no semantic
effect, only latency.  An optional `OffloadCostModel` compares that
transfer latency against REPLAYING the session's recorded request
history from a zero slot and drops the state entirely when recompute is
cheaper (no host copy at all); replayed state is numerically equivalent
but not bit-exact (a replay runs at batch 1, and XLA fuses differently
per batch shape), so the cost model is opt-in.

Fresh sessions carry no host tree: their slot is zero-initialised on
first activation (all state inits are zeros + zero counters).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Collection, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.launch.serve import cow_clone_slots
from repro.launch.specs import batch_bucket
from repro.obs import Observability
from repro.serve.arena import ArenaFull, SessionArena


@dataclasses.dataclass
class Session:
    sid: str
    tenant: str = "default"        # admission-quota group
    shard: int = 0                 # owning arena shard (fixed for life)
    slot: Optional[int] = None     # arena slot while resident
    host_state: Any = None         # CPU pytree while offloaded (None = zero)
    fresh: bool = True             # never activated yet
    needs_replay: bool = False     # state dropped; rebuild from history
    history: Optional[list] = None  # [(op, tokens)] when recording enabled
    history_tokens: int = 0        # running total (cost-model decision)
    last_used: int = 0             # logical LRU clock
    n_ops: int = 0
    n_offloads: int = 0
    mem_groups: int = 0            # filled <COMP> groups (host mirror of
    #                                the slot's MemState.slots; the
    #                                pressure controller's footprint and
    #                                recompress-candidate accounting)

    @property
    def resident(self) -> bool:
        return self.slot is not None


@dataclasses.dataclass(frozen=True)
class OffloadResult:
    """Structured outcome of an offload attempt.  Offloading an unknown
    or already-offloaded session is a NO-OP with a telling status — it
    used to trust callers and crash (unknown sid) or silently pass."""
    sid: str
    status: str   # offloaded | recompute | already-offloaded | fresh | unknown
    n_bytes: int = 0

    @property
    def moved(self) -> bool:
        return self.status in ("offloaded", "recompute")


@dataclasses.dataclass(frozen=True)
class CloseResult:
    """Structured outcome of closing a session.  Closing an unknown sid
    is a NO-OP with a telling status — it used to KeyError out of the
    manager (and out of `ServeEngine.close_session`) after the caller
    had already cancelled queue entries, leaving the engine's side
    tables half-torn-down."""
    sid: str
    status: str                 # closed | unknown
    was_resident: bool = False

    @property
    def closed(self) -> bool:
        return self.status == "closed"


@dataclasses.dataclass(frozen=True)
class OffloadCostModel:
    """Restore-from-host vs recompute-from-history, per session.

    The transfer path pays the state tree down AND back up
    (``2 * state_bytes / host_bandwidth``); the recompute path pays
    nothing at offload time and replays the session's recorded requests
    at restore time (``history_tokens / replay_tokens_per_s``).  Both
    rates are workload constants the operator calibrates (defaults are
    a PCIe-ish bandwidth and a small-model CPU replay rate).

    ``calibrated=True`` folds MEASURED rates back in at decision time:
    `SessionManager.effective_cost_model` overrides ``host_bandwidth``
    with the bandwidth gauge and ``replay_tokens_per_s`` with the replay
    token/seconds counters once those sensors have data, so the
    transfer-vs-recompute tradeoff tracks the hardware actually
    underneath instead of the operator's guess.

    ``latch_history``: whether a transfer-wins decision permanently
    drops the session's replay history.  Sound for static rates (history
    only grows, state bytes are constant — transfer keeps winning) but
    WRONG under calibration or any bandwidth change at runtime: a
    degraded link can flip the decision back to recompute, which needs
    the history that the latch threw away.  Set False to keep recording
    (costs host memory proportional to history)."""
    host_bandwidth: float = 8e9          # bytes/s, device<->host
    replay_tokens_per_s: float = 2e4
    calibrated: bool = False
    latch_history: bool = True

    def transfer_seconds(self, state_bytes: int) -> float:
        return 2.0 * state_bytes / self.host_bandwidth

    def replay_seconds(self, history_tokens: int) -> float:
        return history_tokens / self.replay_tokens_per_s

    def prefers_recompute(self, state_bytes: int,
                          history_tokens: int) -> bool:
        return (self.replay_seconds(history_tokens)
                < self.transfer_seconds(state_bytes))


class SessionManager:
    def __init__(self, arena: SessionArena,
                 max_resident: Optional[int] = None, *,
                 batched_offload: bool = True,
                 async_offload: bool = False,
                 cost_model: Optional[OffloadCostModel] = None,
                 replay_fn: Optional[Callable] = None,
                 resident_quota_of: Optional[Callable[[str],
                                                      Optional[int]]] = None,
                 pack_buckets: Optional[Sequence[int]] = None,
                 obs: Optional[Observability] = None):
        """``batched_offload``: move k victims with one gather + one
        `device_put` each way (False = per-victim transfers).
        ``async_offload``: don't block on the device->host copy; the
        engine overlaps it with the next scheduler pop and `sync()`s at
        drain end.  ``cost_model`` + ``replay_fn(sid, slot, history)``:
        drop state instead of transferring when replaying the session's
        history is cheaper (enables per-session request recording).
        ``resident_quota_of(tenant)``: per-tenant resident-slot cap —
        activation evicts the tenant's own LRU session once at quota.
        ``pack_buckets``: bucket ladder for the batched offload/restore
        pack shapes — pass the engine's ``batch_buckets`` so transfers
        only ever compile at the batch dims the operator configured
        (default: `launch.specs.SERVE_BATCH_BUCKETS`)."""
        self.arena = arena
        self.pack_buckets = tuple(sorted(pack_buckets)) if pack_buckets \
            else None
        self.max_resident = min(max_resident or arena.n_slots,
                                arena.n_slots)
        self.batched_offload = batched_offload
        self.async_offload = async_offload
        self.cost_model = cost_model
        self.replay_fn = replay_fn
        self.resident_quota_of = resident_quota_of or (lambda tenant: None)
        self.sessions: Dict[str, Session] = {}
        self._clock = 0
        # async transfers not yet synced: [host buffer, n transfer rows,
        # shard, {sids whose state rides the buffer}].  The sid set is
        # how close() severs a closed session from a copy still on the
        # wire — entries with no surviving sids are dropped instead of
        # resurrecting host rows at the next sync() (the buffer itself
        # completes safely under jax's own reference).
        self._inflight: List[Any] = []
        # optional hook the engine wires to the prefix cache: called
        # with a shard id when activation planning needs a free slot,
        # returns the number of cache-only rows released (0 or 1) —
        # dropping a cached prefix nobody references is cheaper than
        # evicting a live session
        self.cache_release: Optional[Callable[[int], int]] = None
        # slot-targeted variant: drop the cache pin on ONE specific row
        # (returns True if an entry held it).  Needed when an eviction
        # victim's row would otherwise stay alive on a cache pin alone —
        # evicting the session then frees nothing and activation starves
        self.cache_unpin: Optional[Callable[[int], bool]] = None
        self._host = jax.devices("cpu")[0]
        self._device = jax.local_devices()[0]
        self._state_bytes = sum(
            math.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
            for leaf in jax.tree.leaves(arena.template))
        self.obs = obs if obs is not None else Observability()
        reg = self.obs.registry
        # engines with several arenas (online + stream) share one
        # registry; declaration is idempotent so these families
        # aggregate across managers
        self._m_bytes = reg.counter(
            "offload_bytes_total",
            "state bytes transferred device<->host, pack padding "
            "included (actual wire bytes)", labels=("dir", "shard"))
        self._m_seconds = reg.counter(
            "offload_transfer_seconds_total",
            "host seconds around the transfer: true (blocked) time on "
            "synchronous offloads, dispatch time on async offloads and "
            "restores", labels=("dir", "shard"))
        self._m_sessions = reg.counter(
            "offload_sessions_total",
            "sessions moved device<->host", labels=("dir", "shard"))
        self._m_decisions = reg.counter(
            "offload_decisions_total",
            "cost-model offload decisions (transfer vs recompute); "
            "absent when no cost model is wired", labels=("decision",))
        self._m_replays = reg.counter(
            "offload_replay_sessions_total",
            "recompute-dropped sessions rebuilt from request history")
        self._m_replay_tokens = reg.counter(
            "offload_replay_tokens_total",
            "tokens re-executed by restore replays")
        self._m_replay_s = reg.counter(
            "offload_replay_seconds_total",
            "seconds blocked re-executing restore replays (with "
            "offload_replay_tokens_total this measures the achieved "
            "replay rate, calibrating OffloadCostModel "
            "replay_tokens_per_s)")
        self._m_sync_s = reg.counter(
            "offload_sync_seconds_total",
            "seconds blocked in sync() barriers on async transfers")
        self._g_bw = reg.gauge(
            "offload_measured_bandwidth_bytes_per_s",
            "device->host bandwidth measured on the last synchronous "
            "offload transfer (calibrates OffloadCostModel "
            "host_bandwidth; 0 until the first blocking transfer)")
        self._g_shard_bw = reg.gauge(
            "offload_shard_bandwidth_bytes_per_s",
            "device->host bandwidth of the last measured transfer PER "
            "ARENA SHARD (each shard stages its own host copies; the "
            "unlabeled calibration gauge above stays global)",
            labels=("shard",))
        self._m_cow = reg.counter(
            "serve_cow_breaks_total",
            "copy-on-write breaks: shared arena rows cloned into fresh "
            "slots (one jitted clone per shard per activation) before a "
            "batch could write them", labels=("shard",))
        for d in ("offload", "restore"):
            for s in range(arena.n_shards):
                self._m_bytes.labels(dir=d, shard=str(s))
                self._m_seconds.labels(dir=d, shard=str(s))
                self._m_sessions.labels(dir=d, shard=str(s))
        for s in range(arena.n_shards):
            self._g_shard_bw.labels(shard=str(s))
            self._m_cow.labels(shard=str(s))

    def _count_transfer(self, direction: str, n_rows: int, n_sessions: int,
                        seconds: float, measured: bool,
                        shard: int = 0) -> None:
        """Book one device<->host transfer; ``measured`` marks a blocked
        (true wall time) transfer, which also updates the bandwidth
        gauges the cost model can be calibrated against.  ``shard`` is
        the arena shard whose rows moved (batched transfers stage per
        shard, so one call is always one shard)."""
        n_bytes = n_rows * self._state_bytes
        lab = dict(dir=direction, shard=str(shard))
        self._m_bytes.labels(**lab).inc(n_bytes)
        self._m_seconds.labels(**lab).inc(seconds)
        self._m_sessions.labels(**lab).inc(n_sessions)
        if measured and seconds > 0:
            self._g_bw.set(n_bytes / seconds)
            self._g_shard_bw.labels(shard=str(shard)).set(n_bytes / seconds)
        self.obs.recorder.note(
            direction, f"sessions={n_sessions} rows={n_rows} "
                       f"shard={shard} bytes={n_bytes} "
                       f"seconds={seconds:.6f}"
                       + (" (dispatch)" if not measured else ""))

    # -- lifecycle -----------------------------------------------------
    def create(self, sid: str, tenant: str = "default",
               shard: int = 0) -> Session:
        """``shard``: the arena shard this session is pinned to for its
        whole life (the engine places sessions least-loaded-first at
        creation; state never migrates between shards)."""
        if sid in self.sessions:
            raise ValueError(f"session {sid!r} already exists")
        if not 0 <= shard < self.arena.n_shards:
            raise ValueError(f"shard {shard} out of range "
                             f"[0, {self.arena.n_shards})")
        sess = Session(sid=sid, tenant=tenant, shard=shard,
                       history=[] if self.cost_model is not None else None)
        self.sessions[sid] = sess
        return sess

    def shard_load(self) -> List[int]:
        """Open sessions per shard (resident or not) — the engine's
        least-loaded placement signal."""
        load = [0] * self.arena.n_shards
        for s in self.sessions.values():
            load[s.shard] += 1
        return load

    def close(self, sid: str) -> CloseResult:
        """Tear a session down; unknown sids are a structured no-op
        (`CloseResult(status="unknown")`), not a KeyError.  Host-side
        references — an async-offloaded state buffer still in flight, a
        retained replay history — are dropped NOW rather than riding
        along until the dict entry is garbage-collected, so closing an
        offloaded session actually releases its host memory at the next
        `sync()` instead of stranding it."""
        sess = self.sessions.pop(sid, None)
        if sess is None:
            return CloseResult(sid, "unknown")
        was_resident = sess.resident
        if was_resident:
            self.arena.free(sess.slot)
            sess.slot = None
        sess.host_state = None
        sess.history = None
        sess.needs_replay = False
        # sever the sid from async transfers still on the wire: a
        # buffer carrying ONLY closed sessions is dropped outright (the
        # copy completes under jax's own reference and is then
        # collected) so sync() never books bandwidth for — or retains —
        # state nobody can restore
        if self._inflight:
            for ent in self._inflight:
                ent[3].discard(sid)
            self._inflight = [e for e in self._inflight if e[3]]
        return CloseResult(sid, "closed", was_resident=was_resident)

    # -- forks / shared rows -------------------------------------------
    def fork(self, parent_sid: str, child_sid: str,
             tenant: Optional[str] = None) -> Session:
        """Copy-on-write fork: the child starts as a byte-identical
        branch of the parent at zero device cost.  A RESIDENT parent's
        arena row is shared (incref — the row is read-only until one of
        them writes, at which point `activate_batch` clones it); an
        OFFLOADED parent's host tree is shared by reference (immutable
        on host — each restore scatters into its own fresh slot); a
        recompute-dropped parent propagates ``needs_replay`` with the
        copied history.  The child pins to the parent's shard — forks
        never cross a device boundary."""
        parent = self.sessions.get(parent_sid)
        if parent is None:
            raise ValueError(f"unknown parent session {parent_sid!r}")
        child = self.create(child_sid, tenant or parent.tenant,
                            parent.shard)
        self._clock += 1
        child.last_used = self._clock
        if parent.history is not None:
            child.history = list(parent.history)
        child.history_tokens = parent.history_tokens
        child.mem_groups = parent.mem_groups
        child.n_ops = parent.n_ops
        if parent.resident:
            self.arena.incref(parent.slot)
            child.slot = parent.slot
            child.fresh = False
        elif parent.host_state is not None:
            child.host_state = parent.host_state   # shared immutable tree
            child.fresh = False
            # the parent's state may still be an async transfer on the
            # wire — the child's restore must order behind it too
            for ent in self._inflight:
                if parent_sid in ent[3]:
                    ent[3].add(child_sid)
        elif parent.needs_replay:
            child.needs_replay = True
            child.fresh = False
        # else: parent never activated — the child is fresh too (both
        # zero-init on first activation)
        self.obs.recorder.note(
            "fork", f"parent={parent_sid} child={child_sid} "
                    f"shard={parent.shard} "
                    f"shared_slot={parent.slot if parent.resident else None}")
        return child

    def adopt_row(self, sid: str, tenant: str, shard: int, slot: int,
                  mem_groups: int = 0) -> Session:
        """Create a session attached to an EXISTING live arena row
        (prefix-cache dedup hit): increfs the row and starts the session
        resident on it, read-only until its first write COW-breaks."""
        sess = self.create(sid, tenant, shard)
        self.arena.incref(slot)
        sess.slot = slot
        sess.fresh = False
        sess.mem_groups = mem_groups
        self._clock += 1
        sess.last_used = self._clock
        return sess

    def slot_sharers(self, slot: int) -> List[str]:
        """Resident sids currently holding ``slot`` (refcount holders
        that are sessions; a prefix-cache entry can hold one more)."""
        return sorted(s.sid for s in self.sessions.values()
                      if s.slot == slot)

    @property
    def n_resident(self) -> int:
        return sum(1 for s in self.sessions.values() if s.resident)

    def n_resident_of(self, tenant: str) -> int:
        return sum(1 for s in self.sessions.values()
                   if s.resident and s.tenant == tenant)

    def record(self, sid: str, op: str, tokens: np.ndarray) -> None:
        """Append a delivered request to the session's replay history
        (no-op unless the cost model enabled recording).  ``tokens`` is
        retained as-is — callers hand over an array nothing mutates
        (the engine passes the queue's private request copy)."""
        sess = self.sessions.get(sid)
        if sess is not None and sess.history is not None:
            sess.history.append((op, tokens))
            sess.history_tokens += int(np.asarray(tokens).size)

    def _bucket(self, k: int) -> int:
        """Pack/transfer bucket for k rows, on the configured ladder."""
        if self.pack_buckets is None:
            return max(batch_bucket(k), k)
        return max(batch_bucket(k, self.pack_buckets), k)

    # -- residency -----------------------------------------------------
    def activate(self, sid: str, pinned: Collection[str] = ()) -> int:
        """Ensure ``sid`` is resident (restoring / evicting as needed)
        and return its slot.  Sessions in ``pinned`` are never evicted —
        pass the current batch's sids so co-scheduled sessions survive."""
        return self.activate_batch([sid], pinned)[0]

    def activate_batch(self, sids, pinned: Collection[str] = ()) -> list:
        """Make every session in ``sids`` resident and return their slots.

        Four phases, each one device dispatch for the whole batch:
        (1) plan — walk the batch in order, picking every eviction
        victim up front (tenant-quota LRU first, then global LRU for
        the ``max_resident`` budget, then the owning SHARD's LRU when
        that shard is out of free slots — a full shard evicts its own
        victim even while other shards have room, since sessions never
        migrate).  Slot scarcity is REFCOUNT-AWARE: evicting a session
        that shares its row only frees the slot when no other holder
        remains, and cache-only prefix rows are released (engine-wired
        ``cache_release`` hook) before any live session is evicted.
        Batch sessions sitting on a SHARED row additionally reserve a
        fresh slot for their copy-on-write break — a write must never
        scatter into a row with refcount > 1; (2) evict — ONE batched
        offload of all victims (staged per shard, one transfer per
        unique row inside `offload_batch`); (3) COW-break — clone every
        still-shared batch row into its reserved slot with one jitted
        `cow_clone_slots` per shard and drop the reference on the
        shared original; (4) admit — allocate slots on each session's
        own shard, zero fresh sessions with one batched scatter,
        restore offloaded sessions with one stacked `device_put` +
        scatter per shard, and replay recompute-dropped sessions from
        their history."""
        untouchable = set(pinned) | set(sids)
        res = {s.sid: s for s in self.sessions.values() if s.resident}
        victims: List[Session] = []
        avail = [self.arena.shard_free(s)
                 for s in range(self.arena.n_shards)]
        # planned refcounts: eviction decrefs are staged here so the
        # planner knows which evictions actually free a slot (a shared
        # row survives until its last holder goes)
        plan_ref: Dict[int, int] = {}

        def ref_left(slot: int) -> int:
            return plan_ref.get(slot, self.arena.refcount(slot))

        def evict_one(pool, why="batch size exceeds arena capacity"):
            cands = [s for s in pool if s.sid not in untouchable]
            if not cands:
                raise ArenaFull(f"no evictable session: {why}")
            v = min(cands, key=lambda s: s.last_used)
            victims.append(v)
            del res[v.sid]
            plan_ref[v.slot] = ref_left(v.slot) - 1
            if plan_ref[v.slot] == 0:
                avail[v.shard] += 1
            return v

        def make_room(shard: int, why: str) -> None:
            while avail[shard] == 0:
                if self.cache_release is not None \
                        and self.cache_release(shard):
                    avail[shard] += 1
                    continue
                v = evict_one([s for s in res.values()
                               if s.shard == shard], why=why)
                # the victim's row may stay alive on a prefix-cache pin
                # alone — drop that pin too, else the eviction frees no
                # slot and the loop starves out of candidates
                if (plan_ref.get(v.slot, 0) > 0
                        and self.cache_unpin is not None
                        and self.cache_unpin(v.slot)):
                    plan_ref[v.slot] -= 1
                    if plan_ref[v.slot] == 0:
                        avail[v.shard] += 1

        need: List[str] = []
        cow: List[Session] = []
        cow_sids = set()
        for sid in sids:
            sess = self.sessions[sid]
            self._clock += 1
            sess.last_used = self._clock
            if sess.resident:
                # a batch session on a shared row needs a private copy
                # before the step's scatter — reserve a slot for the
                # COW break on its own shard
                if sid not in cow_sids and ref_left(sess.slot) > 1:
                    cow_sids.add(sid)
                    cow.append(sess)
                    make_room(sess.shard,
                              why=f"shard {sess.shard} has no free slot "
                                  "for a copy-on-write break")
                    avail[sess.shard] -= 1
                continue
            if sid in need:
                continue
            quota = self.resident_quota_of(sess.tenant)
            if quota is not None:
                while sum(1 for s in res.values()
                          if s.tenant == sess.tenant) >= quota:
                    evict_one([s for s in res.values()
                               if s.tenant == sess.tenant])
            while len(res) >= self.max_resident:
                evict_one(res.values())
            make_room(sess.shard,
                      why=f"shard {sess.shard} has no free slot and "
                          "no evictable resident")
            res[sid] = sess          # planned resident
            need.append(sid)
            avail[sess.shard] -= 1

        if victims:
            self.offload_batch([v.sid for v in victims])
        if cow:
            self._cow_break(cow)

        fresh_slots, replay, restore = [], [], []
        for sid in need:
            sess = self.sessions[sid]
            sess.slot = self.arena.alloc(sess.shard)
            if sess.host_state is not None:
                restore.append(sess)
            elif sess.needs_replay:
                fresh_slots.append(sess.slot)
                replay.append(sess)
            else:
                # fresh (never activated) — offload always leaves either
                # host_state or needs_replay, so nothing else reaches here
                fresh_slots.append(sess.slot)
            sess.fresh = False
        if fresh_slots:
            self.arena.reset_slots(fresh_slots)
        if restore:
            self._restore_batch(restore)
        for sess in replay:
            if self.replay_fn is None:
                raise RuntimeError(
                    f"session {sess.sid!r} needs replay but no replay_fn "
                    "is wired (cost model dropped its state)")
            t0 = self.obs.clock.now()
            self.replay_fn(sess.sid, sess.slot, sess.history or [])
            # replay steps donate+replace slab buffers, so blocking on a
            # current leaf bounds the whole replay — the seconds counter
            # must see true time or the calibrated replay rate inflates
            jax.block_until_ready(jax.tree.leaves(self.arena.slabs)[0])
            self._m_replay_s.inc(self.obs.clock.now() - t0)
            sess.needs_replay = False
            self._m_replays.inc()
            self._m_replay_tokens.inc(sess.history_tokens)
            self.obs.recorder.note(
                "replay", f"sid={sess.sid} tokens={sess.history_tokens}")
        return [self.sessions[sid].slot for sid in sids]

    def _cow_break(self, sess_list: List[Session]) -> None:
        """Clone each session's shared row into a freshly allocated slot
        on its own shard (one jitted `cow_clone_slots` per shard, padded
        to a bucket with scratch-row self-copies) and drop the
        reference on the shared original — the siblings' row is never
        written.  Sessions whose row stopped being shared since
        planning (a sibling was evicted or closed meanwhile) keep their
        slot; the conservative reservation is simply unused."""
        todo = [s for s in sess_list if self.arena.shared(s.slot)]
        by_shard: Dict[int, List[Session]] = {}
        for sess in todo:
            by_shard.setdefault(sess.shard, []).append(sess)
        for shard in sorted(by_shard):
            group = by_shard[shard]
            src = [s.slot for s in group]
            dst = [self.arena.alloc(shard) for _ in group]
            n = self._bucket(len(group))
            pad = self.arena.pad_slot_of(shard)
            src_ids = np.asarray(src + [pad] * (n - len(src)), np.int32)
            dst_ids = np.asarray(dst + [pad] * (n - len(dst)), np.int32)
            self.arena.slabs = cow_clone_slots(
                self.arena.slabs, src_ids, dst_ids)
            for sess, new in zip(group, dst):
                old = sess.slot
                sess.slot = new
                self.arena.free(old)          # drop ref; siblings keep it
            self.arena.mark_dirty(dst)
            self._m_cow.labels(shard=str(shard)).inc(len(group))
            self.obs.recorder.note(
                "cow_break", f"shard={shard} rows={len(group)} "
                             f"src={src} dst={dst}")

    # -- offload -------------------------------------------------------
    def _classify(self, sid: str) -> Optional[OffloadResult]:
        """Structured no-op verdicts; None = resident, proceed."""
        sess = self.sessions.get(sid)
        if sess is None:
            return OffloadResult(sid, "unknown")
        if sess.resident:
            return None
        if sess.host_state is not None or sess.needs_replay:
            return OffloadResult(sid, "already-offloaded")
        return OffloadResult(sid, "fresh")

    def effective_cost_model(self) -> Optional[OffloadCostModel]:
        """The cost model with measured rates folded in.  With
        ``calibrated=False`` (or no model) this is ``cost_model``
        verbatim; with ``calibrated=True`` the operator constants are
        only the cold-start fallback — ``host_bandwidth`` comes from the
        bandwidth gauge and ``replay_tokens_per_s`` from the replay
        token/seconds counters once each sensor has data."""
        cm = self.cost_model
        if cm is None or not cm.calibrated:
            return cm
        kw = {}
        bw = float(self._g_bw.value)
        if bw > 0:
            kw["host_bandwidth"] = bw
        tokens = float(self._m_replay_tokens.value)
        seconds = float(self._m_replay_s.value)
        if tokens > 0 and seconds > 0:
            kw["replay_tokens_per_s"] = tokens / seconds
        return dataclasses.replace(cm, **kw) if kw else cm

    def _drop_for_recompute(self, sess: Session) -> bool:
        """True when the cost model chose recompute: state dropped, slot
        freed, nothing transferred."""
        if (self.cost_model is None or self.replay_fn is None
                or sess.history is None):
            return False
        cm = self.effective_cost_model()
        if not cm.prefers_recompute(self._state_bytes,
                                    sess.history_tokens):
            if cm.latch_history:
                # history only grows and state bytes are constant, so
                # under STATIC rates once the transfer wins it wins
                # forever — drop the retained token arrays and stop
                # recording (bounds host memory; the session is
                # transfer-only from here on).  Calibrated rates move at
                # runtime — a degraded link can flip the decision back —
                # so latching is policy-controlled via ``latch_history``.
                sess.history = None
            self._m_decisions.labels(decision="transfer").inc()
            return False
        self._m_decisions.labels(decision="recompute").inc()
        self.arena.free(sess.slot)
        sess.slot = None
        sess.host_state = None
        sess.needs_replay = True
        sess.n_offloads += 1
        return True

    def offload(self, sid: str) -> OffloadResult:
        """Per-victim offload: one gather + one `device_put` for ONE
        session (the ``batched_offload=False`` path and the batched
        path's bit-exactness oracle)."""
        verdict = self._classify(sid)
        if verdict is not None:
            return verdict
        sess = self.sessions[sid]
        if self._drop_for_recompute(sess):
            return OffloadResult(sid, "recompute")
        state = self.arena.read_slot(sess.slot)
        t0 = self.obs.clock.now()
        host = jax.device_put(state, self._host)
        if self.async_offload:
            self._inflight.append([host, 1, sess.shard, {sid}])
        else:
            host = jax.block_until_ready(host)
        self._count_transfer("offload", 1, 1, self.obs.clock.now() - t0,
                             measured=not self.async_offload,
                             shard=sess.shard)
        sess.host_state = host
        self.arena.free(sess.slot)
        sess.slot = None
        sess.n_offloads += 1
        return OffloadResult(sid, "offloaded", n_bytes=self._state_bytes)

    def offload_batch(self, sids: Sequence[str]) -> List[OffloadResult]:
        """Move k resident sessions to host with ONE arena gather and
        ONE `device_put` per SHARD (vs k of each on the per-victim
        path).  Victims are grouped by owning shard so every gather
        reads one device's row block and every `device_put` moves one
        device's bytes; each shard's batch is padded up to a
        `batch_bucket` with that shard's scratch row so only bucketed
        pack shapes compile."""
        if not self.batched_offload:
            return [self.offload(sid) for sid in sids]
        results: Dict[str, OffloadResult] = {}
        todo: List[Session] = []
        seen = set()
        for sid in sids:
            if sid in seen:      # dup sid: one verdict, one transfer
                continue
            seen.add(sid)
            verdict = self._classify(sid)
            if verdict is not None:
                results[sid] = verdict
                continue
            sess = self.sessions[sid]
            if self._drop_for_recompute(sess):
                results[sid] = OffloadResult(sid, "recompute")
            else:
                todo.append(sess)
        by_shard: Dict[int, List[Session]] = {}
        for sess in todo:
            by_shard.setdefault(sess.shard, []).append(sess)
        for shard in sorted(by_shard):
            group = by_shard[shard]
            # sessions sharing one row (COW siblings never diverged)
            # stage ONE transfer lane for that row; every sibling's
            # host_state references the same lane
            lane_of: Dict[int, int] = {}
            uniq: List[int] = []
            for sess in group:
                if sess.slot not in lane_of:
                    lane_of[sess.slot] = len(uniq)
                    uniq.append(sess.slot)
            n = self._bucket(len(uniq))
            ids = uniq + [self.arena.pad_slot_of(shard)] * (n - len(uniq))
            packed = self.arena.pack(ids)
            t0 = self.obs.clock.now()
            host = jax.device_put(packed, self._host)
            if self.async_offload:
                self._inflight.append(
                    [host, n, shard, {s.sid for s in group}])
            else:
                host = jax.block_until_ready(host)
            self._count_transfer("offload", n, len(group),
                                 self.obs.clock.now() - t0,
                                 measured=not self.async_offload,
                                 shard=shard)
            row_host: Dict[int, Any] = {}
            for sess in group:
                if sess.slot not in row_host:
                    i = lane_of[sess.slot]
                    row_host[sess.slot] = jax.tree.map(
                        lambda x, i=i: x[i], host)
                sess.host_state = row_host[sess.slot]
                self.arena.free(sess.slot)
                sess.slot = None
                sess.n_offloads += 1
                results[sess.sid] = OffloadResult(
                    sess.sid, "offloaded", n_bytes=self._state_bytes)
        out, emitted = [], set()
        for sid in sids:
            if sid not in emitted:
                emitted.add(sid)
                out.append(results[sid])
            elif results[sid].moved:
                # a later duplicate observes the first occurrence's
                # effect — exactly what sequential per-victim calls
                # would report
                out.append(OffloadResult(sid, "already-offloaded"))
            else:
                out.append(results[sid])
        return out

    def _restore_batch(self, sess_list: List[Session]) -> None:
        """Stack k host states, move them up with ONE `device_put`, and
        scatter them into their slots with one arena unpack — per SHARD
        (each group padded to a bucket; pad lanes land on the owning
        shard's scratch row), so every upload targets one device."""
        by_shard: Dict[int, List[Session]] = {}
        for sess in sess_list:
            by_shard.setdefault(sess.shard, []).append(sess)
        for shard in sorted(by_shard):
            group = by_shard[shard]
            slots = [s.slot for s in group]
            n = self._bucket(len(slots))
            ids = slots + [self.arena.pad_slot_of(shard)] * (n - len(slots))
            hosts = [s.host_state for s in group]
            pad = n - len(hosts)

            def stack(*leaves):
                rows = [np.asarray(x) for x in leaves]
                rows += [rows[0]] * pad   # scratch lanes: content ignored
                return np.stack(rows)

            stacked = jax.tree.map(stack, *hosts)
            t0 = self.obs.clock.now()
            if self.arena.placed:
                # mesh-sharded slabs: hand the scatter uncommitted host
                # rows — jit moves them to the owning devices itself; a
                # device_put committed to one device would conflict with
                # the multi-device slab operand
                dev = stacked
            else:
                dev = jax.device_put(stacked, self._device)
            self.arena.unpack(ids, dev)
            # dispatch time only: blocking here to measure the true copy
            # would serialize restore against the batch that triggered it
            self._count_transfer("restore", n, len(group),
                                 self.obs.clock.now() - t0, measured=False,
                                 shard=shard)
            for sess in group:
                sess.host_state = None

    def sync(self) -> None:
        """Barrier for ``async_offload`` transfers still in flight.

        Also the async path's bandwidth sensor: dispatch timestamps say
        nothing about the wire, so async transfers used to never touch
        the bandwidth gauge and a ``calibrated`` cost model ran blind on
        exactly the configuration built for throughput.  The barrier is
        the one place async transfer time is actually observed — we
        attribute the in-flight bytes over the blocked interval.  Since
        copies overlap engine compute before the barrier, blocked time
        can be shorter than wire time, making this an EFFECTIVE
        (overlap-discounted) bandwidth rather than raw link speed —
        which is the cost the async engine actually pays per transfer,
        i.e. the right quantity for the transfer-vs-recompute call."""
        if not self._inflight:
            return
        t0 = self.obs.clock.now()
        rows = 0
        shard_rows: Dict[int, int] = {}
        for t, n, shard, _sids in self._inflight:
            jax.block_until_ready(t)
            rows += n
            shard_rows[shard] = shard_rows.get(shard, 0) + n
        self._inflight.clear()
        dt = self.obs.clock.now() - t0
        self._m_sync_s.inc(dt)
        if dt > 0 and rows:
            self._g_bw.set(rows * self._state_bytes / dt)
            # attribute the blocked interval to each shard by its share
            # of the in-flight rows (one barrier covers all shards)
            for shard, r in shard_rows.items():
                self._g_shard_bw.labels(shard=str(shard)).set(
                    r * self._state_bytes / dt)
