"""Session lifecycle + LRU host offload.

A session is a named user stream whose state lives in one arena slot
while *resident*.  When the arena (or the ``max_resident`` budget) is
exhausted, the least-recently-used resident session is offloaded to host
memory (`jax.device_put` to the CPU device) and its slot freed; the next
request on that session transparently restores it.  Offload -> restore
is a pure device transfer of the state pytree, so a restored session's
next logits are bit-identical to never having been offloaded — total
sessions can exceed device HBM with no semantic effect, only latency.

Fresh sessions carry no host tree: their slot is zero-initialised on
first activation (all state inits are zeros + zero counters).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Collection, Dict, Optional

import jax

from repro.serve.arena import ArenaFull, SessionArena


@dataclasses.dataclass
class Session:
    sid: str
    slot: Optional[int] = None     # arena slot while resident
    host_state: Any = None         # CPU pytree while offloaded (None = zero)
    fresh: bool = True             # never activated yet
    last_used: int = 0             # logical LRU clock
    n_ops: int = 0
    n_offloads: int = 0

    @property
    def resident(self) -> bool:
        return self.slot is not None


class SessionManager:
    def __init__(self, arena: SessionArena,
                 max_resident: Optional[int] = None):
        self.arena = arena
        self.max_resident = min(max_resident or arena.n_slots,
                                arena.n_slots)
        self.sessions: Dict[str, Session] = {}
        self._clock = 0
        self._host = jax.devices("cpu")[0]
        self._device = jax.local_devices()[0]

    # -- lifecycle -----------------------------------------------------
    def create(self, sid: str) -> Session:
        if sid in self.sessions:
            raise ValueError(f"session {sid!r} already exists")
        sess = Session(sid=sid)
        self.sessions[sid] = sess
        return sess

    def close(self, sid: str) -> None:
        sess = self.sessions.pop(sid)
        if sess.resident:
            self.arena.free(sess.slot)

    @property
    def n_resident(self) -> int:
        return sum(1 for s in self.sessions.values() if s.resident)

    # -- residency -----------------------------------------------------
    def activate(self, sid: str, pinned: Collection[str] = ()) -> int:
        """Ensure ``sid`` is resident (restoring / evicting as needed)
        and return its slot.  Sessions in ``pinned`` are never evicted —
        pass the current batch's sids so co-scheduled sessions survive."""
        return self.activate_batch([sid], pinned)[0]

    def activate_batch(self, sids, pinned: Collection[str] = ()) -> list:
        """Make every session in ``sids`` resident and return their slots.

        Fresh sessions are zeroed with ONE batched scatter (and skipped
        entirely when their slot was never dirtied) — the per-batch hot
        path does no per-session device work unless a restore is due."""
        fresh_slots = []
        slots = []
        for sid in sids:
            sess = self.sessions[sid]
            self._clock += 1
            sess.last_used = self._clock
            if sess.resident:
                slots.append(sess.slot)
                continue
            while (self.n_resident >= self.max_resident
                   or self.arena.n_free == 0):
                self._evict_lru(pinned)
            slot = self.arena.alloc()
            if sess.fresh and sess.host_state is None:
                fresh_slots.append(slot)
            else:
                self.arena.write_slot(
                    slot, jax.device_put(sess.host_state, self._device))
                sess.host_state = None
            sess.slot = slot
            sess.fresh = False
            slots.append(slot)
        if fresh_slots:
            self.arena.reset_slots(fresh_slots)
        return slots

    def offload(self, sid: str) -> None:
        """Move a resident session's state to host and free its slot."""
        sess = self.sessions[sid]
        if not sess.resident:
            return
        state = self.arena.read_slot(sess.slot)
        sess.host_state = jax.block_until_ready(
            jax.device_put(state, self._host))
        self.arena.free(sess.slot)
        sess.slot = None
        sess.n_offloads += 1

    def _evict_lru(self, pinned: Collection[str]) -> None:
        candidates = [s for s in self.sessions.values()
                      if s.resident and s.sid not in pinned]
        if not candidates:
            raise ArenaFull(
                "no evictable session: batch size exceeds arena capacity")
        victim = min(candidates, key=lambda s: s.last_used)
        self.offload(victim.sid)
