"""Admission control: bounded ingress, per-tenant quotas, overflow policy.

The paper's premise is inference "in a limited memory space": the arena
caps resident sessions, but without admission control the *queue* is
unbounded and `ArenaFull` can surface mid-drain.  This module makes
overflow a first-class contract: every `ServeEngine.submit` returns a
structured verdict and nothing past this layer can run out of room.

  Admitted  — the request is in the scheduler queue (possibly after
              shedding strictly-lower-priority victims, listed on the
              verdict).
  Queued    — backpressured (``block`` policy): held in an ingress
              backlog outside the scheduler queue; `pump()` admits it
              once queued-token capacity frees (the engine pumps after
              every popped batch).
  Shed      — dropped with a reason; the request is flagged
              ``shed``/``done`` and will never run.

Quotas are per *tenant* (a group of sessions — one user, org, or API
key; sessions default to the ``"default"`` tenant):

  max_resident       — cap on the tenant's device-resident sessions per
                       arena.  Enforced from both sides: batch formation
                       never takes more of a tenant's lanes than its
                       quota (`Scheduler.next_batch(tenant_lane_caps)`),
                       and activation evicts the tenant's own LRU
                       session once it is at quota
                       (`SessionManager.activate_batch`).
  max_queued_tokens  — cap on the tenant's tokens in the scheduler
                       queue; the controller's own ``max_queued_tokens``
                       bounds the global queue the same way.

Overflow policies (what happens when a submit would break a bound):

  block                — hold the request in the ingress backlog; FIFO
                         per tenant (cross-tenant overtaking allowed, so
                         one saturated tenant never head-of-line-blocks
                         the rest).
  shed-lowest-priority — make room by shedding queued requests whose
                         *effective* priority (aging included) is
                         STRICTLY lower than the incoming request's;
                         among those, victims that are ALREADY LATE on
                         their deadline go first (their SLO is lost
                         either way — `Scheduler.shed_preference_key`),
                         and victims are only ever a session's queued
                         suffix (program order is never punctured).  If
                         no such victim frees enough room, the incoming
                         request itself is shed.
  reject-new           — shed the incoming request immediately.

A request whose tokens alone exceed an applicable bound is shed under
every policy (``block`` would otherwise hold it forever).

Verdicts are counted in the engine's `MetricsRegistry`
(``admission_verdicts_total{verdict=...}``); the legacy ``stats`` dict
is a read-only view over those counters.  Every counter is MONOTONIC —
a pumped request counts under ``pumped``, not ``admitted`` (direct
admissions only), so rates computed from scrapes are always
well-defined.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.obs import MetricsRegistry
from repro.serve.pressure import MemoryPressureController
from repro.serve.scheduler import Request, Scheduler

POLICIES = ("block", "shed-lowest-priority", "reject-new")


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission bounds (None = unbounded).

    ``slo_seconds`` turns the quota into an SLO policy: a submit that
    carries no explicit deadline gets one derived as ``now + slo``.  A
    float applies to every op kind; a dict maps kinds (``ingest`` /
    ``query`` / ``stream``) to their own SLO, with missing kinds left
    deadline-less."""
    max_resident: Optional[int] = None       # resident sessions per arena
    max_queued_tokens: Optional[int] = None  # tokens in the scheduler queue
    slo_seconds: Union[float, Dict[str, float], None] = None

    def __post_init__(self):
        if self.max_resident is not None and self.max_resident < 1:
            raise ValueError("max_resident quota must be >= 1 "
                             "(0 would make the tenant unschedulable)")
        if self.max_queued_tokens is not None and self.max_queued_tokens < 1:
            raise ValueError("max_queued_tokens quota must be >= 1")
        slos = (self.slo_seconds.values()
                if isinstance(self.slo_seconds, dict)
                else (self.slo_seconds,))
        for s in slos:
            if s is not None and not s > 0:
                raise ValueError(f"slo_seconds must be > 0, got {s!r}")

    def slo_for(self, kind: str) -> Optional[float]:
        """Deadline budget (seconds from submit) for an op kind."""
        if isinstance(self.slo_seconds, dict):
            return self.slo_seconds.get(kind)
        return self.slo_seconds


@dataclasses.dataclass(frozen=True)
class Verdict:
    """Structured outcome of an engine submit; ``request`` is the live
    handle (poll ``request.done`` / read ``request.result``).

    ``shard`` is the arena shard that owns the request's session (the
    engine fills it in from the session's fixed placement) — callers on
    a sharded engine can route follow-up control calls
    (`close_session` / `offload_session`) with it; it is ``None`` when
    the controller is used standalone."""
    request: Request
    shard: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class Admitted(Verdict):
    shed_victims: Tuple[Request, ...] = ()   # displaced queued requests


@dataclasses.dataclass(frozen=True)
class Queued(Verdict):
    reason: str = ""                         # which bound backpressured it


@dataclasses.dataclass(frozen=True)
class Shed(Verdict):
    reason: str = ""


class AdmissionController:
    """Bounded ingress in front of a `Scheduler`.

    The controller owns the token accounting for the scheduler queue
    (incremented at enqueue, decremented when the engine reports popped
    batches / cancels) and the ``block``-policy backlog.  It never
    touches device state — pure control plane, which is what lets the
    property harness fuzz it exhaustively."""

    def __init__(self, scheduler: Scheduler, policy: str = "block",
                 max_queued_tokens: Optional[int] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None,
                 on_shed: Optional[Callable[[Request], None]] = None,
                 max_backlog: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 pressure: Optional[MemoryPressureController] = None):
        """``max_backlog``: cap on ``block``-policy backlog ENTRIES —
        beyond it even the block policy sheds newcomers, so a producer
        that ignores ``Queued`` verdicts cannot grow host memory without
        bound.  None (default) leaves the backlog unbounded (the
        caller's waiters are then the backstop).

        ``pressure``: a `serve.pressure.MemoryPressureController` adds
        the device-memory budget as one more admission bound — and,
        crucially, a memory deficit is handed to the controller's
        degradation ladder (recompress -> offload) BEFORE any overflow
        policy sheds work; only an unrelievable remainder reaches the
        shed path."""
        if policy not in POLICIES:
            raise ValueError(f"unknown overflow policy {policy!r}; "
                             f"pick one of {POLICIES}")
        self.scheduler = scheduler
        self.policy = policy
        self.max_queued_tokens = max_queued_tokens
        self.max_backlog = max_backlog
        self.quotas = dict(quotas or {})
        self.default_quota = default_quota or TenantQuota()
        self.pressure = pressure
        self._on_shed = on_shed
        self._queued_tokens: Dict[str, int] = {}   # per tenant, in queue
        self._queued_total = 0
        self._backlog: List[Request] = []          # block-policy holding pen
        # bounded audit trail of shed-lowest-priority decisions, recorded
        # AT decision time (candidate preference order, lateness flags,
        # deficits, chosen victims) — the property harness replays the
        # two-pass selection from it and asserts the victims match
        self.shed_decisions: collections.deque = collections.deque(
            maxlen=512)
        self._verdicts = (metrics or MetricsRegistry()).counter(
            "admission_verdicts_total",
            "admission outcomes: admitted (direct), queued "
            "(backpressured), pumped (backlog -> queue), shed_new "
            "(newcomer dropped), shed_victim (queued request displaced)",
            labels=("verdict",))
        for v in ("admitted", "queued", "pumped", "shed_new",
                  "shed_victim"):       # explicit zeros in exports
            self._verdicts.labels(verdict=v)

    @property
    def stats(self) -> Dict[str, int]:
        """Legacy counter view over ``admission_verdicts_total``.  All
        values are monotonic: ``admitted`` counts DIRECT admissions;
        backlog entries admitted later count under ``pumped`` (their
        backpressure is already counted under ``queued``)."""
        v = self._verdicts
        return {"admitted": int(v.labels(verdict="admitted").value),
                "queued": int(v.labels(verdict="queued").value),
                "shed_new": int(v.labels(verdict="shed_new").value),
                "shed_victims": int(v.labels(verdict="shed_victim").value),
                "pumped": int(v.labels(verdict="pumped").value)}

    # -- introspection -------------------------------------------------
    def quota(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, self.default_quota)

    def queued_tokens(self, tenant: Optional[str] = None) -> int:
        if tenant is None:
            return self._queued_total
        return self._queued_tokens.get(tenant, 0)

    @property
    def backlog(self) -> Tuple[Request, ...]:
        return tuple(self._backlog)

    def lane_caps(self) -> Tuple[Optional[Dict[str, Optional[int]]],
                                 Optional[int]]:
        """(per-tenant batch lane caps, default cap) for
        `Scheduler.next_batch`.  Explicitly-quota'd tenants appear in
        the dict with their own ``max_resident`` — including ``None``
        for residency-unbounded, which fully OVERRIDES the default
        (matches quota()/SessionManager eviction semantics); every
        other tenant falls back to the default quota's cap.  O(quotas),
        independent of queue depth — called before every batch pop."""
        caps: Dict[str, Optional[int]] = {
            t: q.max_resident for t, q in self.quotas.items()}
        default = self.default_quota.max_resident
        if default is None and not any(c is not None
                                       for c in caps.values()):
            return None, None
        return caps, default

    # -- bound checks --------------------------------------------------
    def _headroom(self, tenant: str) -> Tuple[Optional[int], Optional[str]]:
        """(smallest applicable token headroom, limiting bound's name);
        (None, None) when unbounded."""
        room, bound = None, None
        q = self.quota(tenant).max_queued_tokens
        if q is not None:
            room = q - self.queued_tokens(tenant)
            bound = f"tenant {tenant!r} queued-token quota ({q})"
        if self.max_queued_tokens is not None:
            g = self.max_queued_tokens - self._queued_total
            if room is None or g < room:
                room, bound = g, (f"global queued-token bound "
                                  f"({self.max_queued_tokens})")
        if self.pressure is not None:
            m = self.pressure.headroom()
            if room is None or m < room:
                room, bound = m, (f"device-memory budget "
                                  f"({self.pressure.capacity} tokens)")
        return room, bound

    def _hard_cap(self, tenant: str) -> Optional[int]:
        """Largest request this tenant could EVER fit (even into an
        empty queue); None = unbounded."""
        caps = [c for c in (self.quota(tenant).max_queued_tokens,
                            self.max_queued_tokens) if c is not None]
        if self.pressure is not None:
            caps.append(self.pressure.capacity)
        return min(caps) if caps else None

    # -- submit --------------------------------------------------------
    def submit(self, sid: str, kind: str, tokens, priority: int = 0,
               tenant: str = "default",
               deadline: Optional[float] = None) -> Verdict:
        if deadline is None:
            slo = self.quota(tenant).slo_for(kind)
            if slo is not None:
                deadline = self.scheduler.clock.now() + slo
        return self.submit_request(
            self.scheduler.make_request(sid, kind, tokens, priority,
                                        tenant, deadline=deadline))

    def submit_request(self, req: Request) -> Verdict:
        """Admit an already-made request (the engine makes the request
        first so validation errors raise before any resource is
        reserved against it)."""
        tenant = req.tenant
        hard = self._hard_cap(tenant)
        if hard is not None and req.token_len > hard:
            return self._shed_new(
                req, f"request ({req.token_len} tokens) exceeds the "
                     f"smallest applicable queued-token bound ({hard}); "
                     "it could never be admitted")
        if self.pressure is not None:
            # THE LADDER: a memory deficit goes to the degradation
            # controller (recompress, then offload) before any policy
            # is allowed to shed or backpressure for memory.  Only the
            # memory bound is relievable — queued-token bounds are not
            # about device memory and fall through untouched.
            mh = self.pressure.headroom()
            if req.token_len > mh:
                self.pressure.relieve(req.token_len - mh)
        room, bound = self._headroom(tenant)
        blocked_behind = self.policy == "block" and any(
            r.tenant == tenant for r in self._backlog)
        if (room is None or req.token_len <= room) and not blocked_behind:
            return self._admit(req)
        if self.policy == "reject-new":
            return self._shed_new(req, f"over {bound} (reject-new)")
        if self.policy == "block":
            if (self.max_backlog is not None
                    and len(self._backlog) >= self.max_backlog):
                return self._shed_new(
                    req, f"backlog full ({self.max_backlog} entries)")
            self._backlog.append(req)
            self._verdicts.labels(verdict="queued").inc()
            # honest reason: a request that FITS current headroom was
            # backpressured purely by per-tenant FIFO ordering, not by
            # the bound _headroom happened to name
            fits_now = room is None or req.token_len <= room
            return Queued(req, reason=(
                f"FIFO behind tenant {tenant!r} backlog" if fits_now
                else bound))
        return self._shed_for(req, bound)

    # -- policy internals ----------------------------------------------
    def _admit(self, req: Request, victims: Tuple[Request, ...] = (),
               from_pump: bool = False) -> Admitted:
        self.scheduler.enqueue(req)
        self._queued_tokens[req.tenant] = (
            self._queued_tokens.get(req.tenant, 0) + req.token_len)
        self._queued_total += req.token_len
        # pump admissions get their own counter so both stay monotonic
        # (the old dict did `admitted -= 1` here, breaking rate queries)
        self._verdicts.labels(
            verdict="pumped" if from_pump else "admitted").inc()
        return Admitted(req, shed_victims=victims)

    def _shed_new(self, req: Request, reason: str) -> Shed:
        req.shed = True
        req.done = True
        self._verdicts.labels(verdict="shed_new").inc()
        if self._on_shed is not None:
            self._on_shed(req)
        return Shed(req, reason=reason)

    def _shed_for(self, req: Request, bound: Optional[str]) -> Verdict:
        """shed-lowest-priority: displace queued session-tail requests
        whose effective priority is STRICTLY lower (numerically greater
        — lower drains first) than the incoming request's.  Candidates
        are preferred in `Scheduler.shed_preference_key` order: already-
        late requests first (their deadline is lost whether they run or
        not), then lowest effective priority, tightest deadline,
        youngest.  Victim selection is transactional: the set is chosen
        first and applied only if it frees enough room — otherwise
        NOTHING is shed except the newcomer.  A tenant-quota deficit can
        only be covered by the same tenant's work; the global bound
        sheds from anywhere.  Only current session tails are considered
        (one shed never cascades into a session's earlier program)."""
        new_eff = req.priority       # just arrived: no aging yet
        tq = self.quota(req.tenant).max_queued_tokens
        need_t = 0 if tq is None else max(
            0, self.queued_tokens(req.tenant) + req.token_len - tq)
        need_g = 0 if self.max_queued_tokens is None else max(
            0, self._queued_total + req.token_len - self.max_queued_tokens)
        if self.pressure is not None:
            # residual memory deficit (the ladder already did what it
            # could in submit_request) — shedding queued tokens frees
            # budget 1:1 from any tenant, so it folds into the global
            # pass
            need_g = max(need_g,
                         req.token_len - self.pressure.headroom())
        cands = [r for r in self.scheduler.session_tails(
                     self.scheduler.queued())
                 if self.scheduler.effective_priority(r) > new_eff
                 and r.sid != req.sid]   # never puncture the submitter's
                                         # own program to admit its tail
        now = self.scheduler.clock.now()
        cands.sort(key=lambda r: self.scheduler.shed_preference_key(r, now))
        decision = {
            "now": now,
            "incoming": {"sid": req.sid, "tenant": req.tenant,
                         "priority": req.priority,
                         "token_len": req.token_len,
                         "deadline": req.deadline},
            "need_t": need_t, "need_g": need_g,
            "candidates": [
                {"seq": r.seq, "sid": r.sid, "tenant": r.tenant,
                 "token_len": r.token_len, "deadline": r.deadline,
                 "eff": self.scheduler.effective_priority(r),
                 "late": self.scheduler.is_late(r, now)}
                for r in cands],
        }
        victims: List[Request] = []
        vset = set()
        freed_t = freed_g = 0
        for r in cands:                      # pass 1: tenant deficit
            if freed_t >= need_t:
                break
            if r.tenant == req.tenant:
                victims.append(r)
                vset.add(id(r))
                freed_t += r.token_len
                freed_g += r.token_len
        for r in cands:                      # pass 2: global deficit
            if freed_g >= need_g:
                break
            if id(r) not in vset:
                victims.append(r)
                vset.add(id(r))
                freed_g += r.token_len
        decision["victims"] = [v.seq for v in victims]
        decision["ok"] = not (freed_t < need_t or freed_g < need_g)
        self.shed_decisions.append(decision)
        if freed_t < need_t or freed_g < need_g:
            return self._shed_new(
                req, f"over {bound}; no strictly-lower-priority victims "
                     "free enough room")
        self._remove_from_queue(victims)
        for v in victims:
            v.shed = True
            v.done = True
            self._verdicts.labels(verdict="shed_victim").inc()
            if self._on_shed is not None:
                self._on_shed(v)
        return self._admit(req, tuple(victims))

    # -- queue bookkeeping (engine callbacks) --------------------------
    def _debit(self, reqs) -> None:
        """Tokens left the scheduler queue (popped / dropped / shed)."""
        for r in reqs:
            self._queued_tokens[r.tenant] = (
                self._queued_tokens.get(r.tenant, 0) - r.token_len)
            self._queued_total -= r.token_len

    def _remove_from_queue(self, reqs) -> None:
        self.scheduler.drop(reqs)
        self._debit(reqs)

    def note_popped(self, reqs) -> None:
        """The engine popped these requests into a batch — their tokens
        left the queue (the scheduler already removed them)."""
        self._debit(reqs)

    def cancel(self, sid: str) -> List[Request]:
        """Drop a closed session's work everywhere: backlog entries and
        queued requests (accounting adjusted); returns all dropped."""
        held = [r for r in self._backlog if r.sid == sid]
        self._backlog = [r for r in self._backlog if r.sid != sid]
        for r in held:
            r.cancelled = True
            r.done = True
        # debit BEFORE scheduler.cancel drops them from the queue
        self._debit(self.scheduler.queued(sid=sid))
        return held + self.scheduler.cancel(sid)

    def pump(self) -> List[Request]:
        """Drain the backlog into the queue while capacity allows: FIFO
        per tenant (an entry never overtakes an earlier entry of its own
        tenant — program order per session is preserved a fortiori),
        cross-tenant overtaking allowed.  Returns the requests admitted
        by this pump."""
        admitted: List[Request] = []
        blocked_tenants = set()
        remaining: List[Request] = []
        for r in self._backlog:
            if r.tenant in blocked_tenants:
                remaining.append(r)
                continue
            room, _ = self._headroom(r.tenant)
            if room is None or r.token_len <= room:
                self._admit(r, from_pump=True)
                admitted.append(r)
            else:
                blocked_tenants.add(r.tenant)
                remaining.append(r)
        self._backlog = remaining
        return admitted
