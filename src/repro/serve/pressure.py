"""Unified memory-pressure controller: recompress -> offload -> shed.

The paper's premise is inference "in a limited memory space", but the
serve stack's three memory levers used to act in isolation: compression
ratio was static config (`CCMConfig.comp_len`), the `OffloadCostModel`
ran on operator-guessed constants, and admission shed load without ever
trying to compress or offload first.  This module makes degradation a
LADDER walked strictly cheapest-first whenever the device-memory budget
runs short:

  1. recompress — collapse resident LRU sessions' compressed memory at
     a higher ratio (`core.memory.recompress_memory` through the jitted
     `launch.serve.recompress_arena_slots` arena step).  Costs only
     reconstruction fidelity; the session stays resident and attendable.
  2. offload    — push idle resident LRU sessions' state to host via
     the (optionally calibrated) `OffloadCostModel` path
     (`SessionManager.offload_batch`).  Costs restore latency later.
  3. shed       — only once the first two levers are exhausted does the
     admission controller drop work (its existing overflow policies).

The BUDGET is logical, in token units — arena slabs are fixed-shape, so
recompression cannot free physical bytes; what it frees is *accounted*
memory, exactly like vLLM's block watermark: a session's footprint is
its live KV-cache tokens plus ``mem_groups * comp_len`` memory tokens,
and queued request tokens count as memory already promised.  Admission
enforces ``used + incoming <= capacity_tokens`` as one more bound
(`AdmissionController._headroom`); on a deficit it calls
:meth:`MemoryPressureController.relieve` BEFORE falling into its shed
policy, and the engine's drain hook (`maybe_relieve`) walks the same
ladder when utilization crosses the high watermark.

Every lever decision is appended to :attr:`decisions` (bounded ring)
and counted in the metrics registry
(``pressure_decisions_total{lever=...}``,
``pressure_tokens_freed_total{lever=...}``, used/utilization gauges) —
the property suite proves LADDER MONOTONICITY from that log: a ``shed``
entry may only appear with zero remaining recompress AND offload
candidates at decision time (tests/test_pressure_properties.py).

The controller is pure control plane over injected callables — no
engine import, no device access of its own — so the hypothesis suite
can drive it against fully synthetic session tables as well as the real
`ServeEngine` (which wires the callables in its constructor).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, Iterable, List, Optional

from repro.obs import Observability

LEVERS = ("recompress", "offload", "shed")


@dataclasses.dataclass(frozen=True)
class PressurePolicy:
    """Degradation-ladder configuration (all token counts are logical
    memory tokens — see module docstring).

    ``capacity_tokens``: the device-memory budget admission enforces.
    ``recompress_group``: ratio step per recompression — every ``group``
    consecutive filled <COMP> groups collapse into one.
    ``min_groups``: never recompress a session below this many filled
    groups (a quality guardrail: the last group standing is the whole
    conversation).
    ``enable_recompress`` / ``enable_offload``: lever switches — with
    both off the budget is still enforced but every deficit goes
    straight to shed (the controller-off benchmark arm).
    ``high_watermark`` / ``low_watermark``: the engine's drain hook
    relieves down to ``low * capacity`` once usage exceeds
    ``high * capacity`` (post-admission footprint growth — an admitted
    ingest materializes ``comp_len`` memory tokens its queue estimate
    did not include — is re-absorbed here).
    ``offload_late_sessions``: widen the offload lever to sessions whose
    pending work is ENTIRELY past its deadline (``unsalvageable_fn``) —
    their SLO is lost whether they stay resident or not, so they are
    preferred AHEAD of idle LRU victims.  Off by default: without
    deadlines the lever keeps its idle-sessions-only behavior."""
    capacity_tokens: int
    recompress_group: int = 2
    min_groups: int = 2
    enable_recompress: bool = True
    enable_offload: bool = True
    high_watermark: float = 0.9
    low_watermark: float = 0.75
    offload_late_sessions: bool = False

    def __post_init__(self):
        if self.capacity_tokens < 1:
            raise ValueError("capacity_tokens must be >= 1")
        if self.recompress_group < 2:
            raise ValueError("recompress_group must be >= 2 "
                             "(1 would free nothing)")
        if self.min_groups < 1:
            raise ValueError("min_groups must be >= 1")
        if not 0.0 < self.low_watermark <= self.high_watermark <= 1.0:
            raise ValueError(
                f"need 0 < low_watermark <= high_watermark <= 1, got "
                f"low={self.low_watermark} high={self.high_watermark}")


class MemoryPressureController:
    """Walks the recompress -> offload ladder against a token budget.

    Injected callables (the engine wires these; tests may pass plain
    lambdas over synthetic tables):

      sessions_fn()       -> iterable of session records with ``.sid``,
                             ``.resident``, ``.last_used``,
                             ``.mem_groups`` (`serve.session.Session`
                             satisfies this)
      footprint_fn(sid)   -> resident device-memory tokens of a session
                             (KV-cache tokens + mem_groups * comp_len)
      queued_tokens_fn()  -> tokens currently promised in the scheduler
                             queue (admission accounting)
      has_queued_fn(sid)  -> whether the session has pending work
                             anywhere (queue or backlog) — such sessions
                             are never offload victims: they would
                             restore on the very next batch
      recompress_fn(sid)  -> perform the device recompression, return
                             tokens freed (0 = nothing to shrink)
      offload_fn(sid)     -> perform the offload, return an
                             `OffloadResult`-like with ``.moved``
      unsalvageable_fn(sid) -> whether the session's pending work is
                             entirely past its deadline (optional; only
                             consulted when
                             ``policy.offload_late_sessions`` is on —
                             such sessions become PREFERRED offload
                             victims despite having queued work)
    """

    def __init__(self, policy: PressurePolicy, *,
                 sessions_fn: Callable[[], Iterable],
                 footprint_fn: Callable[[str], int],
                 queued_tokens_fn: Callable[[], int],
                 has_queued_fn: Callable[[str], bool],
                 recompress_fn: Callable[[str], int],
                 offload_fn: Callable[[str], object],
                 unsalvageable_fn: Optional[Callable[[str], bool]] = None,
                 obs: Optional[Observability] = None,
                 max_decisions: int = 4096):
        self.policy = policy
        self._sessions = sessions_fn
        self._footprint = footprint_fn
        self._queued_tokens = queued_tokens_fn
        self._has_queued = has_queued_fn
        self._recompress = recompress_fn
        self._offload = offload_fn
        self._unsalvageable = unsalvageable_fn or (lambda sid: False)
        self.obs = obs if obs is not None else Observability()
        # bounded decision ring: the property suite reads whole (small)
        # traces; a long-lived engine keeps only the recent window
        self.decisions: Deque[Dict] = deque(maxlen=max_decisions)
        self._seq = 0
        reg = self.obs.registry
        self._m_decisions = reg.counter(
            "pressure_decisions_total",
            "memory-pressure ladder decisions: recompress / offload "
            "lever firings, and shed handoffs (a deficit survived both "
            "levers and fell through to the admission shed policy)",
            labels=("lever",))
        self._m_freed = reg.counter(
            "pressure_tokens_freed_total",
            "logical memory tokens freed per lever", labels=("lever",))
        self._g_used = reg.gauge(
            "pressure_memory_used_tokens",
            "logical device-memory tokens in use: queued request tokens "
            "+ resident session footprints (KV cache + compressed "
            "memory)")
        self._g_util = reg.gauge(
            "pressure_memory_utilization",
            "pressure_memory_used_tokens / the policy's capacity_tokens")
        for lever in LEVERS:                 # explicit zeros in exports
            self._m_decisions.labels(lever=lever)
        for lever in ("recompress", "offload"):
            self._m_freed.labels(lever=lever)

    # -- accounting ----------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.policy.capacity_tokens

    def used_tokens(self) -> int:
        """Queued request tokens + every resident session's footprint."""
        used = self._queued_tokens()
        for sess in self._sessions():
            if sess.resident:
                used += self._footprint(sess.sid)
        return used

    def headroom(self) -> int:
        """Tokens left under the budget (negative = overshoot from
        post-admission footprint growth; the drain hook re-absorbs)."""
        return self.capacity - self.used_tokens()

    def utilization(self) -> float:
        return self.used_tokens() / self.capacity

    # -- candidate enumeration (LRU order) ------------------------------
    def _lru(self, sessions) -> List:
        return sorted(sessions, key=lambda s: s.last_used)

    def recompress_candidates(self) -> List:
        """Resident sessions whose memory would actually shrink, LRU
        first (empty when the lever is disabled)."""
        if not self.policy.enable_recompress:
            return []
        r = self.policy.recompress_group
        out = []
        for s in self._sessions():
            if not s.resident or s.mem_groups < self.policy.min_groups:
                continue
            if -(-s.mem_groups // r) < s.mem_groups:   # frees >= 1 group
                out.append(s)
        return self._lru(out)

    def offload_candidates(self) -> List:
        """Resident sessions with a nonzero footprint that are safe to
        offload: idle ones (queued work would restore on the next batch,
        so offloading them frees nothing durable) and — with
        ``policy.offload_late_sessions`` — sessions whose pending work
        is ENTIRELY past deadline.  The late ones are preferred first
        (their SLO is lost either way; an idle session may still serve a
        future request on time), then LRU within each group."""
        if not self.policy.enable_offload:
            return []
        late_ok = self.policy.offload_late_sessions
        out = []
        for s in self._sessions():
            if not s.resident or self._footprint(s.sid) <= 0:
                continue
            if not self._has_queued(s.sid):
                out.append((1, s))
            elif late_ok and self._unsalvageable(s.sid):
                out.append((0, s))
        out.sort(key=lambda g_s: (g_s[0], g_s[1].last_used))
        return [s for _, s in out]

    # -- the ladder -----------------------------------------------------
    def _decide(self, lever: str, **fields) -> None:
        self._seq += 1
        self.decisions.append({"seq": self._seq, "lever": lever, **fields})
        self._m_decisions.labels(lever=lever).inc()

    def relieve(self, deficit: int) -> int:
        """Free at least ``deficit`` logical tokens if the ladder's
        cheap levers can; returns tokens actually freed.  Strict order:
        every recompression candidate is consumed before the first
        offload, and a ``shed`` decision is logged ONLY when both
        candidate lists are empty and the deficit still stands — the
        monotonicity invariant the property suite checks."""
        freed = 0
        if deficit <= 0:
            return 0
        # candidates are re-enumerated per round: one recompression step
        # (group g -> ceil(g/r)) may leave the session shrinkable again,
        # and monotonicity demands EVERY such step fires before a shed
        while freed < deficit:
            cands = self.recompress_candidates()
            if not cands:
                break
            progress = False
            for sess in cands:
                if freed >= deficit:
                    break
                got = int(self._recompress(sess.sid))
                if got > 0:
                    progress = True
                    freed += got
                    self._m_freed.labels(lever="recompress").inc(got)
                    self._decide("recompress", sid=sess.sid, freed=got)
                    self.obs.recorder.note(
                        "pressure",
                        f"recompress sid={sess.sid} freed={got}")
            if not progress:     # callbacks refused: don't spin
                break
        if freed < deficit:
            for sess in self.offload_candidates():
                if freed >= deficit:
                    break
                tokens = self._footprint(sess.sid)
                # recorded BEFORE the offload runs: whether this victim
                # was taken despite queued work (only legal when the
                # late-sessions lever is on and the work is all late)
                late_work = self._has_queued(sess.sid)
                res = self._offload(sess.sid)
                if getattr(res, "moved", False):
                    freed += tokens
                    self._m_freed.labels(lever="offload").inc(tokens)
                    self._decide("offload", sid=sess.sid, freed=tokens,
                                 late_work=late_work)
                    self.obs.recorder.note(
                        "pressure",
                        f"offload sid={sess.sid} freed={tokens}")
        if freed < deficit:
            # both levers exhausted: whatever remains is the admission
            # policy's problem (shed / block / reject).  Candidate
            # counts are re-enumerated AT DECISION TIME so the log
            # itself witnesses "no cheaper lever was available".
            self._decide(
                "shed", deficit=deficit, freed=freed,
                unmet=deficit - freed,
                recompress_candidates=len(self.recompress_candidates()),
                offload_candidates=len(self.offload_candidates()))
            self.obs.recorder.note(
                "pressure", f"shed-handoff deficit={deficit} freed={freed}")
        self.sample_gauges()
        return freed

    def maybe_relieve(self) -> int:
        """Drain hook: once usage crosses the high watermark, relieve
        down to the low watermark (0 tokens freed otherwise)."""
        used = self.used_tokens()
        if used <= self.policy.high_watermark * self.capacity:
            self.sample_gauges()
            return 0
        target = int(self.policy.low_watermark * self.capacity)
        return self.relieve(used - target)

    def sample_gauges(self) -> None:
        used = self.used_tokens()
        self._g_used.set(used)
        self._g_util.set(used / self.capacity)
