"""Continuous-batching scheduler for session requests.

Requests (``ingest`` / ``query`` / ``stream``) queue per session and are
drained as ``ScheduledBatch``es: all requests in a batch share an op kind
and an exact token length (one jitted program per (kind, bucket, len)),
and the batch is padded up to a bucketed batch size
(`launch.specs.SERVE_BATCH_BUCKETS`, capped by the op kind's arena
capacity — the cap acts as one final bucket) so a handful of compiled
shapes covers any arrival pattern — no recompile churn as traffic
fluctuates.

Admission is FIFO-with-priority: lower ``priority`` drains first,
submission order breaks ties.  Two invariants keep batching safe:

  * program order per session — a request is only eligible once it is
    its session's earliest pending request (priority never reorders one
    session's own ops);
  * one request per session per batch — a session's state row is read
    once and written once per step, so a second op on the same session
    must wait for the next batch.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.launch.specs import SERVE_BATCH_BUCKETS, batch_bucket


@dataclasses.dataclass
class Request:
    sid: str
    kind: str                      # 'ingest' | 'query' | 'stream'
    tokens: np.ndarray             # (1, token_len) int32
    priority: int = 0              # lower drains first
    seq: int = -1                  # submission order (set by Scheduler)
    result: Any = None             # logits for query/stream; None for ingest
    done: bool = False
    cancelled: bool = False        # dropped by close_session, never ran

    @property
    def token_len(self) -> int:
        return self.tokens.shape[-1]


@dataclasses.dataclass
class ScheduledBatch:
    kind: str
    token_len: int
    bucket: int                    # padded batch size
    requests: List[Request]

    @property
    def pad(self) -> int:
        return self.bucket - len(self.requests)


class Scheduler:
    def __init__(self, batch_buckets: Sequence[int] = SERVE_BATCH_BUCKETS,
                 max_batch=None):
        """``max_batch``: int cap for every op kind, or a dict
        ``{kind: cap}`` (a kind's batch must fit its arena)."""
        self.batch_buckets = tuple(sorted(batch_buckets))
        cap = self.batch_buckets[-1]
        if max_batch is None:
            max_batch = cap
        if isinstance(max_batch, int):
            max_batch = {k: max_batch
                         for k in ("ingest", "query", "stream")}
        self.max_batch = {k: min(v, cap) for k, v in max_batch.items()}
        self._queue: List[Request] = []
        self._seq = itertools.count()

    def submit(self, sid: str, kind: str, tokens, priority: int = 0
               ) -> Request:
        if kind not in ("ingest", "query", "stream"):
            raise ValueError(f"unknown op kind {kind!r}")
        arr = np.asarray(tokens)
        if arr.ndim > 2 or (arr.ndim == 2 and arr.shape[0] != 1):
            # a (B, L) batch passed by mistake would silently become one
            # concatenated request
            raise ValueError(
                f"tokens must be one sequence (1-D or (1, L)); "
                f"got shape {arr.shape}")
        # copy: the queue holds tokens until run(); a no-copy view of a
        # caller buffer would alias later writes
        toks = np.array(arr, np.int32, copy=True).reshape(1, -1)
        req = Request(sid=sid, kind=kind, tokens=toks, priority=priority,
                      seq=next(self._seq))
        self._queue.append(req)
        return req

    @property
    def pending(self) -> int:
        return len(self._queue)

    def cancel(self, sid: str) -> List[Request]:
        """Drop every queued request for a session (closed sessions must
        not reach a batch).  Dropped requests are flagged ``cancelled``
        (with ``done=True``) so waiters observe the outcome; returns
        them."""
        dropped = [r for r in self._queue if r.sid == sid]
        self._queue = [r for r in self._queue if r.sid != sid]
        for r in dropped:
            r.cancelled = True
            r.done = True
        return dropped

    def _eligible(self) -> List[Request]:
        """Pending requests that are their session's earliest, ordered by
        (priority, submission)."""
        earliest = {}
        for r in self._queue:
            if r.sid not in earliest or r.seq < earliest[r.sid].seq:
                earliest[r.sid] = r
        return sorted(earliest.values(), key=lambda r: (r.priority, r.seq))

    def next_batch(self) -> Optional[ScheduledBatch]:
        """Pop the next batch: head of the eligible order defines the
        (kind, token_len) key; fill with matching eligible requests."""
        elig = self._eligible()
        if not elig:
            return None
        head = elig[0]
        key: Tuple[str, int] = (head.kind, head.token_len)
        cap = self.max_batch.get(head.kind, self.batch_buckets[-1])
        taken = [r for r in elig if (r.kind, r.token_len) == key][:cap]
        taken_set = set(id(r) for r in taken)
        self._queue = [r for r in self._queue if id(r) not in taken_set]
        bucket = min(batch_bucket(len(taken), self.batch_buckets), cap)
        bucket = max(bucket, len(taken))
        return ScheduledBatch(kind=head.kind, token_len=head.token_len,
                              bucket=bucket, requests=taken)
