"""Continuous-batching scheduler for session requests.

Requests (``ingest`` / ``query`` / ``stream``) queue per session and are
drained as ``ScheduledBatch``es.  All requests in a batch share an op
kind and a *token bucket* (`launch.specs.SERVE_TOKEN_BUCKETS`): the batch
head's token length picks the bucket, and any eligible request whose
length fits is padded up to it (carrying its ``valid_len`` so the masked
session ops can freeze the pad lanes — see `core.inference`).  The batch
itself is padded up to a bucketed batch size
(`launch.specs.SERVE_BATCH_BUCKETS`, capped by the op kind's arena
capacity — the cap acts as one final bucket), so a handful of compiled
shapes covers any mixed-length arrival pattern — no recompile churn as
traffic fluctuates.  ``token_buckets=None`` restores exact token-length
grouping (required for SSM/hybrid archs, whose recurrent scans cannot
mask pad tokens).

Admission is priority-with-aging: lower *effective* priority drains
first, where a request's effective priority decreases by one for every
``aging`` batches popped since it was submitted — a starved low-priority
session always drains eventually under sustained high-priority load.
Within one effective-priority class, requests carrying a *deadline*
drain earliest-deadline-first (EDF); deadline-less requests sort after
every deadline inside the class, and submission order breaks the
remaining ties.  The whole ordering lives in ONE function —
`Scheduler.effective_key` — so aging and EDF can never disagree about
who goes first (aging still rescues a starved request: one more aging
step drops it into a strictly better class, where it beats any deadline).
Two invariants keep batching safe:

  * program order per session — a request is only eligible once it is
    its session's earliest pending request (priority never reorders one
    session's own ops);
  * one request per session per batch — a session's state row is read
    once and written once per step, so a second op on the same session
    must wait for the next batch.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.launch.specs import (SERVE_BATCH_BUCKETS, SERVE_TOKEN_BUCKETS,
                                batch_bucket, token_bucket)
from repro.obs import MetricsRegistry, MonotonicClock

_KINDS = ("ingest", "query", "stream", "fork")


@dataclasses.dataclass
class Request:
    sid: str
    kind: str                      # 'ingest' | 'query' | 'stream' | 'fork'
    tokens: np.ndarray             # (1, token_len) int32
    priority: int = 0              # lower drains first
    tenant: str = "default"        # admission-quota group (serve.admission)
    deadline: Optional[float] = None  # absolute scheduler-clock seconds by
    #                                which the result should be delivered
    #                                (None = no SLO); EDF key within the
    #                                request's effective-priority class
    shard: int = 0                 # owning arena shard (set at submit from
    #                                the session's placement; the sharded
    #                                pop groups lanes by this)
    seq: int = -1                  # submission order (set at enqueue)
    round: int = 0                 # scheduler round at enqueue (aging clock)
    result: Any = None             # logits for query/stream; None for ingest
    done: bool = False
    cancelled: bool = False        # dropped by close_session, never ran
    shed: bool = False             # dropped by admission overflow, never ran
    fork_child: Optional[str] = None  # kind='fork' only: the child sid to
    #                                create when this request executes.
    #                                Fork requests queue on the PARENT sid
    #                                (zero tokens) so the snapshot point
    #                                respects the parent's program order

    @property
    def token_len(self) -> int:
        """The request's true (valid) token length — unchanged by any
        bucket padding applied at batch time."""
        return self.tokens.shape[-1]


@dataclasses.dataclass
class ScheduledBatch:
    kind: str
    token_len: int                 # padded (bucketed) token length
    bucket: int                    # padded batch size
    requests: List[Request]

    @property
    def pad(self) -> int:
        return self.bucket - len(self.requests)

    @property
    def valid_lens(self) -> List[int]:
        """Per-request valid token lengths (<= ``token_len``)."""
        return [r.token_len for r in self.requests]


@dataclasses.dataclass
class ShardedBatch:
    """One sharded pop: a same-kind, same-token-bucket, same-BATCH-bucket
    sub-batch PER arena shard (index = shard id).  Every sub-batch shares
    ``token_len`` and ``bucket`` so the stacked (n_shards, bucket, ...)
    lanes form one rectangular `shard_map` program; a shard with no
    eligible work contributes an empty sub-batch (all lanes padded with
    its scratch row)."""
    kind: str
    token_len: int                 # padded (bucketed) token length
    bucket: int                    # padded PER-SHARD batch size
    shards: List[ScheduledBatch]   # index s = shard s's sub-batch

    @property
    def requests(self) -> List[Request]:
        """All requests across shards, shard-major."""
        return [r for sb in self.shards for r in sb.requests]

    @property
    def n_requests(self) -> int:
        return sum(len(sb.requests) for sb in self.shards)


class Scheduler:
    def __init__(self, batch_buckets: Sequence[int] = SERVE_BATCH_BUCKETS,
                 max_batch=None,
                 token_buckets: Optional[Sequence[int]] = SERVE_TOKEN_BUCKETS,
                 max_token_len: Union[int, Dict[str, int], None] = None,
                 aging: Optional[int] = 32,
                 metrics: Optional[MetricsRegistry] = None,
                 edf: bool = True, clock=None):
        """``max_batch``: int cap for every op kind, or a dict
        ``{kind: cap}`` (a kind's batch must fit its arena).

        ``token_buckets``: padded token lengths for ragged batching; None
        disables padding (batches group by exact token length).
        ``max_token_len``: int or ``{kind: cap}`` upper bound on the
        padded length (e.g. a stream op must never pad past
        ``cfg.ccm.stream_chunk``); a request's own length is always
        allowed.  ``aging``: every ``aging`` popped batches a waiting
        request's effective priority improves by one (None/0 disables —
        pure FIFO-within-priority, which can starve).

        ``edf``: order deadline-carrying requests earliest-deadline-
        first WITHIN their effective-priority class (`effective_key`).
        With no deadlines submitted the ordering is identical either
        way, so the default is on.  ``clock`` is the time source for
        lateness checks (`is_late`) — the engine passes its
        observability clock so simulated traffic runs on logical
        time."""
        self.batch_buckets = tuple(sorted(batch_buckets))
        cap = self.batch_buckets[-1]
        if max_batch is None:
            max_batch = cap
        if isinstance(max_batch, int):
            max_batch = {k: max_batch for k in _KINDS}
        self.max_batch = {k: min(v, cap) for k, v in max_batch.items()}
        self.token_buckets = None if token_buckets is None \
            else tuple(sorted(token_buckets))
        if max_token_len is None:
            max_token_len = {}
        if isinstance(max_token_len, int):
            max_token_len = {k: max_token_len for k in _KINDS}
        self.max_token_len = dict(max_token_len)
        self.aging = int(aging) if aging else 0
        self.edf = bool(edf)
        self.clock = clock if clock is not None else MonotonicClock()
        self._queue: List[Request] = []
        self._held: set = set()
        self._seq = itertools.count()
        self._round = 0
        reg = metrics or MetricsRegistry()
        self._m_aged = reg.counter(
            "sched_aging_promotions_total",
            "requests popped into a batch with an aged (improved) "
            "effective priority — the anti-starvation mechanism firing")
        self._m_popped = reg.counter(
            "sched_batches_popped_total",
            "batches popped from the queue (the aging clock)")

    def make_request(self, sid: str, kind: str, tokens, priority: int = 0,
                     tenant: str = "default",
                     deadline: Optional[float] = None) -> Request:
        """Validate and wrap a submission WITHOUT queueing it — the
        admission controller holds backpressured requests outside the
        queue and enqueues them when capacity frees (``seq`` is assigned
        at enqueue time so drain order follows admission order).
        ``deadline`` is an absolute time on this scheduler's clock; it
        rides the request unchanged through every admission verdict."""
        if kind not in _KINDS:
            raise ValueError(f"unknown op kind {kind!r}")
        if deadline is not None and not math.isfinite(deadline):
            raise ValueError(f"deadline must be finite, got {deadline!r}")
        arr = np.asarray(tokens)
        if arr.ndim > 2 or (arr.ndim == 2 and arr.shape[0] != 1):
            # a (B, L) batch passed by mistake would silently become one
            # concatenated request
            raise ValueError(
                f"tokens must be one sequence (1-D or (1, L)); "
                f"got shape {arr.shape}")
        # copy: the queue holds tokens until run(); a no-copy view of a
        # caller buffer would alias later writes
        toks = np.array(arr, np.int32, copy=True).reshape(1, -1)
        return Request(sid=sid, kind=kind, tokens=toks, priority=priority,
                       tenant=tenant, deadline=deadline)

    def enqueue(self, req: Request) -> Request:
        """Admit a made request into the queue (stamps seq + aging round)."""
        req.seq = next(self._seq)
        req.round = self._round
        self._queue.append(req)
        return req

    def submit(self, sid: str, kind: str, tokens, priority: int = 0,
               tenant: str = "default",
               deadline: Optional[float] = None) -> Request:
        return self.enqueue(
            self.make_request(sid, kind, tokens, priority, tenant,
                              deadline=deadline))

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def round(self) -> int:
        """Logical aging clock: number of batches popped so far."""
        return self._round

    def aged_steps(self, req: Request, round_: Optional[int] = None) -> int:
        """How many aging promotions ``req`` has earned by ``round_``
        (default: the current round) — the ONE place the aging formula
        lives."""
        if not self.aging:
            return 0
        return ((self._round if round_ is None else round_)
                - req.round) // self.aging

    def effective_priority(self, req: Request) -> int:
        """Priority after aging: drops by one per ``aging`` rounds waited."""
        return req.priority - self.aged_steps(req)

    def effective_key(self, req: Request) -> Tuple[int, float, int]:
        """THE scheduler ordering — every drain, shed and fill decision
        sorts by this one key, so aging and EDF compose in exactly one
        place:

          (effective priority,   # aging-promoted class; strictly lower
                                 # beats ANY deadline in a higher class
           deadline,             # EDF within the class; no deadline
                                 # sorts after every deadline (+inf)
           seq)                  # submission order breaks ties

        With ``edf=False`` (or no deadline on the request) the middle
        component is +inf for everyone, which reproduces the pre-EDF
        ``(effective_priority, seq)`` ordering bit for bit.  Aging still
        rescues a starved deadline-less request: one more aging step
        drops its class below the deadline traffic's, and the first
        component dominates."""
        dl = req.deadline if (self.edf and req.deadline is not None) \
            else math.inf
        return (self.effective_priority(req), dl, req.seq)

    def is_late(self, req: Request, now: Optional[float] = None) -> bool:
        """Whether ``req``'s deadline has already passed (deadline-less
        requests are never late)."""
        if req.deadline is None:
            return False
        return (self.clock.now() if now is None else now) > req.deadline

    def shed_preference_key(self, req: Request,
                            now: Optional[float] = None
                            ) -> Tuple[int, int, float, int]:
        """Victim-preference ordering for shed/offload levers — sort
        ascending and take from the front.  Prefer, in order: requests
        that are ALREADY LATE (their SLO is lost whether we run them or
        not), then lower effective priority, then the tightest deadline
        (closest to becoming late — least salvageable; deadline-less
        last), then the youngest submission.  Without deadlines this
        degrades to exactly the old (lowest-effective-priority,
        youngest-first) victim order."""
        eff, dl, seq = self.effective_key(req)
        late = self.edf and self.is_late(req, now)
        return (0 if late else 1, -eff, dl, -seq)

    def queued(self, tenant: Optional[str] = None,
               sid: Optional[str] = None) -> List[Request]:
        """Queued requests, optionally filtered by tenant / session."""
        return [r for r in self._queue
                if (tenant is None or r.tenant == tenant)
                and (sid is None or r.sid == sid)]

    def drop(self, reqs: Sequence[Request]) -> None:
        """Remove specific queued requests (admission shed victims).  The
        caller flags the outcome on the requests; unknown entries are
        ignored."""
        ids = set(id(r) for r in reqs)
        self._queue = [r for r in self._queue if id(r) not in ids]

    def session_tails(self, reqs: Sequence[Request]) -> List[Request]:
        """Subset of ``reqs`` that are their session's LAST queued
        request.  Shedding only ever removes a session-program suffix —
        dropping a middle request would leave later ops of the same
        session running against a state that skipped one."""
        last_seq = {}
        for r in self._queue:
            if r.sid not in last_seq or r.seq > last_seq[r.sid]:
                last_seq[r.sid] = r.seq
        return [r for r in reqs if last_seq.get(r.sid) == r.seq]

    def cancel(self, sid: str) -> List[Request]:
        """Drop every queued request for a session (closed sessions must
        not reach a batch).  Dropped requests are flagged ``cancelled``
        (with ``done=True``) so waiters observe the outcome; returns
        them."""
        dropped = [r for r in self._queue if r.sid == sid]
        self._queue = [r for r in self._queue if r.sid != sid]
        for r in dropped:
            r.cancelled = True
            r.done = True
        return dropped

    def hold(self, sid: str) -> None:
        """Gate a session's queued requests out of eligibility until
        `release`.  The engine holds a fork CHILD from `fork_session`
        until the creating fork request executes: the child's program
        starts at the fork, so no priority/deadline reordering may run a
        child op before the session exists — the cross-session half of
        the program-order invariant."""
        self._held.add(sid)

    def release(self, sid: str) -> None:
        """Lift a `hold` (the fork executed, or was cancelled/shed and
        the child's queued requests were dropped by the engine)."""
        self._held.discard(sid)

    def _eligible(self) -> List[Request]:
        """Pending requests that are their session's earliest, ordered by
        `effective_key` (effective priority, deadline-EDF, submission).
        Held sessions (fork children awaiting creation) are skipped."""
        earliest = {}
        for r in self._queue:
            if r.sid in self._held:
                continue
            if r.sid not in earliest or r.seq < earliest[r.sid].seq:
                earliest[r.sid] = r
        return sorted(earliest.values(), key=self.effective_key)

    def _head_token_len(self, head: Request) -> int:
        """Padded token length for a batch led by ``head``: its token
        bucket, capped per kind — never below the head's own length."""
        if self.token_buckets is None:
            return head.token_len
        tlen = token_bucket(head.token_len, self.token_buckets)
        cap = self.max_token_len.get(head.kind)
        if cap is not None:
            tlen = min(tlen, cap)
        return max(tlen, head.token_len)

    def next_batch(self,
                   tenant_lane_caps: Optional[Dict[str,
                                                   Optional[int]]] = None,
                   default_lane_cap: Optional[int] = None
                   ) -> Optional[ScheduledBatch]:
        """Pop the next batch: head of the eligible order defines the op
        kind and token bucket; fill with any eligible request of that
        kind whose token length fits the bucket (padded lanes carry
        their ``valid_len``).

        ``tenant_lane_caps``: max lanes per tenant in this batch; a
        tenant missing from the dict falls back to
        ``default_lane_cap``, and an explicit ``None`` value means
        uncapped (an explicit quota overrides the default).  The serve
        engine passes each tenant's resident-slot quota so a single
        batch can never pin more of a tenant's sessions than its quota
        allows — the batch-formation half of the per-tenant residency
        invariant (`serve.admission`; eviction in `SessionManager` is
        the other half)."""
        elig = self._eligible()
        if not elig:
            return None
        round0 = self._round     # the round the eligible order was built
        self._round += 1         # under, BEFORE this pop advanced aging
        self._m_popped.inc()
        head = elig[0]
        tlen = self._head_token_len(head)
        cap = self.max_batch.get(head.kind, self.batch_buckets[-1])
        if self.token_buckets is None:
            fits = [r for r in elig
                    if r.kind == head.kind and r.token_len == tlen]
        else:
            fits = [r for r in elig
                    if r.kind == head.kind and r.token_len <= tlen]
        taken, lanes_of = [], {}
        for r in fits:
            if len(taken) >= cap:
                break
            if tenant_lane_caps is not None or default_lane_cap is not None:
                tcap = (tenant_lane_caps or {}).get(r.tenant,
                                                    default_lane_cap)
                if tcap is not None and lanes_of.get(r.tenant, 0) >= tcap:
                    continue
            taken.append(r)
            lanes_of[r.tenant] = lanes_of.get(r.tenant, 0) + 1
        if self.aging:
            self._m_aged.inc(sum(
                1 for r in taken if self.aged_steps(r, round0) > 0))
        taken_set = set(id(r) for r in taken)
        self._queue = [r for r in self._queue if id(r) not in taken_set]
        bucket = min(batch_bucket(len(taken), self.batch_buckets), cap)
        bucket = max(bucket, len(taken))
        return ScheduledBatch(kind=head.kind, token_len=tlen,
                              bucket=bucket, requests=taken)

    def next_sharded_batches(self, n_shards: int,
                             tenant_lane_caps: Optional[
                                 Dict[str, Optional[int]]] = None,
                             default_lane_cap: Optional[int] = None,
                             per_shard_cap: Union[int, Dict[str, int],
                                                  None] = None,
                             max_total: Union[int, Dict[str, int],
                                              None] = None
                             ) -> Optional["ShardedBatch"]:
        """Pop ONE batch per arena shard in a single scheduling decision
        (a `ShardedBatch`): the global eligible head defines the op kind
        and token bucket exactly as in `next_batch`, then each shard
        fills its own sub-batch from the eligible requests routed to it
        (``Request.shard``), all sharing one common batch bucket — the
        max over shards, so the stacked lanes are rectangular for the
        `shard_map` hot path.  Shards with no eligible work of the
        head's kind/bucket get empty sub-batches (all-pad lanes compute
        on their scratch row).

        Counts as ONE pop for the aging clock and the popped-batches
        counter: the sharded engine retires up to ``n_shards`` sub-
        batches per drain iteration, and aging measures drain progress,
        not device count.

        ``per_shard_cap`` bounds each shard's lane count and
        ``max_total`` the pop's TOTAL lane count — each an int or a
        per-kind dict (the engine passes the per-shard slot capacity
        and the arena's ``max_resident``, so a sharded pop never pins
        more sessions than one `activate_batch` call can hold).
        ``tenant_lane_caps`` apply across the WHOLE sharded pop, not
        per shard: the engine activates every sub-batch's sessions in
        one `activate_batch` call, so the pop as a whole must not pin
        more of a tenant's sessions than its quota allows (conservative
        — a tenant spread over shards still gets at most its quota in
        lanes per pop)."""
        def _resolve(v, kind):
            if isinstance(v, dict):
                return v.get(kind)
            return v

        elig = self._eligible()
        if not elig:
            return None
        round0 = self._round
        self._round += 1
        self._m_popped.inc()
        head = elig[0]
        tlen = self._head_token_len(head)
        cap = self.max_batch.get(head.kind, self.batch_buckets[-1])
        psc = _resolve(per_shard_cap, head.kind)
        if psc is not None:
            cap = min(cap, psc)
        total_cap = _resolve(max_total, head.kind)
        if self.token_buckets is None:
            fits = [r for r in elig
                    if r.kind == head.kind and r.token_len == tlen]
        else:
            fits = [r for r in elig
                    if r.kind == head.kind and r.token_len <= tlen]
        taken: List[List[Request]] = [[] for _ in range(n_shards)]
        lanes_of: Dict[str, int] = {}
        total = 0
        for r in fits:
            if total_cap is not None and total >= total_cap:
                break
            if not 0 <= r.shard < n_shards:
                raise ValueError(
                    f"request for session {r.sid!r} routed to shard "
                    f"{r.shard}, but the pop spans {n_shards} shards")
            if len(taken[r.shard]) >= cap:
                continue             # this shard is full; others may fit
            if tenant_lane_caps is not None or default_lane_cap is not None:
                tcap = (tenant_lane_caps or {}).get(r.tenant,
                                                    default_lane_cap)
                if tcap is not None and lanes_of.get(r.tenant, 0) >= tcap:
                    continue
            taken[r.shard].append(r)
            lanes_of[r.tenant] = lanes_of.get(r.tenant, 0) + 1
            total += 1
        if self.aging:
            self._m_aged.inc(sum(
                1 for g in taken for r in g
                if self.aged_steps(r, round0) > 0))
        taken_set = set(id(r) for g in taken for r in g)
        self._queue = [r for r in self._queue if id(r) not in taken_set]
        n_max = max(len(g) for g in taken)
        bucket = min(batch_bucket(n_max, self.batch_buckets), cap)
        bucket = max(bucket, n_max)
        return ShardedBatch(
            kind=head.kind, token_len=tlen, bucket=bucket,
            shards=[ScheduledBatch(kind=head.kind, token_len=tlen,
                                   bucket=bucket, requests=g)
                    for g in taken])
