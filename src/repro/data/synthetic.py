"""Synthetic online-interaction data (MetaICL/LaMP-shaped), fully on-device.

Each batch element is an *identity* with a hidden key->value mapping.
Context chunks c(j) show (key, value) demonstration pairs; the tail
interleaves query keys with answer values. A model that compresses context
well answers queries whose evidence appeared in earlier chunks — exactly the
paper's multi-task/personalization setup, but deterministic and dataless so
tests, examples and benchmarks can validate compression quality (loss with
memory must beat loss without).

Token map: 0 pad | 1 <COMP> placeholder | 2 bos | 3 sep |
           keys   [4, 4+n_keys) | values [4+n_keys, 4+n_keys+n_vals)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import SegmentLayout

PAD, COMP, BOS, SEP = 0, 1, 2, 3


@dataclasses.dataclass(frozen=True)
class KVTaskConfig:
    n_keys: int = 32
    n_vals: int = 32

    @property
    def min_vocab(self) -> int:
        return 4 + self.n_keys + self.n_vals

    def key_id(self, k):
        return 4 + k

    def val_id(self, v):
        return 4 + self.n_keys + v


def sample_kv_batch(key: jax.Array, layout: SegmentLayout, batch: int,
                    task: KVTaskConfig = KVTaskConfig(),
                    query_pool: str = "ctx") -> Dict[str, jnp.ndarray]:
    """Returns {'tokens': (B,S) i32, 'loss_mask': (B, tail-1) f32}.

    loss positions: tail even offsets (predict the value following each
    query key). With ``query_pool="ctx"`` (default, the training
    distribution) keys queried in the tail are drawn from keys shown in
    the context chunks, so the answer is in Mem — compressible signal.
    ``query_pool="all"`` draws query keys uniformly from the WHOLE key
    space instead: unseen keys are unanswerable (chance), so accuracy
    measures how much of the identity's mapping the accumulated context
    covers — the paper's accuracy-improves-over-time-steps claim (more
    chunks -> more keys demonstrated), rather than per-retrieval
    fidelity (which *falls* with t as queries spread over more
    compressed material).
    """
    t, lc, m, tail = (layout.t_steps, layout.chunk_len, layout.comp_len,
                      layout.tail_len)
    n_pairs = lc // 2
    kmap, kctx, kq = jax.random.split(key, 3)
    # identity mapping: value for each key, per batch element
    mapping = jax.random.randint(kmap, (batch, task.n_keys), 0, task.n_vals)
    # context demonstrations: (B, t, n_pairs) keys — distinct within a chunk
    ctx_keys = jax.vmap(jax.vmap(
        lambda k: jax.random.permutation(k, task.n_keys)[:n_pairs]))(
        jax.random.split(kctx, batch * t).reshape(batch, t, 2))
    ctx_vals = jnp.take_along_axis(
        mapping[:, None, :].repeat(t, 1), ctx_keys, axis=2)
    pair = jnp.stack([task.key_id(ctx_keys), task.val_id(ctx_vals)], axis=-1)
    chunk = pair.reshape(batch, t, 2 * n_pairs)
    if lc > 2 * n_pairs:
        chunk = jnp.concatenate(
            [chunk, jnp.full((batch, t, lc - 2 * n_pairs), SEP,
                             jnp.int32)], axis=-1)
    comp_toks = jnp.full((batch, t, m), COMP, jnp.int32)
    body = jnp.concatenate([chunk, comp_toks], axis=-1).reshape(batch, -1)
    # tail: query keys = DISTINCT positions of keys seen in context
    # (sampling with replacement would let later tail queries copy earlier
    # tail answers, contaminating the no-context control); "all" draws
    # distinct keys from the whole space instead (see docstring)
    n_q = tail // 2
    if query_pool == "all":
        q_keys = jax.vmap(
            lambda k: jax.random.permutation(k, task.n_keys)[:n_q])(
            jax.random.split(kq, batch))
    elif query_pool == "ctx":
        flat_ctx = ctx_keys.reshape(batch, -1)
        reps = -(-n_q // flat_ctx.shape[1])  # tile if more queries than ctx

        def _pick(k):
            perm = jax.random.permutation(k, flat_ctx.shape[1])
            return jnp.tile(perm, reps)[:n_q]

        pick = jax.vmap(_pick)(jax.random.split(kq, batch))
        q_keys = jnp.take_along_axis(flat_ctx, pick, axis=1)
    else:
        raise ValueError(f"unknown query_pool {query_pool!r}")
    q_vals = jnp.take_along_axis(mapping, q_keys, axis=1)
    qa = jnp.stack([task.key_id(q_keys), task.val_id(q_vals)],
                   axis=-1).reshape(batch, 2 * n_q)
    if tail > 2 * n_q:
        qa = jnp.concatenate(
            [qa, jnp.full((batch, tail - 2 * n_q), PAD, jnp.int32)], axis=-1)
    tokens = jnp.concatenate([body, qa], axis=-1).astype(jnp.int32)
    # next-token loss over tail[:-1]: predict values at even offsets
    off = np.arange(tail - 1)
    lm = ((off % 2 == 0) & (off < 2 * n_q - 1)).astype(np.float32)
    loss_mask = jnp.broadcast_to(jnp.asarray(lm)[None], (batch, tail - 1))
    return {"tokens": tokens, "loss_mask": loss_mask}


def lm_stream(key: jax.Array, batch: int, length: int, vocab: int,
              period: int = 97) -> jnp.ndarray:
    """Semi-predictable token stream (noisy periodic pattern) for streaming
    / perplexity benchmarks: position-dependent structure a compressor can
    exploit."""
    base = (jnp.arange(length) % period)[None, :] + 4
    noise = jax.random.randint(key, (batch, length), 0, vocab // 8)
    mix = jax.random.bernoulli(jax.random.fold_in(key, 1),
                               0.2, (batch, length))
    toks = jnp.where(mix, 4 + noise, base)
    return jnp.clip(toks, 0, vocab - 1).astype(jnp.int32)


class ShardableIndexIterator:
    """Stateless-indexable data iterator: restart/elastic-safe.

    ``state = (epoch, step)`` is checkpointed; every host derives its shard
    deterministically from (seed, epoch, step, host_id) — a restarted or
    re-scaled job resumes mid-epoch without coordination (DESIGN §6
    straggler/fault notes).
    """

    def __init__(self, seed: int, batch_per_host: int, n_hosts: int = 1,
                 host_id: int = 0):
        self.seed, self.bph = seed, batch_per_host
        self.n_hosts, self.host_id = n_hosts, host_id
        self.step = 0

    def key_for(self, step: int) -> jax.Array:
        k = jax.random.PRNGKey(self.seed)
        k = jax.random.fold_in(k, step)
        return jax.random.fold_in(k, self.host_id)

    def next_key(self) -> jax.Array:
        k = self.key_for(self.step)
        self.step += 1
        return k

    def state_dict(self):
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, st):
        self.step = int(st["step"])
        self.seed = int(st["seed"])
