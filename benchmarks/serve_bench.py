"""Multi-tenant serving benchmark: continuous batching vs naive loop.

Measures what the serve subsystem buys over the obvious baseline on the
same workload — N independent user sessions, each ingesting ``turns``
context chunks then issuing one query:

  naive   — per-session loop over the single-session jitted steps
            (one B=1 dispatch per op, as examples/serve_online.py would
            do per user)
  engine  — repro.serve.ServeEngine: continuous batching over the
            session arena, one vmapped dispatch per bucketed batch

A second scenario drives MIXED-LENGTH arrivals (each session's chunks
and query draw random lengths) through the engine twice — exact
token-length grouping vs ragged token-bucket batching (masked lanes) —
and reports compile-cache churn and mean batch occupancy for both; the
ragged scheduler must compile strictly fewer programs at higher
occupancy on identical traffic.

A third scenario drives OPEN-LOOP admission (arrival rate > service
rate): requests arrive faster than `run(max_batches=1)` can serve them,
against a bounded queue (`max_queued_tokens`) with the
``shed-lowest-priority`` policy and an arena smaller than the session
population (constant offload/restore churn).  It reports shed rate,
queue depth, queue-wait and end-to-end latency percentiles
(p50/p95/p99, from the engine's tracing histograms — see
docs/OBSERVABILITY.md), goodput, and tok/s for per-victim vs batched
vs batched+async offload on IDENTICAL traffic (admission is
deterministic control plane, so the shed/queue numbers must match
across modes — only the transfer batching changes throughput).
``--metrics-out PREFIX`` additionally writes the last open-loop
engine's full metrics snapshot as PREFIX.json + PREFIX.prom (the CI
artifact).

A fourth scenario drives the same saturating ingest traffic under a
tight DEVICE-MEMORY budget (`PressurePolicy.capacity_tokens`) twice:
memory-pressure controller on (recompress -> offload -> shed ladder)
vs levers off (every deficit goes straight to shed).  The acceptance
invariant — recorded as ``pressure.controller_reduces_shed`` — is a
strictly lower shed count with the controller on at EQUAL capacity.

A fifth scenario drives DEADLINE traffic (mixed tight/loose SLOs, one
logical second per arrival round on a manual clock) through the same
saturated open loop twice: EDF-within-priority plus late-preferring
shed (``edf=True``, the default) vs plain FIFO-within-priority
(``edf=False``) at EQUAL capacity on identical seeded traffic.  The
acceptance invariant — recorded as ``deadline.deadline_reduces_late_
rate`` — is a strictly lower SLA-miss rate (missed deliveries + shed
deadline-carrying requests, over all deadline-carrying submissions)
with EDF on.

A sixth scenario drives IDENTICAL seeded traffic through a single-shard
engine and a session-sharded one (``n_shards=4``, mesh-native over the
``shards`` axis when >= 4 devices are visible — CI forces them with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — else the
per-shard loop path).  The invariants, recorded in the JSON, are
bit-exact query logits vs the single shard and ZERO steady-state
cross-device session moves (``serve_cross_shard_moves_total``);
tok/s at 1 vs 4 shards is reported for trend tracking (on a 2-core
CPU container the forced devices share cores, so the ratio is noise —
the exactness/no-transfer invariants are the signal).

A seventh scenario drives PREFIX-HEAVY arrivals — every session opens
with the same per-tenant context prefix — under a tight logical-memory
budget (`PressurePolicy.capacity_tokens`, cheap levers off so every
deficit falls to ``reject-new``) twice: content-addressed prefix dedup
on (all sessions attach to ONE compressed row, copy-on-write) vs off
(every session compresses its own row).  The acceptance invariant —
recorded as ``prefix_dedup.dedup_raises_admitted_sessions`` — is
strictly more sessions holding their compressed prefix at EQUAL
capacity with dedup on, with sampled query logits matching a direct
compress-from-scratch in both arms.

Also checks the LRU offload path end-to-end: a session offloaded to host
and restored must reproduce its query logits EXACTLY (allclose) vs a
never-offloaded run.

Results are written to BENCH_serve.json (``--out``; committed per PR,
CI uploads a ``--smoke`` run as an artifact — absolute numbers are
container noise, ratios and invariants are the signal).

Weights are random — throughput and state-exactness don't need a trained
adapter (accuracy benchmarks live in benchmarks/tables.py).

    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] \
        [--out BENCH_serve.json]
"""
import argparse
import json
import sys
import time

sys.path.insert(0, ".")
sys.path.insert(0, "benchmarks")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import inference as I
from repro.models import transformer as T
from repro.obs import ManualClock, Observability
from repro.serve import PressurePolicy, ServeEngine


def _workload(n_sessions, turns, chunk, qlen, vocab, seed=0):
    rng = np.random.RandomState(seed)
    return [
        {"chunks": [rng.randint(0, vocab, size=chunk).astype(np.int32)
                    for _ in range(turns)],
         "query": rng.randint(0, vocab, size=qlen).astype(np.int32)}
        for _ in range(n_sessions)
    ]


def run_naive(params, cfg, work, cache_len, repeats=3):
    ingest = jax.jit(lambda s, c: I.ingest_context(params, cfg, s, c))
    query = jax.jit(lambda s, q: I.prefill(params, cfg, s, q,
                                           full_logits=True))
    def one(w):
        st = I.init_online_state(cfg, 1, max_cache_len=cache_len)
        for c in w["chunks"]:
            st = ingest(st, c[None])
        lg, _ = query(st, w["query"][None])
        return lg
    jax.block_until_ready(one(work[0]))        # compile outside the clock
    best, outs = None, None
    for _ in range(repeats):                   # best-of-N: 2-core container
        t0 = time.perf_counter()               # timing is noisy
        o = [one(w) for w in work]
        jax.block_until_ready(o)
        dt = time.perf_counter() - t0
        if best is None or dt < best:
            best, outs = dt, o
    return best, [np.asarray(o[0]) for o in outs]


def run_engine(params, cfg, work, cache_len, warm=True):
    eng = ServeEngine(params, cfg, n_slots=len(work) + 1,
                      cache_len=cache_len)
    if warm:
        # two throwaway waves compile everything outside the clock: the
        # fused steps (wave 1) and the recycled-slot zeroing scatter
        # (wave 2 reuses wave 1's dirtied slots)
        for wave in range(2):
            wwork = _workload(len(work), len(work[0]["chunks"]),
                              work[0]["chunks"][0].size,
                              work[0]["query"].size,
                              cfg.vocab_size, seed=123 + wave)
            for s, w in enumerate(wwork):
                eng.create_session(f"warm{wave}_{s}")
            for t in range(len(work[0]["chunks"])):
                for s, w in enumerate(wwork):
                    eng.ingest(f"warm{wave}_{s}", w["chunks"][t])
                eng.run()
            for s, w in enumerate(wwork):
                eng.query(f"warm{wave}_{s}", w["query"])
            eng.run()
            for s in range(len(wwork)):
                eng.close_session(f"warm{wave}_{s}")
    best, reqs = None, None
    for rep in range(3):                       # best-of-N, fresh sessions
        t0 = time.perf_counter()               # each rep (same shapes)
        for s, w in enumerate(work):
            eng.create_session(f"u{rep}_{s}")
        for t in range(len(work[0]["chunks"])):
            for s, w in enumerate(work):
                eng.ingest(f"u{rep}_{s}", w["chunks"][t])
        rr = [eng.query(f"u{rep}_{s}", w["query"]).request
              for s, w in enumerate(work)]
        eng.run()
        dt = time.perf_counter() - t0
        if best is None or dt < best:
            best, reqs = dt, rr
        for s in range(len(work)):
            eng.close_session(f"u{rep}_{s}")
    return best, [np.asarray(r.result) for r in reqs], eng


def _mixed_workload(n_sessions, turns, vocab, seed=7,
                    chunk_lens=(3, 5, 8, 11), q_lens=(2, 4, 7)):
    """Sessions whose chunk/query lengths vary — realistic traffic that
    fragments an exact-length scheduler into tiny per-length batches."""
    rng = np.random.RandomState(seed)
    return [
        {"chunks": [rng.randint(0, vocab,
                                size=chunk_lens[rng.randint(len(chunk_lens))]
                                ).astype(np.int32)
                    for _ in range(turns)],
         "query": rng.randint(0, vocab,
                              size=q_lens[rng.randint(len(q_lens))]
                              ).astype(np.int32)}
        for _ in range(n_sessions)
    ]


def run_mixed(params, cfg, work, cache_len, token_buckets):
    """One engine pass over the mixed-length workload; returns
    (wall seconds, results, engine) — engine carries compile/occupancy
    stats."""
    eng = ServeEngine(params, cfg, n_slots=len(work) + 1,
                      cache_len=cache_len, token_buckets=token_buckets)
    t0 = time.perf_counter()
    for s, w in enumerate(work):
        eng.create_session(f"m{s}")
    for t in range(len(work[0]["chunks"])):
        for s, w in enumerate(work):
            eng.ingest(f"m{s}", w["chunks"][t])
        eng.run()
    reqs = [eng.query(f"m{s}", w["query"]).request for s, w in enumerate(work)]
    eng.run()
    dt = time.perf_counter() - t0
    return dt, [np.asarray(r.result) for r in reqs], eng


def _overall_occupancy(eng):
    lanes = sum(s["lanes"] for s in eng.stats.values())
    reqs = sum(s["requests"] for s in eng.stats.values())
    return reqs / lanes if lanes else 0.0


def offload_roundtrip_check(params, cfg, work, cache_len):
    """Logits after offload->restore == logits never offloaded."""
    w = work[0]
    outs = []
    for do_offload in (False, True):
        eng = ServeEngine(params, cfg, n_slots=2, cache_len=cache_len)
        eng.create_session("u")
        for c in w["chunks"]:
            eng.ingest("u", c)
        eng.run()
        if do_offload:
            eng.offload_session("u")
        r = eng.query("u", w["query"]).request
        eng.run()
        outs.append(np.asarray(r.result))
    return np.allclose(outs[0], outs[1], atol=0.0)


def run_sharded(params, cfg, work, cache_len, n_shards, mesh):
    """Drive ``work`` through an ``n_shards``-way engine: two warm
    passes compile the per-shard programs (and the recycled-slot zeroing
    scatter) outside the clock, then best-of-2 timed passes with fresh
    sessions.  ``mesh=None`` at ``n_shards>1`` exercises the per-shard
    loop path instead of the fused `shard_map` program."""
    eng = ServeEngine(params, cfg, n_slots=len(work), cache_len=cache_len,
                      n_shards=n_shards, mesh=mesh)
    best, outs = None, None
    for rep in range(4):                   # reps 0-1 warm, 2-3 timed
        t0 = time.perf_counter()
        for s in range(len(work)):
            eng.create_session(f"r{rep}_{s}")
        for t in range(len(work[0]["chunks"])):
            for s, w in enumerate(work):
                eng.ingest(f"r{rep}_{s}", w["chunks"][t])
        rr = [eng.query(f"r{rep}_{s}", w["query"]).request
              for s, w in enumerate(work)]
        eng.run()
        dt = time.perf_counter() - t0
        res = [np.asarray(r.result) for r in rr]
        for s in range(len(work)):
            eng.close_session(f"r{rep}_{s}")
        if rep >= 2 and (best is None or dt < best):
            best, outs = dt, res
    return best, outs, eng


def run_open_loop(params, cfg, *, mode, rounds, arrivals_per_round=4,
                  n_sessions=16, n_slots=5, max_resident=4,
                  max_queued_tokens=96, seed=11):
    """Open-loop admission: ``arrivals_per_round`` requests land per
    round but only ONE batch is served per round, so the queue saturates
    and the bounded-ingress shed policy engages; a session population
    4x the resident budget keeps the offload path hot.  ``mode`` picks
    the offload transfer strategy under test.  Runs with request
    tracing on — queue-wait / e2e latency percentiles come from the obs
    histograms (host-side only; the compute path is identical to an
    untraced engine)."""
    batched = mode != "per_victim"
    obs = Observability.tracing()
    eng = ServeEngine(params, cfg, n_slots=n_slots,
                      max_resident=max_resident, cache_len=64,
                      batch_buckets=(1, 2, 4),
                      admission_policy="shed-lowest-priority",
                      max_queued_tokens=max_queued_tokens,
                      batched_offload=batched,
                      async_offload=(mode == "batched_async"), obs=obs)
    rng = np.random.RandomState(seed)
    for s in range(n_sessions):
        eng.create_session(f"u{s}")
    depths = []
    submitted = 0
    t0 = time.perf_counter()
    for _ in range(rounds):
        for _ in range(arrivals_per_round):
            s = rng.randint(n_sessions)
            ln = (3, 5, 8)[rng.randint(3)]
            toks = rng.randint(0, cfg.vocab_size, size=ln).astype(np.int32)
            eng.ingest(f"u{s}", toks, priority=int(rng.randint(3)))
            submitted += 1
        eng.run(max_batches=1)          # service rate < arrival rate
        depths.append(eng.queue_depth())
    eng.run()                           # close the loop: drain the rest
    wall = time.perf_counter() - t0
    st = eng.admission.stats
    shed = st["shed_new"] + st["shed_victims"]
    toks_served = sum(s_["tokens"] for s_ in eng.stats.values())
    offloads = sum(s_.n_offloads
                   for s_ in eng._mgr["online"].sessions.values())
    reg = eng.obs.registry
    wait_pct = reg.get("serve_queue_wait_seconds").aggregate().percentiles()
    e2e_pct = reg.get("serve_e2e_latency_seconds").aggregate().percentiles()
    served = submitted - shed
    return {
        "mode": mode, "submitted": submitted, "shed": shed,
        "shed_rate": shed / submitted,
        "served": served,
        "queue_depth_mean": float(np.mean(depths)),
        "queue_depth_max": int(max(depths)),
        "offloads": offloads,
        "queue_wait_s": wait_pct,
        "e2e_latency_s": e2e_pct,
        "goodput_req_per_s": served / wall,
        "tok_per_s": toks_served / wall, "wall_s": wall,
    }, eng


def run_pressure(params, cfg, *, on, rounds, capacity_tokens=48,
                 arrivals_per_round=4, n_sessions=12, n_slots=6,
                 max_resident=5, seed=13):
    """Open-loop saturation under a DEVICE-MEMORY budget: identical
    ingest-heavy traffic against the same ``capacity_tokens``, with the
    pressure controller's cheap levers enabled (``on=True``: recompress
    -> offload -> shed ladder) or disabled (``on=False``: every budget
    deficit falls straight through to the shed policy).  The acceptance
    criterion is a strictly lower shed rate with the controller on —
    degradation (coarser compressed memory, offloaded idle sessions)
    traded for dropped requests at EQUAL capacity."""
    policy = PressurePolicy(capacity_tokens=capacity_tokens,
                            enable_recompress=on, enable_offload=on)
    eng = ServeEngine(params, cfg, n_slots=n_slots,
                      max_resident=max_resident, cache_len=64,
                      batch_buckets=(1, 2, 4),
                      admission_policy="shed-lowest-priority",
                      batched_offload=True, pressure_policy=policy)
    rng = np.random.RandomState(seed)
    for s in range(n_sessions):
        eng.create_session(f"u{s}")
    submitted = 0
    t0 = time.perf_counter()
    for _ in range(rounds):
        for _ in range(arrivals_per_round):
            s = rng.randint(n_sessions)
            ln = (3, 5, 8)[rng.randint(3)]
            toks = rng.randint(0, cfg.vocab_size, size=ln).astype(np.int32)
            eng.ingest(f"u{s}", toks, priority=int(rng.randint(3)))
            submitted += 1
        eng.run(max_batches=1)          # service rate < arrival rate
    eng.run()
    wall = time.perf_counter() - t0
    st = eng.admission.stats
    shed = st["shed_new"] + st["shed_victims"]
    ctl = eng.pressure
    levers = {lv: int(ctl._m_decisions.labels(lever=lv).value)
              for lv in ("recompress", "offload", "shed")}
    freed = {lv: float(ctl._m_freed.labels(lever=lv).value)
             for lv in ("recompress", "offload")}
    toks_served = sum(s_["tokens"] for s_ in eng.stats.values())
    return {
        "controller": "on" if on else "off",
        "capacity_tokens": capacity_tokens,
        "submitted": submitted, "shed": shed,
        "shed_rate": shed / submitted,
        "lever_decisions": levers, "tokens_freed": freed,
        "used_tokens_final": ctl.used_tokens(),
        "tok_per_s": toks_served / wall, "wall_s": wall,
    }


def run_deadline(params, cfg, *, edf, rounds, arrivals_per_round=6,
                 n_sessions=12, n_slots=6, max_resident=5,
                 max_queued_tokens=96, seed=17):
    """Open-loop deadline traffic on a MANUAL clock (one logical second
    per arrival round, so lateness is a deterministic function of the
    trace, not of container speed): mixed tight/loose relative
    deadlines plus deadline-less fillers, arrival rate > service rate.
    ``edf`` flips the scheduler between EDF-within-priority with
    late-preferring shed (the default serve configuration) and plain
    FIFO-within-priority — everything else, including the seeded
    traffic, is identical across the two arms."""
    obs = Observability.tracing(clock=ManualClock())
    eng = ServeEngine(params, cfg, n_slots=n_slots,
                      max_resident=max_resident, cache_len=64,
                      batch_buckets=(1, 2, 4),
                      admission_policy="shed-lowest-priority",
                      max_queued_tokens=max_queued_tokens,
                      batched_offload=True, edf=edf, obs=obs)
    rng = np.random.RandomState(seed)
    for s in range(n_sessions):
        eng.create_session(f"u{s}")
    submitted = 0
    t0 = time.perf_counter()
    for _ in range(rounds):
        obs.clock.advance(1.0)
        for _ in range(arrivals_per_round):
            s = rng.randint(n_sessions)
            ln = (3, 5, 8)[rng.randint(3)]
            rel = (2.0, 3.0, 12.0, None)[rng.randint(4)]
            toks = rng.randint(0, cfg.vocab_size, size=ln).astype(np.int32)
            eng.ingest(f"u{s}", toks, priority=int(rng.randint(2)),
                       deadline=(None if rel is None
                                 else obs.clock.now() + rel))
            submitted += 1
        eng.run(max_batches=1)          # service rate < arrival rate
    drain_rounds = 0                    # the clock keeps ticking while
    while eng.queue_depth() or eng.admission.backlog:   # the tail drains
        obs.clock.advance(1.0)
        eng.run(max_batches=1)
        drain_rounds += 1
    wall = time.perf_counter() - t0
    kinds = ("ingest", "query", "stream")
    md = eng._m_deadline
    met = sum(int(md["met"].labels(kind=k).value) for k in kinds)
    missed = sum(int(md["missed"].labels(kind=k).value) for k in kinds)
    dl_requests = sum(int(md["requests"].labels(kind=k).value)
                      for k in kinds)
    shed_late = int(md["shed"].labels(late="yes").value)
    shed_dl = shed_late + int(md["shed"].labels(late="no").value)
    assert met + missed + shed_dl == dl_requests, \
        "deadline accounting leak: every deadline-carrying request is " \
        "delivered (met|missed) or shed"
    lateness = eng._h_lateness.labels()

    def _q(q):
        v = lateness.quantile(q)
        return None if not np.isfinite(v) else float(v)

    return {
        "scheduler": "edf" if edf else "fifo",
        "submitted": submitted,
        "deadline_requests": dl_requests,
        "met": met, "missed": missed,
        "shed_deadline": shed_dl, "shed_late": shed_late,
        "delivered_late_rate": missed / max(1, met + missed),
        "sla_miss_rate": (missed + shed_dl) / max(1, dl_requests),
        "lateness_p50_s": _q(0.50), "lateness_p99_s": _q(0.99),
        "drain_rounds": drain_rounds, "wall_s": wall,
    }


def run_prefix_dedup(params, cfg, *, dedup, n_sessions=12, prefix_len=8,
                     qlen=4, capacity_tokens=16, seed=23):
    """Prefix-heavy admission under a tight logical-memory budget:
    ``n_sessions`` sessions all open with the SAME tenant-scoped prefix,
    then a couple of sampled sessions serve a query (numeric check).
    ``dedup`` flips the content-addressed prefix cache; the pressure
    budget (cheap levers off, ``reject-new`` overflow) is sized so the
    dedup-off arm — one compressed row per session — runs out of
    logical memory while the dedup-on arm shares one row.  The gate is
    ``admitted``: sessions actually holding their compressed prefix
    after the open wave."""
    policy = PressurePolicy(capacity_tokens=capacity_tokens,
                            enable_recompress=False, enable_offload=False)
    eng = ServeEngine(params, cfg, n_slots=n_sessions + 4, cache_len=32,
                      batch_buckets=(1, 2, 4),
                      admission_policy="reject-new",
                      pressure_policy=policy,
                      prefix_cache=dedup)
    rng = np.random.RandomState(seed)
    prefix = rng.randint(0, cfg.vocab_size,
                         size=prefix_len).astype(np.int32)
    query = rng.randint(0, cfg.vocab_size, size=qlen).astype(np.int32)
    t0 = time.perf_counter()
    for s in range(n_sessions):         # open wave: everyone, same prefix
        eng.create_session(f"u{s}", prefix_tokens=prefix)
        eng.run()
    mgr = eng._mgr["online"]
    admitted = sum(1 for s_ in mgr.sessions.values() if s_.mem_groups > 0)
    used_after_open = eng.pressure.used_tokens()
    shared_rows = len(mgr.arena.shared_slots())
    # numeric spot-check on sessions that DID get their prefix: sampled
    # queries must match a direct compress-from-scratch (the dedup-on
    # samples COW-break off the shared row here)
    holders = [s_.sid for s_ in mgr.sessions.values() if s_.mem_groups > 0]
    st = I.init_online_state(cfg, 1, max_cache_len=32)
    st = I.ingest_context(params, cfg, st, prefix[None])
    want, _ = I.prefill(params, cfg, st, query[None], full_logits=True)
    # the budget is sized for the open wave; a query needs extra
    # headroom (queued tokens + the pre-charged KV-cache growth), so
    # release the non-sampled holders first and each sample after its
    # query — the open-wave numbers above are already recorded
    samples = {holders[0], holders[-1]}
    for sid in holders:
        if sid not in samples:
            eng.close_session(sid)
    sample_ok = True
    for sid in samples:
        r = eng.query(sid, query).request
        eng.run()
        if r.result is None or not np.allclose(
                np.asarray(r.result), np.asarray(want[0]), atol=1e-5):
            sample_ok = False
        eng.close_session(sid)
    wall = time.perf_counter() - t0
    cache = eng.prefix_cache
    return {
        "dedup": "on" if dedup else "off",
        "capacity_tokens": capacity_tokens,
        "sessions": n_sessions,
        "admitted": admitted,
        "used_tokens_after_open": used_after_open,
        "shared_rows_after_open": shared_rows,
        "dedup_hits": int(cache._m_hits.value) if cache else 0,
        "dedup_inserts": int(cache._m_inserts.value) if cache else 0,
        "sampled_queries_match_direct": bool(sample_ok),
        "wall_s": wall,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=96)
    ap.add_argument("--turns", type=int, default=3)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--qlen", type=int, default=4)
    ap.add_argument("--mixed-sessions", type=int, default=24,
                    help="sessions in the mixed-length ragged scenario")
    ap.add_argument("--open-rounds", type=int, default=120,
                    help="arrival rounds in the open-loop scenario")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the CI artifact run")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--metrics-out", default=None, metavar="PREFIX",
                    help="also write the last open-loop engine's metrics "
                         "snapshot as PREFIX.json and PREFIX.prom")
    args = ap.parse_args()
    if args.smoke:
        args.sessions, args.mixed_sessions, args.open_rounds = 12, 8, 40

    # serve-bench config: half-width bench model so the per-op dispatch
    # floor (what continuous batching amortizes) is visible on a 2-core
    # CPU container; trends/ratios are the target, not absolute numbers
    cfg = C.bench_cfg(d_model=64, d_ff=128, n_heads=4, n_kv_heads=2)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    cache_len = 4 * args.qlen
    work = _workload(args.sessions, args.turns, args.chunk, args.qlen,
                     cfg.vocab_size)
    tok_total = args.sessions * (args.turns * args.chunk + args.qlen)

    t_naive, out_naive = run_naive(params, cfg, work, cache_len)
    t_eng, out_eng, eng = run_engine(params, cfg, work, cache_len)

    ok = all(np.allclose(a, b, atol=1e-5)
             for a, b in zip(out_naive, out_eng))
    exact = offload_roundtrip_check(params, cfg, work, cache_len)

    print(f"\nsessions={args.sessions} turns={args.turns} "
          f"chunk={args.chunk} qlen={args.qlen} "
          f"({tok_total} tokens total)")
    print(f"naive per-session loop : {t_naive:7.3f} s  "
          f"{tok_total / t_naive:9.0f} tok/s")
    print(f"continuous batching    : {t_eng:7.3f} s  "
          f"{tok_total / t_eng:9.0f} tok/s")
    print(f"speedup                : {t_naive / t_eng:7.2f}x")
    print(f"engine == naive logits : {ok}")
    print(f"offload->restore exact : {exact}")
    print(f"compiled programs      : {eng.compile_stats()}")
    if t_naive / t_eng < 3.0:
        print("WARNING: speedup below the 3x acceptance bar")
    C.csv_row("serve_naive", t_naive * 1e6, f"{tok_total / t_naive:.0f} tok/s")
    C.csv_row("serve_batched", t_eng * 1e6, f"{tok_total / t_eng:.0f} tok/s")

    # -- mixed-length arrivals: exact-length vs ragged token buckets ----
    mixed = _mixed_workload(args.mixed_sessions, args.turns, cfg.vocab_size)
    t_exact, out_exact, eng_exact = run_mixed(params, cfg, mixed,
                                              cache_len=32,
                                              token_buckets=None)
    t_ragged, out_ragged, eng_ragged = run_mixed(params, cfg, mixed,
                                                 cache_len=32,
                                                 token_buckets="auto")
    same = all(np.allclose(a, b, atol=1e-5)
               for a, b in zip(out_exact, out_ragged))
    occ_e, occ_r = _overall_occupancy(eng_exact), _overall_occupancy(eng_ragged)
    prog_e, prog_r = eng_exact.compiled_programs(), eng_ragged.compiled_programs()
    bat_e = sum(s["batches"] for s in eng_exact.stats.values())
    bat_r = sum(s["batches"] for s in eng_ragged.stats.values())
    print(f"\nmixed-length arrivals ({args.mixed_sessions} sessions, "
          f"{args.turns} turns, chunk lens 3/5/8/11, query lens 2/4/7)")
    print(f"exact-length grouping  : {bat_e:3d} batches  "
          f"{prog_e:3d} compiled programs  occupancy {occ_e:.2f}  "
          f"({t_exact:.3f} s incl. compile)")
    print(f"ragged token buckets   : {bat_r:3d} batches  "
          f"{prog_r:3d} compiled programs  occupancy {occ_r:.2f}  "
          f"({t_ragged:.3f} s incl. compile)")
    print(f"ragged == exact logits : {same}")
    if not (prog_r < prog_e and occ_r > occ_e):
        print("WARNING: ragged batching must compile fewer programs at "
              "higher occupancy than exact-length grouping")
    C.csv_row("serve_mixed_exact", t_exact * 1e6,
              f"{prog_e} programs, occ {occ_e:.2f}")
    C.csv_row("serve_mixed_ragged", t_ragged * 1e6,
              f"{prog_r} programs, occ {occ_r:.2f}")

    # -- open-loop admission: arrival rate > service rate ---------------
    open_loop = []
    open_eng = None
    for mode in ("per_victim", "batched", "batched_async"):
        r, open_eng = run_open_loop(params, cfg, mode=mode,
                                    rounds=args.open_rounds)
        open_loop.append(r)
        print(f"\nopen-loop [{mode:13s}]: shed rate {r['shed_rate']:.2f} "
              f"({r['shed']}/{r['submitted']}), queue depth "
              f"mean {r['queue_depth_mean']:.1f} max {r['queue_depth_max']}, "
              f"{r['offloads']} offloads, {r['tok_per_s']:7.0f} tok/s")
        print(f"  queue wait p50/p95/p99: "
              f"{r['queue_wait_s']['p50']*1e3:.1f}/"
              f"{r['queue_wait_s']['p95']*1e3:.1f}/"
              f"{r['queue_wait_s']['p99']*1e3:.1f} ms   "
              f"e2e p50/p95/p99: "
              f"{r['e2e_latency_s']['p50']*1e3:.1f}/"
              f"{r['e2e_latency_s']['p95']*1e3:.1f}/"
              f"{r['e2e_latency_s']['p99']*1e3:.1f} ms   "
              f"goodput {r['goodput_req_per_s']:.0f} req/s")
        C.csv_row(f"serve_open_{mode}", r["wall_s"] * 1e6,
                  f"shed {r['shed_rate']:.2f}, {r['tok_per_s']:.0f} tok/s")
    # identical traffic -> identical control plane across offload modes;
    # recorded in the JSON so the CI artifact carries the invariant
    deterministic = all(
        r["shed"] == open_loop[0]["shed"]
        and r["queue_depth_max"] == open_loop[0]["queue_depth_max"]
        for r in open_loop)
    if not deterministic:
        print("WARNING: open-loop control plane diverged across offload "
              "modes (must be deterministic on identical traffic)")
    base, best = open_loop[0]["tok_per_s"], max(
        r["tok_per_s"] for r in open_loop[1:])
    print(f"batched-offload speedup under churn: {best / base:.2f}x")

    # -- memory-pressure ladder: controller on vs off, equal capacity ----
    pressure = {}
    for arm in (True, False):
        r = run_pressure(params, cfg, on=arm, rounds=args.open_rounds)
        pressure["on" if arm else "off"] = r
        lv = r["lever_decisions"]
        print(f"\npressure [{r['controller']:3s}] capacity="
              f"{r['capacity_tokens']}: shed rate {r['shed_rate']:.2f} "
              f"({r['shed']}/{r['submitted']}), levers "
              f"recompress={lv['recompress']} offload={lv['offload']} "
              f"shed-handoff={lv['shed']}, {r['tok_per_s']:7.0f} tok/s")
        C.csv_row(f"serve_pressure_{r['controller']}", r["wall_s"] * 1e6,
                  f"shed {r['shed_rate']:.2f} @cap {r['capacity_tokens']}")
    reduces = pressure["on"]["shed"] < pressure["off"]["shed"]
    print(f"controller reduces shed at equal capacity: {reduces} "
          f"({pressure['on']['shed']} vs {pressure['off']['shed']})")
    if not reduces:
        print("WARNING: pressure controller must shed strictly less than "
              "levers-off at equal capacity")

    # -- deadline scheduling: EDF + late-shed vs FIFO, equal capacity ----
    deadline = {}
    for arm in (True, False):
        r = run_deadline(params, cfg, edf=arm, rounds=args.open_rounds)
        deadline[r["scheduler"]] = r
        print(f"\ndeadline [{r['scheduler']:4s}]: SLA miss rate "
              f"{r['sla_miss_rate']:.2f} (missed {r['missed']} + shed "
              f"{r['shed_deadline']} of {r['deadline_requests']} "
              f"deadline-carrying), delivered-late rate "
              f"{r['delivered_late_rate']:.2f}, met {r['met']}, "
              f"drained in {r['drain_rounds']} extra rounds")
        C.csv_row(f"serve_deadline_{r['scheduler']}", r["wall_s"] * 1e6,
                  f"sla miss {r['sla_miss_rate']:.2f}")
    reduces_late = (deadline["edf"]["sla_miss_rate"]
                    < deadline["fifo"]["sla_miss_rate"])
    print(f"EDF reduces SLA-miss rate at equal capacity: {reduces_late} "
          f"({deadline['edf']['sla_miss_rate']:.2f} vs "
          f"{deadline['fifo']['sla_miss_rate']:.2f})")
    if not reduces_late:
        print("WARNING: EDF + late-preferring shed must miss strictly "
              "fewer SLAs than FIFO on identical traffic")

    # -- session-sharded serving: 1 vs 4 shards, identical traffic ------
    n_sh = 4
    sh_sessions = 8 if args.smoke else 16
    sh_work = _workload(sh_sessions, args.turns, args.chunk, args.qlen,
                        cfg.vocab_size, seed=21)
    sh_tok = sh_sessions * (args.turns * args.chunk + args.qlen)
    mesh = None
    if jax.device_count() >= n_sh:
        from repro.launch.mesh import make_session_mesh
        mesh = make_session_mesh(n_sh)
    t_one, out_one, _ = run_sharded(params, cfg, sh_work, cache_len, 1, None)
    t_sh, out_sh, eng_sh = run_sharded(params, cfg, sh_work, cache_len,
                                       n_sh, mesh)
    bit_exact = all(np.array_equal(a, b)
                    for a, b in zip(out_one, out_sh))
    moves = int(eng_sh._m_cross_shard.value)
    path = "shard_map mesh" if mesh is not None else "per-shard loop"
    print(f"\nsharded serving ({sh_sessions} sessions, {n_sh} shards, "
          f"{path}, {jax.device_count()} devices)")
    print(f"1 shard                : {t_one:7.3f} s  "
          f"{sh_tok / t_one:9.0f} tok/s")
    print(f"{n_sh} shards               : {t_sh:7.3f} s  "
          f"{sh_tok / t_sh:9.0f} tok/s")
    print(f"sharded == 1-shard     : {bit_exact} (bit-exact)")
    print(f"cross-shard moves      : {moves}")
    if not bit_exact:
        print("WARNING: sharded engine must be bit-exact vs single shard "
              "on identical traffic")
    if moves != 0:
        print("WARNING: steady-state serving must not move sessions "
              "across shards")
    C.csv_row("serve_shard_1", t_one * 1e6, f"{sh_tok / t_one:.0f} tok/s")
    C.csv_row(f"serve_shard_{n_sh}", t_sh * 1e6,
              f"{sh_tok / t_sh:.0f} tok/s, {path}")

    # -- prefix-heavy arrivals: dedup on vs off, equal memory budget ----
    prefix_dedup = {}
    for arm in (True, False):
        r = run_prefix_dedup(params, cfg, dedup=arm)
        prefix_dedup[r["dedup"]] = r
        print(f"\nprefix dedup [{r['dedup']:3s}] capacity="
              f"{r['capacity_tokens']}: admitted {r['admitted']}/"
              f"{r['sessions']} sessions, used {r['used_tokens_after_open']}"
              f" tokens after open, {r['shared_rows_after_open']} shared "
              f"rows, hits={r['dedup_hits']} inserts={r['dedup_inserts']}, "
              f"sampled queries match: {r['sampled_queries_match_direct']}")
        C.csv_row(f"serve_prefix_{r['dedup']}", r["wall_s"] * 1e6,
                  f"admitted {r['admitted']}/{r['sessions']}")
    raises_admitted = (prefix_dedup["on"]["admitted"]
                       > prefix_dedup["off"]["admitted"])
    print(f"dedup raises admitted sessions at equal capacity: "
          f"{raises_admitted} ({prefix_dedup['on']['admitted']} vs "
          f"{prefix_dedup['off']['admitted']})")
    if not raises_admitted:
        print("WARNING: prefix dedup must admit strictly more sessions "
              "than no-dedup at equal memory capacity")

    results = {
        "config": {"sessions": args.sessions, "turns": args.turns,
                   "chunk": args.chunk, "qlen": args.qlen,
                   "mixed_sessions": args.mixed_sessions,
                   "open_rounds": args.open_rounds, "smoke": args.smoke},
        "continuous_batching": {
            "naive_tok_per_s": tok_total / t_naive,
            "engine_tok_per_s": tok_total / t_eng,
            "speedup": t_naive / t_eng,
            "engine_matches_naive": bool(ok),
            "offload_roundtrip_exact": bool(exact)},
        "mixed_length": {
            "exact": {"batches": bat_e, "programs": prog_e,
                      "occupancy": occ_e},
            "ragged": {"batches": bat_r, "programs": prog_r,
                       "occupancy": occ_r},
            "ragged_matches_exact": bool(same)},
        "open_loop": open_loop,
        "open_loop_control_plane_deterministic": deterministic,
        "pressure": {**pressure,
                     "controller_reduces_shed": bool(reduces)},
        "deadline": {**deadline,
                     "deadline_reduces_late_rate": bool(reduces_late)},
        "prefix_dedup": {**prefix_dedup,
                         "dedup_raises_admitted_sessions":
                             bool(raises_admitted)},
        "sharded": {
            "n_shards": n_sh, "sessions": sh_sessions,
            "mesh": mesh is not None,
            "n_devices": jax.device_count(),
            "one_shard_tok_per_s": sh_tok / t_one,
            "sharded_tok_per_s": sh_tok / t_sh,
            "bit_exact_vs_single_shard": bool(bit_exact),
            "cross_shard_moves": moves},
    }
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {args.out}")
    if args.metrics_out and open_eng is not None:
        with open(args.metrics_out + ".json", "w") as f:
            json.dump(open_eng.metrics_snapshot(), f, indent=1)
        with open(args.metrics_out + ".prom", "w") as f:
            f.write(open_eng.metrics_prometheus())
        print(f"wrote {args.metrics_out}.json / .prom")


if __name__ == "__main__":
    main()
