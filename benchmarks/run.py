"""Benchmark driver: one function per paper table (see tables.py).

Prints ``name,us_per_call,derived`` CSV and writes
experiments/bench_results.json. ``--fast`` trims training steps for CI.
Roofline tables (from the dry-run artifacts) are appended when present.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer training steps (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated table names")
    args, _ = ap.parse_known_args()

    from benchmarks import tables as TB
    steps = 120 if args.fast else 600
    small = 100 if args.fast else 400
    jobs = {
        "table3_complexity": lambda: TB.table3_complexity(),
        "table8_training_speed": lambda: TB.table8_training_speed(),
        "table1_throughput": lambda: TB.table1_throughput(),
        "fig6_memory_vs_performance":
            lambda: TB.fig6_memory_vs_performance(steps),
        "table5_conditional_lora":
            lambda: TB.table5_conditional_lora(small),
        "fig8_streaming": lambda: TB.fig8_streaming(steps),
        "table16_merge_design": lambda: TB.table16_merge_design(small),
        "table18_comp_len": lambda: TB.table18_comp_len(small),
    }
    if args.only:
        keep = set(args.only.split(","))
        jobs = {k: v for k, v in jobs.items() if k in keep}

    print("name,us_per_call,derived")
    results = {}
    for name, fn in jobs.items():
        t0 = time.time()
        try:
            results[name] = fn()
        except Exception as e:  # keep the suite running
            import traceback
            traceback.print_exc()
            results[name] = {"error": str(e)}
        print(f"# {name} done in {time.time()-t0:.0f}s")

    # roofline from dry-run artifacts, if present
    try:
        from benchmarks import roofline as RL
        recs = RL.load_records()
        if recs:
            for mesh in ("single", "multi"):
                if any(r["mesh"] == mesh for r in recs):
                    print(f"\n# === roofline ({mesh}-pod) ===")
                    results[f"roofline_{mesh}"] = RL.print_table(mesh)
    except Exception as e:
        print(f"# roofline skipped: {e}")

    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"# wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
