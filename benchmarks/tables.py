"""One function per paper table/figure (EXPERIMENTS.md §Paper-fidelity).

Each prints ``name,us_per_call,derived`` CSV rows and returns a dict that
benchmarks.run aggregates into experiments/bench_results.json.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import inference as I
from repro.core import masks as M
from repro.core import streaming as ST
from repro.data.synthetic import lm_stream, sample_kv_batch
from repro.models import transformer as T
from repro.models.config import CCMConfig, ModelConfig
from repro.optim.losses import next_token_loss


def _variant_cfg(method: str, mode: str = "concat", **kw) -> ModelConfig:
    return C.bench_cfg(**kw).replace(
        ccm=CCMConfig(comp_len=C.COMP, max_steps=C.T_MAX, mode=mode,
                      method=method))


def _eval_no_context(params, cfg, ts=(1, 2, 4), n_batches=6) -> Dict:
    lo0 = M.segment_layout(0, C.CHUNK, C.COMP, C.TAIL)
    plain = cfg.replace(ccm=CCMConfig(enabled=False))
    fn = jax.jit(lambda toks: T.train_forward(params, plain, toks, lo0))
    out = {}
    for t in ts:
        lo = C.layout_for(t)
        correct = total = 0
        for b in range(n_batches):
            batch = sample_kv_batch(jax.random.fold_in(
                jax.random.PRNGKey(99), t * 100 + b), lo, 16, C.TASK)
            tail = batch["tokens"][:, lo.seq_len - lo.tail_len:]
            logits = fn(tail)
            pred = jnp.argmax(logits[:, :-1], axis=-1)
            hit = (pred == tail[:, 1:]) * batch["loss_mask"]
            correct += float(hit.sum())
            total += float(batch["loss_mask"].sum())
        out[t] = correct / max(total, 1)
    return out


# ===========================================================================
def fig6_memory_vs_performance(steps: int = 400) -> Dict:
    """Fig. 6 + Fig. 7 + Tables 23-25 (shape): accuracy vs time step and vs
    peak KV memory, CCM vs baselines vs full/no-context."""
    t0 = time.time()
    base = C.pretrain_base(steps)
    results = {}
    full_cfg = C.bench_cfg().replace(ccm=CCMConfig(enabled=False))
    results["full"] = C.eval_at_timesteps(base, full_cfg)
    results["no_context"] = _eval_no_context(base, full_cfg)
    variants = {
        "ccm-concat": _variant_cfg("ccm", "concat"),
        "ccm-merge": _variant_cfg("ccm", "merge"),
        "gisting-online": _variant_cfg("gisting"),
        "compressive": _variant_cfg("compressive"),
    }
    for name, cfg in variants.items():
        p = C.train_compression(base, cfg, steps)
        results[name] = C.eval_at_timesteps(p, cfg)
    rows = {}
    for name, accs in results.items():
        for t, acc in accs.items():
            mname = name if name in ("full", "no_context") else \
                ("ccm-merge" if name == "ccm-merge" else name)
            method_key = {"full": "full", "no_context": "no_context",
                          "ccm-concat": "ccm-concat",
                          "ccm-merge": "ccm-merge",
                          "gisting-online": "gisting-online",
                          "compressive": "compressive"}[name]
            toks = C.peak_kv_tokens(method_key, t)
            kb = C.kv_bytes(C.bench_cfg(), toks) / 1024
            C.csv_row(f"fig6/{name}/t{t}", 0.0,
                      f"acc={acc:.3f};peak_kv_kb={kb:.1f}")
            rows[f"{name}/t{t}"] = {"acc": acc, "peak_kv_kb": kb}
    print(f"# fig6 wall: {time.time()-t0:.0f}s")
    return rows


# ===========================================================================
def table5_conditional_lora(steps: int = 300) -> Dict:
    """Table 5: conditional vs default (unconditional) LoRA."""
    base = C.pretrain_base(steps)
    out = {}
    for method, mode in [("ccm", "concat"), ("ccm", "merge"),
                         ("gisting", "concat")]:
        cfg = _variant_cfg(method, mode)
        tag = f"{method}-{mode}" if method == "ccm" else method
        for cond in (True, False):
            p = C.train_compression(base, cfg, steps,
                                    unconditional=not cond)
            acc = C.eval_at_timesteps(p, cfg, ts=(C.T_MAX,),
                                      unconditional=not cond)[C.T_MAX]
            key = f"{tag}/{'conditional' if cond else 'default'}"
            C.csv_row(f"table5/{key}", 0.0, f"acc={acc:.3f}")
            out[key] = acc
    return out


# ===========================================================================
def table8_training_speed() -> Dict:
    """Table 8: parallelized CCM training vs recursive (RMT/AutoCompressor-
    style BPTT through t sequential compressions). ms per sample."""
    cfg = _variant_cfg("ccm", "concat")
    layout = C.layout_for(C.T_MAX)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    batch = sample_kv_batch(jax.random.PRNGKey(1), layout, 8, C.TASK)

    def par_loss(p):
        lg = T.train_forward(p, cfg, batch["tokens"], layout)
        tail = batch["tokens"][:, layout.seq_len - layout.tail_len:]
        return next_token_loss(lg, tail, batch["loss_mask"])

    def rec_loss(p):
        """RMT/AutoCompressor-style: BPTT through t sequential compression
        forwards, then the tail pass."""
        state = I.init_online_state(cfg, 8, max_cache_len=C.TAIL + 2)
        step = layout.chunk_len + layout.comp_len
        toks = batch["tokens"]
        for j in range(layout.t_steps):
            chunk = toks[:, j * step:(j + 1) * step - layout.comp_len]
            state = I.ingest_context(p, cfg, state, chunk)
        tail = toks[:, layout.t_steps * step:]
        lg, _ = I.prefill(p, cfg, state, tail, full_logits=True)
        return next_token_loss(lg, tail, batch["loss_mask"])

    par_step = jax.jit(jax.grad(par_loss))
    us_par = C.timed(par_step, params, iters=5)
    rec_step = jax.jit(jax.grad(rec_loss))
    us_rec = C.timed(rec_step, params, iters=5)
    ratio = us_rec / us_par
    C.csv_row("table8/parallel", us_par / 8, f"ms_per_sample={us_par/8e3:.2f}")
    C.csv_row("table8/recursive", us_rec / 8,
              f"ms_per_sample={us_rec/8e3:.2f};speedup={ratio:.2f}x")
    return {"parallel_us": us_par, "recursive_us": us_rec,
            "speedup": ratio}


# ===========================================================================
def table1_throughput() -> Dict:
    """Table 1 (shape): serving cost at time step 16-analog — decode step
    time + context KV length, full-context vs CCM-concat vs CCM-merge."""
    t = C.T_MAX
    lc, m = C.CHUNK, C.COMP
    out = {}
    for method, ctx_tokens in [
            ("full", t * lc), ("ccm-concat", t * m), ("ccm-merge", m)]:
        cfg = _variant_cfg("ccm",
                           "merge" if method == "ccm-merge" else "concat")
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        B = 32
        state = I.init_online_state(cfg, B, max_cache_len=ctx_tokens + 64)
        state = state._replace(cache=state.cache._replace(
            length=jnp.asarray(ctx_tokens if method == "full" else 0,
                               jnp.int32)))
        if method != "full":
            state = state._replace(mem=state.mem._replace(
                slots=jnp.asarray(t if method == "ccm-concat" else 1,
                                  jnp.int32)))
        step = jax.jit(lambda s, tok: I.decode_step(params, cfg, s, tok))
        tok = jnp.ones((B, 1), jnp.int32)
        us = C.timed(lambda: step(state, tok)[0], iters=20)
        thr = B / (us / 1e6)
        kvb = C.kv_bytes(cfg, ctx_tokens) / 1024
        C.csv_row(f"table1/{method}", us,
                  f"samples_per_s={thr:.0f};ctx_kv_len={ctx_tokens};"
                  f"ctx_kv_kb={kvb:.1f}")
        out[method] = {"us": us, "throughput": thr,
                       "ctx_tokens": ctx_tokens}
    return out


# ===========================================================================
def table3_complexity() -> Dict:
    """Table 3: measured peak-KV scaling vs time step per method."""
    out = {}
    for method in ("full", "ccm-concat", "ccm-merge", "gisting-online",
                   "compressive"):
        toks = [C.peak_kv_tokens(method, t) for t in (1, 2, 4, 8, 16)]
        growth = toks[-1] / toks[0]
        C.csv_row(f"table3/{method}", 0.0,
                  "peak_tokens=" + "|".join(map(str, toks))
                  + f";growth16x={growth:.1f}")
        out[method] = toks
    return out


# ===========================================================================
def _stream_kv_batch(key, layout, batch, vocab):
    """CCM-layout batch whose chunks/tail are a CONTIGUOUS token stream
    (fig8 trains compression on the streaming distribution)."""
    import numpy as np
    from repro.data.synthetic import COMP, lm_stream
    raw_len = layout.t_steps * layout.chunk_len + layout.tail_len
    raw = lm_stream(key, batch, raw_len, vocab)
    comp = np.asarray(layout.comp_mask)
    toks = jnp.zeros((batch, layout.seq_len), jnp.int32)
    toks = toks.at[:, ~comp].set(raw)
    toks = toks.at[:, comp].set(COMP)
    lm = jnp.ones((batch, layout.tail_len - 1), jnp.float32)
    return {"tokens": toks, "loss_mask": lm}


def fig8_streaming(steps: int = 400) -> Dict:
    """Fig. 8: streaming perplexity, CCM vs StreamingLLM (same KV budget).

    Trains base + compression ON the stream distribution (PG19-analog)."""
    ccm = CCMConfig(comp_len=C.COMP, max_steps=C.T_MAX, stream_window=64,
                    stream_sink=4, stream_chunk=16, stream_mem_slots=8)
    cfg = C.bench_cfg().replace(ccm=ccm)
    import functools
    sampler = functools.partial(_stream_kv_batch, vocab=cfg.vocab_size)
    base = C.pretrain_base(steps, sampler=sampler)
    params = C.train_compression(base, cfg, steps, sampler=sampler)
    toks = lm_stream(jax.random.PRNGKey(5), 8, 512, cfg.vocab_size)
    out = {}
    for name, ccm_on in (("ccm", True), ("streamingllm", False)):
        st = ST.init_stream_state(cfg, 8)
        step = jax.jit(lambda s, t: ST.stream_step(params, cfg, s, t,
                                                   ccm_on=ccm_on))
        nll = cnt = 0.0
        for i in range(0, 512 - 16, 16):
            lg, st = step(st, toks[:, i:i + 16])
            lp = jax.nn.log_softmax(lg.astype(jnp.float32)[:, :-1], -1)
            tgt = toks[:, i + 1:i + 16]
            nll += float(-jnp.take_along_axis(
                lp, tgt[..., None], -1).sum())
            cnt += tgt.size
        ppl = float(np.exp(nll / cnt))
        C.csv_row(f"fig8/{name}", 0.0, f"ppl={ppl:.2f}")
        out[name] = ppl
    return out


# ===========================================================================
def table16_merge_design(steps: int = 300) -> Dict:
    """Table 16: merge update — arithmetic average vs EMA."""
    base = C.pretrain_base(steps)
    out = {}
    for name, alpha in (("arith", None), ("ema0.5", 0.5)):
        cfg = C.bench_cfg().replace(ccm=CCMConfig(
            comp_len=C.COMP, max_steps=C.T_MAX, mode="merge",
            merge_alpha=alpha))
        p = C.train_compression(base, cfg, steps)
        accs = C.eval_at_timesteps(p, cfg)
        C.csv_row(f"table16/{name}", 0.0,
                  ";".join(f"t{t}={a:.3f}" for t, a in accs.items()))
        out[name] = accs
    return out


# ===========================================================================
def table18_comp_len(steps: int = 300) -> Dict:
    """Table 18: <COMP> token length sweep."""
    base = C.pretrain_base(steps)
    out = {}
    for m in (1, 2, 4):
        cfg = C.bench_cfg().replace(ccm=CCMConfig(
            comp_len=m, max_steps=C.T_MAX))
        p = C.train_compression(base, cfg, steps)
        acc = C.eval_at_timesteps(p, cfg, ts=(C.T_MAX,))[C.T_MAX]
        C.csv_row(f"table18/m{m}", 0.0, f"acc={acc:.3f}")
        out[f"m{m}"] = acc
    return out
