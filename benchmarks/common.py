"""Shared benchmark harness: tiny-model train/eval loops + timing.

Paper-fidelity benchmarks run REAL training of a small decoder on the
synthetic online KV task (answers only recoverable through compressed
memory), so accuracy deltas between methods are meaningful, then measure
the same quantities the paper tabulates (accuracy per time step, peak KV
bytes, step time). Scale is CPU-sized; trends, ratios and orderings are
the reproduction target (absolute GPU numbers are not reproducible in this
container — EXPERIMENTS.md §Paper-fidelity).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as M
from repro.data.synthetic import KVTaskConfig, sample_kv_batch
from repro.launch.train import make_train_step, trainable_mask_for
from repro.models import transformer as T
from repro.models.config import CCMConfig, ModelConfig
from repro.optim import partition as PT
from repro.optim.adamw import AdamWConfig, init_adamw

TASK = KVTaskConfig(n_keys=16, n_vals=16)
T_MAX = 4
CHUNK = 8
COMP = 2
TAIL = 8


def bench_cfg(**kw) -> ModelConfig:
    base = dict(name="bench", family="dense", n_layers=2, d_model=128,
                n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=128,
                compute_dtype="float32", train_mode="lora",
                ccm=CCMConfig(comp_len=COMP, max_steps=T_MAX))
    base.update(kw)
    return ModelConfig(**base)


def layout_for(t: int, comp_len: int = COMP) -> M.SegmentLayout:
    return M.segment_layout(t, CHUNK, comp_len, TAIL)


def pretrain_base(steps: int = 600, seed: int = 0,
                  lr: float = 3e-3, sampler=None) -> Dict:
    """Fine-tune the base model full-context on the task (the paper first
    fine-tunes LLaMA on each dataset; full-context = upper bound)."""
    cfg = bench_cfg(train_mode="full").replace(
        ccm=CCMConfig(enabled=False, comp_len=COMP, max_steps=T_MAX))
    layout = layout_for(T_MAX)
    params = T.init_lm(jax.random.PRNGKey(seed), cfg)
    tr = trainable_mask_for(cfg, params)
    tp, fp = PT.partition(params, tr)
    opt = init_adamw(tp)
    step = jax.jit(make_train_step(
        cfg, layout, AdamWConfig(lr=lr, total_steps=steps)))
    draw = sampler or (lambda k, lo, b: sample_kv_batch(k, lo, b, TASK))
    for s in range(steps):
        batch = draw(jax.random.fold_in(
            jax.random.PRNGKey(seed + 1), s), layout, 32)
        tp, opt, m, _ = step(tp, fp, opt, batch, None)
    return PT.merge(tp, fp)


def train_compression(base_params: Dict, cfg: ModelConfig,
                      steps: int = 600, seed: int = 1, lr: float = 3e-3,
                      unconditional: bool = False, sampler=None) -> Dict:
    """Train the compression adapter (LoRA + comp embeddings) on a frozen
    base — paper Alg. 1."""
    layout = layout_for(cfg.ccm.max_steps, cfg.ccm.comp_len)
    fresh = T.init_lm(jax.random.PRNGKey(seed), cfg)
    params = dict(base_params)
    params["comp_embed"] = fresh["comp_embed"]
    params = _graft_lora(params, fresh)
    tr = trainable_mask_for(cfg, params)
    tp, fp = PT.partition(params, tr)
    opt = init_adamw(tp)
    from repro.launch.train import _loss_fn
    from repro.optim.adamw import adamw_update

    opt_cfg = AdamWConfig(lr=lr, total_steps=steps)

    @jax.jit
    def step(tp, fp, opt, batch):
        def lf(tp_):
            merged = PT.merge(tp_, fp)
            logits = T.train_forward(merged, cfg, batch["tokens"], layout,
                                     unconditional_lora=unconditional)
            tail = batch["tokens"][:, layout.seq_len - layout.tail_len:]
            from repro.optim.losses import next_token_loss
            return next_token_loss(logits, tail, batch["loss_mask"])

        loss, grads = jax.value_and_grad(lf)(tp)
        tp2, opt2, m = adamw_update(opt_cfg, tp, grads, opt)
        m["loss"] = loss
        return tp2, opt2, m

    draw = sampler or (lambda k, lo, b: sample_kv_batch(k, lo, b, TASK))
    for s in range(steps):
        batch = draw(jax.random.fold_in(
            jax.random.PRNGKey(seed + 2), s), layout, 32)
        tp, opt, m = step(tp, fp, opt, batch)
    return PT.merge(tp, fp)


def _graft_lora(params: Dict, fresh: Dict) -> Dict:
    """Copy fresh (zero-delta) LoRA subtrees into a base param tree that
    may lack them (base pretrained without CCM)."""
    import copy
    out = jax.tree.map(lambda x: x, params)
    layers = dict(out["layers"])
    attn = dict(layers["attn"])
    attn["lora"] = fresh["layers"]["attn"]["lora"]
    layers["attn"] = attn
    out["layers"] = layers
    return out


def eval_at_timesteps(params: Dict, cfg: ModelConfig,
                      ts=(1, 2, 4), n_batches: int = 6,
                      seed: int = 99, query_pool: str = "ctx",
                      unconditional: bool = False) -> Dict[int, float]:
    """Accuracy of value prediction at each online time step t.

    ``query_pool="ctx"`` (default) queries only keys shown in context —
    per-retrieval fidelity.  ``query_pool="all"`` queries the whole key
    space — mapping COVERAGE, the quantity the paper's Fig. 7 trend is
    about (see `sample_kv_batch`)."""
    out = {}
    for t in ts:
        layout = layout_for(t, cfg.ccm.comp_len)
        fn = jax.jit(lambda toks: T.train_forward(
            params, cfg, toks, layout, unconditional_lora=unconditional))
        correct = total = 0
        for b in range(n_batches):
            batch = sample_kv_batch(jax.random.fold_in(
                jax.random.PRNGKey(seed), t * 100 + b), layout, 16, TASK,
                query_pool=query_pool)
            logits = fn(batch["tokens"])
            tail = batch["tokens"][:, layout.seq_len - layout.tail_len:]
            pred = jnp.argmax(logits[:, :-1], axis=-1)
            hit = (pred == tail[:, 1:]) * batch["loss_mask"]
            correct += float(hit.sum())
            total += float(batch["loss_mask"].sum())
        out[t] = correct / max(total, 1)
    return out


# ---------------------------------------------------------------------------
# KV memory accounting (paper's "peak KV memory" MB numbers)
# ---------------------------------------------------------------------------

def kv_bytes(cfg: ModelConfig, n_tokens: int, bytes_per=2) -> int:
    return 2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * n_tokens * bytes_per


def peak_kv_tokens(method: str, t: int, lc: int = CHUNK, m: int = COMP,
                   tail: int = TAIL) -> int:
    """Peak #tokens whose KV is live during [compress then infer] at step t
    (paper Fig. 5 / Table 3)."""
    if method == "full":
        return t * lc + tail
    if method == "no_context":
        return tail
    if method == "ccm-concat":
        return max((t - 1) * m + lc + m, t * m + tail)
    if method == "ccm-merge":
        return max(m + lc + m, m + tail)
    if method == "gisting":          # fixed-context recompression of C(t)
        return max(t * lc + t * m, t * m + tail)
    if method == "gisting-online":
        return max(lc + m + (t - 1) * m, t * m + tail)
    if method == "compressive":
        return max(lc + t * m, t * m + tail)
    raise KeyError(method)


def timed(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """us per call (blocked until ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def csv_row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
